from .optimizer import OptConfig, adamw_init, adamw_update, lr_schedule
from .train_loop import TrainState, make_train_step, train_state_axes

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_schedule",
           "TrainState", "make_train_step", "train_state_axes"]
