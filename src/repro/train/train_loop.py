"""train_step assembly: grad accumulation, clipping, AdamW, metrics.

The returned ``train_step(state, batch)`` is pure and jit/pjit-friendly;
``train_state_axes`` supplies the logical-axes pytree for sharding the
whole state (params + moments inherit the same rules — FSDP over "data",
TP over "model").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import init_model, loss_fn
from repro.sharding.partition import PARAM_RULES, constrain
from .optimizer import OptConfig, adamw_init, adamw_update

PyTree = Any


@dataclass
class TrainState:
    params: PyTree
    opt: Dict
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda _, c: TrainState(*c))


def init_train_state(cfg: ArchConfig, oc: OptConfig,
                     key: Optional[jax.Array] = None,
                     abstract: bool = False) -> Tuple[TrainState, PyTree]:
    params, axes = init_model(cfg, key, abstract=abstract)
    opt = adamw_init(params, oc)
    step = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
            else jnp.zeros((), jnp.int32))
    state = TrainState(params, opt, step)
    state_axes = TrainState(
        axes,
        {"m": axes, "v": axes, "step": ()},
        ())
    return state, state_axes


def train_state_axes(cfg: ArchConfig) -> PyTree:
    _, axes = init_model(cfg, abstract=True)
    return TrainState(axes, {"m": axes, "v": axes, "step": ()}, ())


def make_train_step(cfg: ArchConfig, oc: OptConfig,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves are (B, ...) with B divisible by ``microbatches``; grads
    accumulate in f32 across microbatches (lax.scan), then one AdamW
    update. Grads cross the DP reduction in ``oc.grad_dtype`` (bf16
    compression).
    """

    # grads + accumulator live in the PARAM sharding (FSDP/TP): the DP
    # reduction lowers to reduce-scatter instead of a full all-reduce
    # (§Perf, deepseek train cell — halves grad wire bytes and shards the
    # f32 accumulator 16-way).
    _, param_axes = init_model(cfg, abstract=True)

    def _shard_like_params(tree):
        return jax.tree.map(
            lambda g, ax: constrain(g, ax, PARAM_RULES), tree, param_axes)

    def single_grads(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, mb)
        grads = jax.tree.map(
            lambda g: g.astype(jnp.dtype(oc.grad_dtype)), grads)
        return _shard_like_params(grads), metrics

    def train_step(state: TrainState, batch: Dict
                   ) -> Tuple[TrainState, Dict]:
        params = state.params
        batch = jax.tree.map(
            lambda x: constrain(x, ("act_batch",) + (None,) * (x.ndim - 1)),
            batch)
        if microbatches == 1:
            grads, metrics = single_grads(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(acc, mb):
                g, m = single_grads(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return _shard_like_params(acc), m

            zero = _shard_like_params(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, ms = jax.lax.scan(body, zero, mbs)
            grads = jax.tree.map(lambda g: (g / microbatches).astype(
                jnp.dtype(oc.grad_dtype)), grads)
            metrics = jax.tree.map(lambda x: x[-1], ms)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, params, oc)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
