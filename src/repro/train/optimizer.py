"""AdamW (from scratch) with low-precision moment options + LR schedule.

Distributed-optimization knobs (DESIGN §5):
  * moment dtypes: bf16 first/second moments cut optimizer HBM 4x — the
    difference between fitting and not fitting the 671B cell on v5e;
  * global-norm clipping in f32 regardless of param dtype;
  * decoupled weight decay; cosine schedule with linear warmup.
Optimizer state inherits each parameter's sharding (FSDP over "data").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "bfloat16"
    v_dtype: str = "bfloat16"
    # bf16 gradient all-reduce (compression): cast grads before the DP
    # reduction boundary.
    grad_dtype: str = "bfloat16"


def lr_schedule(oc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - oc.warmup_steps) /
                 jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = oc.min_lr_frac + (1 - oc.min_lr_frac) * cos
    return oc.lr * warm * frac


def adamw_init(params: PyTree, oc: OptConfig) -> Dict:
    def zeros_like_dt(p, dt):
        return jnp.zeros(p.shape, jnp.dtype(dt)) if not isinstance(
            p, jax.ShapeDtypeStruct) else jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(dt))
    return {
        "m": jax.tree.map(lambda p: zeros_like_dt(p, oc.m_dtype), params),
        "v": jax.tree.map(lambda p: zeros_like_dt(p, oc.v_dtype), params),
        "step": (jnp.zeros((), jnp.int32)
                 if not any(isinstance(l, jax.ShapeDtypeStruct)
                            for l in jax.tree.leaves(params))
                 else jax.ShapeDtypeStruct((), jnp.int32)),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in jax.tree.leaves(tree)))


def adamw_update(grads: PyTree, state: Dict, params: PyTree,
                 oc: OptConfig) -> Tuple[PyTree, Dict, Dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9)) \
        if oc.clip_norm else jnp.float32(1.0)
    lr = lr_schedule(oc, step)
    b1, b2 = jnp.float32(oc.b1), jnp.float32(oc.b2)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        if oc.weight_decay and p.ndim >= 2:   # no decay on norms/bias
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
