"""Stage decomposition of Algorithm 2 and the pluggable backend protocol.

See ``src/repro/build/README.md`` for the full design. The paper's RLC
indexing algorithm decomposes into four stages shared by every backend:

1. **access-order scheduling** — vertices sorted by the IN-OUT score,
   defining both the hub processing order and the PR2 access ids;
2. **kernel-search** — exhaustive BFS over (vertex, label-sequence)
   states up to depth ``k``, producing tentative entries and the eager
   kernel candidates that seed stage 3;
3. **kernel-BFS** — per kernel ``L``, a product-automaton expansion over
   ``V x {0..|L|-1}`` guided by ``L``-cyclic transitions;
4. **pruned insertion** — PR1/PR2 gating of every tentative entry, with
   PR3 feeding failures back into stage 3 as subtree cuts.

Backends differ only in *how* stages 2-3 traverse the graph (scalar
python, numpy bitset waves, or Pallas ``frontier_step`` batches); the
pruning semantics and therefore the produced index are bit-identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, fields
from typing import Callable, Dict, Tuple

import numpy as np

from repro.core.graph import LabeledGraph
from repro.core.rlc_index import RLCIndex


@dataclass
class BuildStats:
    """Construction counters (bit-identical across backends) plus
    per-build metadata (``backend``, ``wall_time_s``) that is not part of
    counter equality."""

    kernel_search_states: int = 0
    kernel_bfs_states: int = 0
    inserted: int = 0
    pruned_pr1: int = 0
    pruned_pr2: int = 0
    pr3_cuts: int = 0
    backend: str = ""
    wall_time_s: float = 0.0
    #: peak bytes of PR1 coverage mirror(s) held by any one process during
    #: the build (0 = no mirror: the scalar reference). Metadata, not part
    #: of counter equality — backends with different mirror layouts are
    #: still bit-identical in entries/counters.
    peak_mirror_bytes: int = 0

    _COUNTERS = ("kernel_search_states", "kernel_bfs_states", "inserted",
                 "pruned_pr1", "pruned_pr2", "pr3_cuts")

    def counters(self) -> Tuple[int, ...]:
        """The backend-invariant portion (used by equivalence tests)."""
        return tuple(getattr(self, f) for f in self._COUNTERS)

    def as_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in fields(self)}
        d["wall_time_s"] = round(d["wall_time_s"], 6)
        return d


def access_schedule(graph: LabeledGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 1: the IN-OUT access order and the 1-based access ids
    (``aid[order[i]] == i + 1``); PR2 compares these ids."""
    return graph.access_order(), graph.access_ids()


def vertex_mask(ys, num_vertices: int) -> int:
    """Vertex ids -> packed little-endian bitmask (a python int, the same
    representation the bits build tier and the delta engine use)."""
    if not len(ys):
        return 0
    row = np.zeros(num_vertices, np.uint8)
    row[np.asarray(ys)] = 1
    return int.from_bytes(
        np.packbits(row, bitorder="little").tobytes(), "little")


def mask_vertices(mask: int):
    """Iterate the set vertex ids of a packed mask (ascending)."""
    while mask:
        b = mask & -mask
        yield b.bit_length() - 1
        mask ^= b


class PhaseProbe:
    """Traversal-footprint recorder for one ``(hub, direction)`` phase.

    Filled by the stage 2-3 implementations (all tiers record the same
    sets, so a trace is tier-independent) and consumed by the delta
    engine's affected-hub analysis:

    * ``visited`` — every vertex holding a discovered state (superset of
      the vertices whose entries the phase attempts / whose rows PR1
      reads);
    * ``near`` — vertices whose states expand with *full label fanout*
      (kernel-search states at depth < k, plus the hub itself): any edge
      mutation at these tails changes the traversal;
    * ``lab[l]`` — vertices whose states expand *along label l only*
      (kernel-BFS product states): an edge mutation with label ``l`` at
      these tails changes the traversal, other labels cannot.

    All masks are packed python-int bitsets over the vertex space.
    """

    __slots__ = ("visited", "near", "lab")

    def __init__(self, num_labels: int):
        self.visited = 0
        self.near = 0
        self.lab = [0] * num_labels


class PrunedInserter:
    """Stage 4: PR1/PR2-gated insertion into an :class:`RLCIndex`.

    One instance per build; every backend funnels its tentative entries
    through :meth:`insert` (scalar) or the batched equivalents in
    :mod:`repro.build.batched`, so the pruning semantics live in exactly
    one place. ``insert`` returning False is the PR3 signal.
    """

    def __init__(self, index: RLCIndex, stats: BuildStats,
                 use_pr1: bool = True, use_pr2: bool = True):
        self.index = index
        self.stats = stats
        self.use_pr1 = use_pr1
        self.use_pr2 = use_pr2

    def insert(self, y: int, v: int, L, backward: bool) -> bool:
        """Try to record hub ``v`` at visited vertex ``y`` (paper
        Algorithm 2, lines 19-24). True iff the entry was added."""
        idx = self.index
        if self.use_pr2 and idx.aid[v] > idx.aid[y]:
            self.stats.pruned_pr2 += 1
            return False
        s, t = (y, v) if backward else (v, y)
        if self.use_pr1 and idx.query(s, t, L):
            self.stats.pruned_pr1 += 1
            return False
        if backward:
            idx.add_out(y, v, L)
        else:
            idx.add_in(y, v, L)
        self.stats.inserted += 1
        return True


class BuildBackend:
    """Protocol for index-construction backends.

    Concrete backends implement :meth:`_build` and set :attr:`name`;
    :meth:`build` wraps it with timing + stats metadata. All backends
    must produce bit-identical index entries *and* pruning counters for
    the same ``(graph, k, flags)`` — the property suite in
    ``tests/test_build_backends.py`` enforces this against the python
    reference.
    """

    name: str = "?"

    def __init__(self, use_pr1: bool = True, use_pr2: bool = True,
                 use_pr3: bool = True):
        self.use_pr1 = use_pr1
        self.use_pr2 = use_pr2
        self.use_pr3 = use_pr3
        #: optional :class:`repro.obs.BuildPhaseObserver` — when set, the
        #: per-(hub, direction) phase loops report timings and pruning
        #: counter deltas into its registry (None = zero overhead)
        self.observer = None

    def set_observer(self, observer) -> "BuildBackend":
        self.observer = observer
        return self

    # -- subclass hook --------------------------------------------------- #
    def _build(self, graph: LabeledGraph, k: int, stats: BuildStats
               ) -> RLCIndex:
        raise NotImplementedError

    # -- public API ------------------------------------------------------- #
    def build(self, graph: LabeledGraph, k: int
              ) -> Tuple[RLCIndex, BuildStats]:
        stats = BuildStats(backend=self.name)
        t0 = time.perf_counter()
        index = self._build(graph, int(k), stats)
        stats.wall_time_s = time.perf_counter() - t0
        if self.observer is not None:
            self.observer.build_done(self.name, stats.wall_time_s)
        return index, stats


# --------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------- #
_REGISTRY: Dict[str, Callable[..., BuildBackend]] = {}

#: resolution order for ``backend="auto"`` — first constructible wins.
AUTO_ORDER = ("numpy", "python")


def register_backend(name: str, factory: Callable[..., BuildBackend]
                     ) -> None:
    _REGISTRY[name] = factory


def list_backends() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_backend(name: str = "auto", **kw) -> BuildBackend:
    """Instantiate a build backend. ``auto`` resolves to the first
    registered name in :data:`AUTO_ORDER` (numpy; the pallas backend
    must be requested explicitly — on CPU it runs interpreted).
    Constructor errors (bad kwargs etc.) propagate."""
    if name == "auto":
        name = next((c for c in AUTO_ORDER if c in _REGISTRY), "python")
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown build backend {name!r}; choose from "
            f"{('auto',) + list_backends()}")
    return _REGISTRY[name](**kw)
