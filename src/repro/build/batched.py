"""Shared wave machinery for the batched (numpy / pallas) build backends.

Algorithm 2 is restated level-synchronously: one (hub, direction) phase
advances a *batch* of label contexts per wave:

* **kernel-search rows** are label sequences, identified by their
  base-``|L|`` digit string: depth-``d`` row ``seq`` holds the vertices
  reachable from the hub by spelling ``seq``;
* **kernel-BFS rows** are product-automaton coordinates ``(kernel, p)``,
  all of a hub's eager kernels advancing in lockstep.

Frontiers travel as **index pairs** ``(row, vertex)`` so every per-wave
operation is proportional to the edges actually traversed; the dense
side — visited/attempted bitsets and the per-MR PR1 coverage rows from
:meth:`RLCIndex.pr1_cover_all` — exists only for O(1) membership
gathers. A wave therefore costs one neighbor gather over the
label-partitioned CSR, one sort-dedup, and a handful of mask gathers,
regardless of how many kernels ride in the batch.

Why this is bit-identical to the sequential reference (and why batching
stops at the hub boundary): within one (hub, direction) phase, every
PR1 outcome is a function of the *pre-phase* index snapshot only — an
insertion made during the phase can change ``Query(y, v, L)`` solely by
creating that exact ``(v, L)`` entry at ``y``, i.e. the duplicate-attempt
case, which the visited/attempted bitsets detect exactly like the
reference's ``seen`` sets (within one depth the duplicate cannot even
occur: two same-length sequences never share a minimum repeat, since
``L^h`` is unique for fixed length and ``L``). PR2 is a static access-id
comparison. Across hubs the dependence is real — hub ``v``'s PR1 reads
entries completed by every earlier hub — so hubs are scheduled
sequentially in access order, the same reason
``dense.build_condensed_device`` only matches the paper schedule at
``hub_batch=1``. Equivalence of entries *and* pruning counters is
enforced by ``tests/test_build_backends.py``.

Low-degree hubs would waste the fixed per-wave cost, so a two-hop work
estimate dispatches them to the scalar reference stages instead (same
inserter, same index — identical by construction). ``mode`` forces
``"vector"`` / ``"scalar"`` for testing.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import LabeledGraph
from repro.core.minimum_repeat import LabelSeq, minimum_repeat, mr_id_space
from repro.core.rlc_index import RLCIndex

from .base import (BuildBackend, BuildStats, PrunedInserter, access_schedule,
                   vertex_mask)
from .reference import (_MemoMR, _NeighborLists, kernel_bfs_scalar,
                        kernel_search_scalar)

# Attach the packed PR1 mirror only while it stays below this footprint;
# beyond it every hub takes the scalar path (correct, just not batched).
MIRROR_BUDGET_BYTES = 256 * 1024 * 1024

#: two-hop work estimate below which a hub-direction runs the scalar
#: stages (tuned on the bench stand-ins; see README).
SCALAR_THRESHOLD = 12

#: two-hop work estimate above which the engine's array waves replace the
#: packed-word waves (array overhead amortizes only on wide frontiers).
GATHER_THRESHOLD = 2000


class FrontierEngine:
    """Expansion strategy for one wave (the only backend-specific part).

    Both hooks take a frontier as parallel ``(rows, ys)`` index arrays
    and return the raw expanded pairs (possibly with duplicates — the
    caller dedups against its visited sets). ``expand`` advances pair
    ``j`` along ``rowlab[rows[j]]`` into row ``dstrow[rows[j]]``;
    ``expand_fanout`` advances along *every* label, landing label ``l``
    of row ``r`` in row ``r * num_labels + l``.
    """

    def expand(self, rows: np.ndarray, ys: np.ndarray, rowlab: np.ndarray,
               dstrow: np.ndarray, backward: bool
               ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def expand_fanout(self, rows: np.ndarray, ys: np.ndarray,
                      backward: bool) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError


def _two_hop_estimate(indptr: np.ndarray, nbrs: np.ndarray,
                      deg: np.ndarray) -> np.ndarray:
    """``deg(v) + sum_{u in N(v)} deg(u)`` — a depth-2 breadth proxy for
    the kernel-search state count (the hybrid dispatch signal)."""
    if not deg.size:
        return deg.astype(np.int64)
    keys = np.repeat(np.arange(deg.size), np.diff(indptr))
    two = np.bincount(keys, weights=deg[nbrs].astype(np.float64),
                      minlength=deg.size)
    return deg.astype(np.int64) + two.astype(np.int64)


class _PhaseContext:
    """State for the vectorized (hub, direction) phases, allocated once
    per build: MR/row tables, the reusable attempted/coverage buffers,
    and the stats plumbing shared with the scalar path."""

    def __init__(self, graph: LabeledGraph, k: int, index: RLCIndex,
                 stats: BuildStats, engine: FrontierEngine,
                 mr_ids: Dict[LabelSeq, int],
                 use_pr1: bool, use_pr2: bool, use_pr3: bool):
        self.g = graph
        self.k = k
        self.index = index
        self.stats = stats
        self.engine = engine
        self.use_pr1, self.use_pr2, self.use_pr3 = use_pr1, use_pr2, use_pr3
        self.V = graph.num_vertices
        self.nl = graph.num_labels
        self.aid = np.asarray(index.aid)
        self.mrs_by_c: List[LabelSeq] = [
            mr for mr, _ in sorted(mr_ids.items(), key=lambda kv: kv[1])]
        C = self.C = len(self.mrs_by_c)
        # row-id (base-|L| digit string) -> mr id, or -1 when |MR| > k
        self._rowid_c: Dict[Tuple[int, int, bool], int] = {}
        # reusable per-phase buffers (rows cleared after each phase)
        self._att = np.zeros((C, self.V), dtype=bool)
        self._cov = np.empty((C, self.V), dtype=bool)
        self._cov_has = np.zeros(C, dtype=bool)
        # static kernel-BFS row layout over ALL kernels, per direction:
        # rows [base_c, base_c + m_c) hold kernel c's phases 0..m_c-1;
        # inactive kernels simply never receive frontier pairs.
        self._layout = {bw: self._make_layout(bw) for bw in (False, True)}
        # packed-word adjacency for the bits tier (built on first use)
        self._adjb: Dict[bool, Tuple[list, list]] = {}
        self._pr2_cache: Tuple[int, int] = (-1, 0)
        self._want_cache: Dict[Tuple[int, bool], list] = {}

    def _make_layout(self, backward: bool) -> Tuple[np.ndarray, ...]:
        rowlab, dstrow, c_of_row, is_p0, p0_of_c = [], [], [], [], []
        base = 0
        for c, L in enumerate(self.mrs_by_c):
            m = len(L)
            for p in range(m):
                rowlab.append(L[m - 1 - p] if backward else L[p])
                dstrow.append(base + (p + 1) % m)
                c_of_row.append(c)
                is_p0.append(p == 0)
            p0_of_c.append(base)
            base += m
        return (np.asarray(rowlab, dtype=np.int64),
                np.asarray(dstrow, dtype=np.int64),
                np.asarray(c_of_row, dtype=np.int64),
                np.asarray(is_p0, dtype=bool),
                np.asarray(p0_of_c, dtype=np.int64),
                base)

    # ------------------------------------------------------------------ #
    def _c_of_rowid1(self, r: int, depth: int, backward: bool) -> int:
        """MR id for one kernel-search row id (−1 when not an entry). A
        row id's base-|L| digits spell the sequence (reversed when
        backward, which prepends labels)."""
        key = (r, depth, backward)
        c = self._rowid_c.get(key)
        if c is None:
            digits = []
            rr = r
            for _ in range(depth):
                digits.append(rr % self.nl)
                rr //= self.nl
            seq = tuple(digits) if backward else tuple(digits[::-1])
            mr = minimum_repeat(seq)
            c = self.index._mr_ids[mr] if len(mr) <= self.k else -1
            self._rowid_c[key] = c
        return c

    def _c_of_rowids(self, rowids: np.ndarray, depth: int, backward: bool
                     ) -> np.ndarray:
        return np.array([self._c_of_rowid1(r, depth, backward)
                         for r in rowids.tolist()], dtype=np.int64)

    def _cov_rows(self, cs: np.ndarray, packed: Optional[np.ndarray]
                  ) -> None:
        """Ensure unpacked PR1 coverage rows exist for MR ids ``cs``."""
        for c in cs[~self._cov_has[cs]].tolist():
            self._cov[c] = np.unpackbits(packed[c], count=self.V,
                                         bitorder="little").astype(bool)
            self._cov_has[c] = True

    # ------------------------------------------------------------------ #
    def run_phase(self, v: int, backward: bool, probe=None) -> None:
        pr2pass = (self.aid >= self.aid[v]) if self.use_pr2 else None
        cov_packed = (self.index.pr1_cover_all(v, backward)
                      if self.use_pr1 else None)
        touched: List[np.ndarray] = []
        seeds_c: List[np.ndarray] = []
        seeds_y: List[np.ndarray] = []
        self._kernel_search(v, backward, pr2pass, cov_packed, touched,
                            seeds_c, seeds_y, probe)
        if seeds_c:
            self._kernel_bfs(v, backward, pr2pass, cov_packed, touched,
                             np.concatenate(seeds_c),
                             np.concatenate(seeds_y), probe)
        # reset the reusable buffers (only rows this phase touched)
        if touched:
            cs = np.unique(np.concatenate(touched))
            self._att[cs] = False
            self._cov_has[cs] = False

    # -- stage 2: vectorized kernel-search ------------------------------- #
    def _kernel_search(self, v: int, backward: bool,
                       pr2pass: Optional[np.ndarray],
                       cov_packed: Optional[np.ndarray],
                       touched: List[np.ndarray],
                       seeds_c: List[np.ndarray],
                       seeds_y: List[np.ndarray], probe=None) -> None:
        nl, V, st = self.nl, self.V, self.stats
        nb, lb = self.g.in_edges(v) if backward else self.g.out_edges(v)
        rows = lb.astype(np.int64)          # depth-1 row id == label
        ys = nb.astype(np.int64)            # edges are unique: no dedup
        if probe is not None:
            probe.visited |= 1 << v
            probe.near |= 1 << v
        for depth in range(1, self.k + 1):
            if depth > 1:
                raw_r, raw_y = self.engine.expand_fanout(rows, ys, backward)
                if not raw_r.size:
                    return
                pairs = np.unique(raw_r * V + raw_y)
                rows, ys = pairs // V, pairs % V
            if probe is not None:
                m = vertex_mask(ys, V)
                probe.visited |= m
                if depth < self.k:
                    probe.near |= m
            st.kernel_search_states += len(rows)
            urows, inv = np.unique(rows, return_inverse=True)
            cs = self._c_of_rowids(urows, depth, backward)[inv]
            keep = cs >= 0
            if keep.any():
                self._attempts_ks(v, backward, cs[keep], ys[keep],
                                  pr2pass, cov_packed, touched,
                                  seeds_c, seeds_y)

    def _attempts_ks(self, v: int, backward: bool, cs: np.ndarray,
                     yy: np.ndarray, pr2pass: Optional[np.ndarray],
                     cov_packed: Optional[np.ndarray],
                     touched: List[np.ndarray],
                     seeds_c: List[np.ndarray],
                     seeds_y: List[np.ndarray]) -> None:
        """Stage-4 pruned insertion for one kernel-search wave.

        Within a depth every ``(mr, y)`` pair occurs at most once (same-
        length sequences never share an MR), so only the *cross-depth*
        repeat needs the attempted bitset: the reference resolves it as
        PR2 refiring, else PR1 firing on the now-present entry."""
        st = self.stats
        seeds_c.append(cs)
        seeds_y.append(yy)
        touched.append(cs)
        prev = self._att[cs, yy]
        self._att[cs, yy] = True
        if pr2pass is not None:
            ok2 = pr2pass[yy]
            st.pruned_pr2 += int((~ok2).sum())
        else:
            ok2 = np.ones(len(yy), dtype=bool)
        if cov_packed is not None:   # PR1 on
            self._cov_rows(np.unique(cs), cov_packed)
            newins = ok2 & ~self._cov[cs, yy] & ~prev
            st.pruned_pr1 += int(ok2.sum() - newins.sum())
            st.inserted += int(newins.sum())
            self._apply(v, backward, cs[newins], yy[newins])
        else:                        # PR1 off: every PR2 pass (re-)inserts
            st.inserted += int(ok2.sum())
            self._apply(v, backward, cs[ok2], yy[ok2])

    # -- stage 3: vectorized kernel-BFS ----------------------------------- #
    def _kernel_bfs(self, v: int, backward: bool,
                    pr2pass: Optional[np.ndarray],
                    cov_packed: Optional[np.ndarray],
                    touched: List[np.ndarray],
                    seed_c: np.ndarray, seed_y: np.ndarray,
                    probe=None) -> None:
        V, st = self.V, self.stats
        rowlab, dstrow, c_of_row, is_p0, p0_of_c, R = self._layout[backward]
        pairs = np.unique(seed_c * V + seed_y)   # cross-depth seeds collapse
        seed_c, seed_y = pairs // V, pairs % V
        VIS = np.zeros((R, V), dtype=bool)
        fr = p0_of_c[seed_c]
        fy = seed_y
        VIS[fr, fy] = True
        use_pr3 = self.use_pr3
        while fr.size:
            if probe is not None:
                labs = rowlab[fr]
                for lv in np.unique(labs).tolist():
                    probe.lab[lv] |= vertex_mask(fy[labs == lv], V)
            raw_r, raw_y = self.engine.expand(fr, fy, rowlab, dstrow,
                                              backward)
            if not raw_r.size:
                return
            pairs = np.unique(raw_r * V + raw_y)
            nr, ny = pairs // V, pairs % V
            new = ~VIS[nr, ny]
            nr, ny = nr[new], ny[new]
            if not nr.size:
                return
            if probe is not None:
                probe.visited |= vertex_mask(ny, V)
            st.kernel_bfs_states += len(nr)
            VIS[nr, ny] = True
            p0 = is_p0[nr]
            if p0.any():
                yy = ny[p0]
                cs = c_of_row[nr[p0]]
                if pr2pass is not None:
                    ok = pr2pass[yy]
                    st.pruned_pr2 += int((~ok).sum())
                else:
                    ok = np.ones(len(yy), dtype=bool)
                if cov_packed is not None:
                    self._cov_rows(np.unique(cs), cov_packed)
                    cov = self._cov[cs, yy] & ok
                    st.pruned_pr1 += int(cov.sum())
                    ok &= ~cov
                st.inserted += int(ok.sum())
                self._apply(v, backward, cs[ok], yy[ok])
                if use_pr3 and not ok.all():
                    st.pr3_cuts += int(len(ok) - ok.sum())
                    keep = np.ones(len(nr), dtype=bool)
                    keep[np.nonzero(p0)[0][~ok]] = False
                    nr, ny = nr[keep], ny[keep]
            fr, fy = nr, ny

    # ================= packed-word (bits) tier ========================== #
    # The same staged semantics with frontiers as arbitrary-width machine
    # words (python ints over the V-bit vertex space): zero per-op
    # dispatch overhead, which wins for the many small-to-mid phases
    # where array calls cannot amortize. One OR per (state, label) is the
    # whole expansion step.
    def _adj_bits(self, backward: bool) -> Tuple[list, list]:
        """``(by_label, by_vertex)`` packed-word adjacency views of the
        label-partitioned CSR: ``by_label[l][y]`` is the neighbor bitset
        of ``y`` via ``l``; ``by_vertex[y]`` lists its nonzero
        ``(l, bits)`` pairs (the fanout layout). Built edge-
        proportionally (one shifted-bit OR per edge)."""
        got = self._adjb.get(backward)
        if got is not None:
            return got
        V, nl = self.V, self.nl
        lptr, lnbr = self.g.label_csr(backward)
        bounds = lptr.tolist()
        nbr_list = lnbr.tolist()
        by_label = [[0] * V for _ in range(nl)]
        by_vertex: list = [()] * V
        nz = np.nonzero(np.diff(lptr))[0]
        for key in nz.tolist():
            y, l = divmod(key, nl)
            bits = 0
            for n in nbr_list[bounds[key]:bounds[key + 1]]:
                bits |= 1 << n
            by_label[l][y] = bits
        for y in range(V):
            row = tuple((l, by_label[l][y]) for l in range(nl)
                        if by_label[l][y])
            if row:
                by_vertex[y] = row
        got = self._adjb[backward] = (by_label, by_vertex)
        return got

    def _pr2_bits(self, v: int) -> int:
        """``{y : aid(y) >= aid(v)}`` as a packed word (cached per hub —
        both directions share it)."""
        if self._pr2_cache[0] != v:
            packed = np.packbits(self.aid >= self.aid[v],
                                 bitorder="little")
            self._pr2_cache = (v, int.from_bytes(packed.tobytes(),
                                                 "little"))
        return self._pr2_cache[1]

    def run_phase_bits(self, v: int, backward: bool, probe=None) -> None:
        by_label, by_vertex = self._adj_bits(backward)
        pr2 = self._pr2_bits(v) if self.use_pr2 else None
        mirror = self.index._mirror
        side = mirror.out if backward else mirror.in_
        cov_cache: Dict[int, int] = {}
        cmap: Dict[int, list] = {}
        if self.use_pr1:
            row = (self.index.l_in[v] if backward else self.index.l_out[v])
            mr_ids = self.index._mr_ids
            for x, mrs in row.items():
                for mr in mrs:
                    cmap.setdefault(mr_ids[mr], []).append(x)

        def covget(c: int) -> int:
            acc = cov_cache.get(c)
            if acc is None:
                acc = int.from_bytes(side[v, c].tobytes(), "little")
                for x in cmap.get(c, ()):
                    acc |= (int.from_bytes(side[x, c].tobytes(), "little")
                            | (1 << x))
                cov_cache[c] = acc
            return acc

        att = self._ks_bits(v, backward, pr2, covget, by_vertex, probe)
        for c, seeds in att.items():
            self._kbfs_bits(v, backward, pr2, covget, by_label, c, seeds,
                            probe)

    def _ks_bits(self, v: int, backward: bool, pr2: Optional[int], covget,
                 by_vertex: list, probe=None) -> Dict[int, int]:
        """Bits-tier kernel-search; returns the eager kernel seeds
        (``{mr id: attempted bitset}`` — exactly the reference's
        ``kernels`` map)."""
        st, nl = self.stats, self.nl
        att: Dict[int, int] = {}
        if probe is not None:
            probe.visited |= 1 << v
            probe.near |= 1 << v
        # depth-1 rows are single labels: v's own adjacency fans out
        cur: Dict[int, int] = {l: b for l, b in by_vertex[v]}
        for depth in range(1, self.k + 1):
            if depth > 1:
                nxt: Dict[int, int] = {}
                nxt_get = nxt.get
                for r, bits in cur.items():
                    base = r * nl
                    loc: Dict[int, int] = {}
                    loc_get = loc.get
                    f = bits
                    while f:
                        b = f & -f
                        f ^= b
                        for l, ab in by_vertex[b.bit_length() - 1]:
                            loc[l] = loc_get(l, 0) | ab
                    for l, bb in loc.items():
                        key = base + l
                        nxt[key] = nxt_get(key, 0) | bb
                cur = nxt
                if not cur:
                    break
            use_pr1 = self.use_pr1
            add = (self.index.add_out_many if backward
                   else self.index.add_in_many)
            for r, bits in cur.items():
                st.kernel_search_states += bits.bit_count()
                if probe is not None:
                    probe.visited |= bits
                    if depth < self.k:
                        probe.near |= bits
                c = self._c_of_rowid1(r, depth, backward)
                if c < 0:
                    continue
                prev = att.get(c, 0)
                att[c] = prev | bits
                # stage-4 pruned insertion, inlined (hot: once per row)
                if pr2 is not None:
                    p2 = bits & pr2
                    st.pruned_pr2 += bits.bit_count() - p2.bit_count()
                    if not p2:
                        continue
                else:
                    p2 = bits
                if use_pr1:
                    ok = p2 & ~covget(c)
                    if prev:
                        ok &= ~prev
                    st.pruned_pr1 += p2.bit_count() - ok.bit_count()
                else:
                    ok = p2
                if ok:
                    st.inserted += ok.bit_count()
                    ys, f = [], ok
                    while f:
                        b = f & -f
                        ys.append(b.bit_length() - 1)
                        f ^= b
                    add(ys, v, self.mrs_by_c[c])
        return att

    def _kbfs_bits(self, v: int, backward: bool, pr2: Optional[int],
                   covget, by_label: list, c: int, seeds: int,
                   probe=None) -> None:
        """Bits-tier kernel-BFS for one kernel ``c`` from its seed set.

        The stage-4 logic is inlined into the wave loop (this runs once
        per (hub, direction, kernel) — the hottest python scope in the
        build). ``m == 1`` skips the phase bookkeeping entirely.
        """
        st = self.stats
        key = (c, backward)
        cached = self._want_cache.get(key)
        if cached is None:
            L = self.mrs_by_c[c]
            m = len(L)
            lbls = [L[m - 1 - p] if backward else L[p] for p in range(m)]
            cached = self._want_cache[key] = (
                [by_label[lv] for lv in lbls], lbls)
        want, lbls = cached
        m = len(want)
        if m == 1:
            adjl = want[0]
            vis = fr = seeds
            while fr:
                if probe is not None:
                    probe.lab[lbls[0]] |= fr
                acc = 0
                while fr:
                    b = fr & -fr
                    acc |= adjl[b.bit_length() - 1]
                    fr ^= b
                new = acc & ~vis
                if not new:
                    return
                st.kernel_bfs_states += new.bit_count()
                if probe is not None:
                    probe.visited |= new
                vis |= new
                fr = self._p0_bits(new, c, v, backward, pr2, covget)
            return
        vis = [0] * m
        vis[0] = seeds
        fr = [0] * m
        fr[0] = seeds
        while True:
            nxt = [0] * m
            for p in range(m):
                f = fr[p]
                if not f:
                    continue
                if probe is not None:
                    probe.lab[lbls[p]] |= f
                adjl = want[p]
                acc = 0
                while f:
                    b = f & -f
                    acc |= adjl[b.bit_length() - 1]
                    f ^= b
                if acc:
                    nxt[(p + 1) % m] |= acc
            alive = False
            for p in range(m):
                new = nxt[p] & ~vis[p]
                if not new:
                    fr[p] = 0
                    continue
                st.kernel_bfs_states += new.bit_count()
                if probe is not None:
                    probe.visited |= new
                vis[p] |= new
                if p == 0:
                    new = self._p0_bits(new, c, v, backward, pr2, covget)
                fr[p] = new
                if new:
                    alive = True
            if not alive:
                return

    def _p0_bits(self, new: int, c: int, v: int, backward: bool,
                 pr2: Optional[int], covget) -> int:
        """Phase-0 boundary crossing: pruned insertion + the PR3 cut.
        Returns the bits the BFS may keep expanding."""
        st = self.stats
        if pr2 is not None:
            p2 = new & pr2
            st.pruned_pr2 += new.bit_count() - p2.bit_count()
        else:
            p2 = new
        if self.use_pr1 and p2:
            ok = p2 & ~covget(c)
            st.pruned_pr1 += p2.bit_count() - ok.bit_count()
        else:
            ok = p2
        if ok:
            st.inserted += ok.bit_count()
            ys, f = [], ok
            while f:
                b = f & -f
                ys.append(b.bit_length() - 1)
                f ^= b
            if backward:
                self.index.add_out_many(ys, v, self.mrs_by_c[c])
            else:
                self.index.add_in_many(ys, v, self.mrs_by_c[c])
        if self.use_pr3:
            if ok != new:
                st.pr3_cuts += new.bit_count() - ok.bit_count()
            return ok
        return new

    # -- stage 4 application ---------------------------------------------- #
    def _apply(self, v: int, backward: bool, cs: np.ndarray, ys: np.ndarray
               ) -> None:
        """Record the surviving entries (grouped per MR for one bulk dict +
        mirror update each)."""
        if not cs.size:
            return
        add = self.index.add_out_many if backward else self.index.add_in_many
        if cs[0] == cs[-1] and (cs == cs[0]).all():   # common: one MR
            add(ys.tolist(), v, self.mrs_by_c[int(cs[0])])
            return
        order = np.argsort(cs, kind="stable")
        cs, ys = cs[order], ys[order]
        splits = np.nonzero(np.diff(cs))[0] + 1
        for chunk_c, chunk_y in zip(np.split(cs, splits),
                                    np.split(ys, splits)):
            add(chunk_y.tolist(), v, self.mrs_by_c[int(chunk_c[0])])


class PhaseRunner:
    """One build's per-phase dispatch state: the hybrid tier selection,
    the shared :class:`_PhaseContext`, and the scalar fallback.

    Factored out of :meth:`BatchedBackend._build` so the delta engine
    (:mod:`repro.build.delta`) can drive phases in its own schedule —
    replaying most of them from a trace and running only the dirty ones —
    while executing *exactly* the code path a full build would have used
    (that shared path is what makes delta results bit-identical).
    ``run`` accepts an optional :class:`repro.build.base.PhaseProbe` that
    records the phase's traversal footprint.
    """

    def __init__(self, backend: "BatchedBackend", graph: LabeledGraph,
                 k: int, index: RLCIndex, stats: BuildStats, mirror=None):
        self.backend = backend
        self.g = graph
        self.k = int(k)
        self.index = index
        self.stats = stats
        self.inserter = PrunedInserter(index, stats, backend.use_pr1,
                                       backend.use_pr2)
        V, nl = graph.num_vertices, graph.num_labels
        words = (V + 7) // 8
        C = len(mr_id_space(nl, k)) if nl else 0
        self.can_batch = (backend.mode != "scalar" and V > 0 and nl > 0
                          and 2 * C * V * words <= backend.mirror_budget)
        self._nbrs = None      # scalar-tier accessor, built on first dispatch
        self._mr_fn = _MemoMR()
        self.out_deg, self.in_deg = graph.out_degree(), graph.in_degree()
        #: True when a caller-provided mirror was adopted instead of a
        #: fresh (empty) one — the delta engine hands back the previous
        #: build's mirror, whose rows double as the old phase outputs.
        self.adopted_mirror = False
        if self.can_batch:
            mr_ids = mr_id_space(nl, k)
            if mirror is not None:
                index._mirror = mirror
                index._mr_ids = dict(mr_ids)
                self.adopted_mirror = True
            else:
                index.attach_bit_mirror(mr_ids)
            stats.peak_mirror_bytes = max(stats.peak_mirror_bytes,
                                          index._mirror.size_bytes())
            self.ctx = _PhaseContext(graph, k, index, stats,
                                     backend._make_engine(graph), mr_ids,
                                     backend.use_pr1, backend.use_pr2,
                                     backend.use_pr3)
            self._est = {
                True: _two_hop_estimate(graph.bwd[0], graph.bwd[1],
                                        self.in_deg),
                False: _two_hop_estimate(graph.fwd[0], graph.fwd[1],
                                         self.out_deg)}

    def run(self, v: int, backward: bool, probe=None) -> None:
        """Run one ``(hub, direction)`` phase (no-op on a degree-0 hub,
        exactly like the full build's skip). With a
        :class:`repro.obs.BuildPhaseObserver` on the backend, the phase's
        wall time and counter deltas are reported (the degree-0 skip is
        never timed — it would drown the histograms in zeros)."""
        if not (self.in_deg[v] if backward else self.out_deg[v]):
            return
        obs = self.backend.observer
        if obs is None:
            self._run_phase(v, backward, probe)
            return
        before = self.stats.counters()
        t0 = time.perf_counter()
        self._run_phase(v, backward, probe)
        obs.phase(v, backward, time.perf_counter() - t0,
                  counter_delta=tuple(
                      a - b for a, b in zip(self.stats.counters(), before)))

    def _run_phase(self, v: int, backward: bool, probe=None) -> None:
        backend = self.backend
        if self.can_batch:
            est = self._est[backward][v]
            if backend.mode == "vector":
                self.ctx.run_phase(v, backward, probe)
                return
            if backend.mode == "bits" or (
                    backend.mode == "hybrid"
                    and backend.scalar_threshold <= est
                    < backend.gather_threshold):
                self.ctx.run_phase_bits(v, backward, probe)
                return
            if (backend.mode == "hybrid"
                    and est >= backend.gather_threshold):
                self.ctx.run_phase(v, backward, probe)
                return
        if self._nbrs is None:
            self._nbrs = _NeighborLists(self.g)
        kernels = kernel_search_scalar(
            self._nbrs, self.inserter, self.stats, self._mr_fn, v, self.k,
            backward, probe)
        for L, seeds in kernels.items():
            kernel_bfs_scalar(self._nbrs, self.inserter, self.stats,
                              backend.use_pr3, v, L, seeds, backward, probe)

    def finish(self) -> RLCIndex:
        """Detach the construction-time scratch (the coverage mirror is up
        to ``mirror_budget`` bytes — never serve it)."""
        if self.index._mirror is not None:
            self.stats.peak_mirror_bytes = max(
                self.stats.peak_mirror_bytes,
                self.index._mirror.size_bytes())
        self.index._mirror = None
        self.index._mr_ids = None
        return self.index


class BatchedBackend(BuildBackend):
    """Template for wave-batched backends; subclasses provide the engine."""

    def __init__(self, use_pr1: bool = True, use_pr2: bool = True,
                 use_pr3: bool = True, mode: str = "hybrid",
                 scalar_threshold: Optional[int] = None,
                 gather_threshold: Optional[int] = None,
                 mirror_budget: int = MIRROR_BUDGET_BYTES):
        super().__init__(use_pr1, use_pr2, use_pr3)
        if mode not in ("hybrid", "vector", "bits", "scalar"):
            raise ValueError(
                f"mode {mode!r} not in hybrid|vector|bits|scalar")
        self.mode = mode
        self.scalar_threshold = (SCALAR_THRESHOLD if scalar_threshold is None
                                 else scalar_threshold)
        self.gather_threshold = (GATHER_THRESHOLD if gather_threshold is None
                                 else gather_threshold)
        self.mirror_budget = mirror_budget

    # -- subclass hook ---------------------------------------------------- #
    def _make_engine(self, graph: LabeledGraph) -> FrontierEngine:
        raise NotImplementedError

    # --------------------------------------------------------------------- #
    def _build(self, graph: LabeledGraph, k: int, stats: BuildStats
               ) -> RLCIndex:
        order, aid = access_schedule(graph)
        index = RLCIndex(graph.num_vertices, k, aid)
        runner = PhaseRunner(self, graph, k, index, stats)
        for v in order:
            v = int(v)
            for backward in (True, False):
                runner.run(v, backward)
        return runner.finish()
