"""Incremental (delta) index construction for mutable graphs.

Public surface::

    from repro.build.delta import DeltaBuilder, GraphDelta

    db = DeltaBuilder(graph, k=2)            # backend="numpy" by default
    index, stats = db.full()                 # traced full build
    delta = GraphDelta.of(inserts=[(0, 1, 7)], deletes=[(3, 0, 4)])
    res = db.apply(delta)                    # incremental re-derivation
    db.index                                 # == full rebuild on db.graph

``apply`` produces an index (and :class:`repro.build.BuildStats`
counters) **bit-identical** to a from-scratch build of the mutated
graph, re-running only the ``(hub, direction)`` phases the delta can
touch and replaying every other phase from the previous build's trace.
See ``src/repro/build/README.md`` ("Incremental delta builds") for the
affected-hub analysis and the correctness argument; the property suite
in ``tests/test_delta_build.py`` enforces the bit-identicality bar.
"""
from __future__ import annotations

from repro.core.graph import GraphDelta

from .engine import DeltaBuilder, DeltaResult
from .trace import BuildTrace, PhaseTrace

__all__ = ["BuildTrace", "DeltaBuilder", "DeltaResult", "GraphDelta",
           "PhaseTrace"]
