"""Build traces: the per-phase memory a delta build replays from.

A full (traced) build records one :class:`PhaseTrace` per
``(hub, direction)`` phase — the traversal footprint captured by
:class:`repro.build.base.PhaseProbe` plus the phase's share of the
:class:`repro.build.BuildStats` counters. The delta engine consults the
footprint to decide whether a graph delta can touch the phase, replays
the counters (and the old entries) when it cannot, and refreshes the
trace for phases it re-runs — so traces chain across any number of
``apply`` calls.

All masks are packed python-int bitsets over the vertex space (the same
representation as the bits build tier), so a phase's storage cost is
proportional to the vertices it actually touched.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

ZERO_COUNTERS: Tuple[int, ...] = (0, 0, 0, 0, 0, 0)


@dataclass
class PhaseTrace:
    """Footprint + counters of one ``(hub, direction)`` phase.

    ``visited``/``near``/``lab`` follow the
    :class:`repro.build.base.PhaseProbe` contract; ``counters`` is the
    phase's delta of ``BuildStats.counters()``. The all-empty instance
    doubles as the trace of a skipped (degree-0) phase.
    """

    visited: int = 0
    near: int = 0
    lab: Tuple[int, ...] = ()
    counters: Tuple[int, ...] = ZERO_COUNTERS
    _work: int = -1

    @property
    def work(self) -> int:
        """Cached ``popcount(visited)`` (phases replay across traces, so
        memoizing on the instance pays)."""
        if self._work < 0:
            self._work = self.visited.bit_count()
        return self._work


_EMPTY = PhaseTrace()


class BuildTrace:
    """All phase traces of one build, keyed by ``(hub, backward)``."""

    def __init__(self, num_vertices: int, num_labels: int):
        self.num_vertices = num_vertices
        self.num_labels = num_labels
        self._phases: Dict[Tuple[int, bool], PhaseTrace] = {}
        #: sum of visited popcounts — the delta engine's work denominator
        self.total_work = 0

    def get(self, v: int, backward: bool) -> PhaseTrace:
        return self._phases.get((v, backward), _EMPTY)

    def put(self, v: int, backward: bool, pt: PhaseTrace) -> None:
        old = self._phases.get((v, backward))
        if old is not None:
            self.total_work -= old.work
        self._phases[(v, backward)] = pt
        self.total_work += pt.work

    def __len__(self) -> int:
        return len(self._phases)

    def nbytes(self) -> int:
        """Approximate footprint of the stored masks (diagnostics)."""
        total = 0
        for pt in self._phases.values():
            total += (pt.visited.bit_length() + pt.near.bit_length()
                      + sum(m.bit_length() for m in pt.lab)) // 8 + 8
        return total
