"""The incremental build engine: affected-hub analysis + phase replay.

Algorithm 2 processes hubs sequentially in access order, each ``(hub,
direction)`` phase reading (a) the graph along its traversal and (b) the
index entries earlier phases left at the vertices it visits (PR1) plus
the access-id order (PR2). A :class:`repro.core.graph.GraphDelta`
therefore changes a phase's outcome only if one of four conditions
holds, each checkable against the previous build's
:class:`~repro.build.delta.trace.BuildTrace` with a handful of bitmask
ANDs:

A. **traversal** — a delta edge's tail sits where the phase expands:
   in the full-fanout region (``near``, kernel-search depth < k plus
   the hub itself) for any label, or in the label-``l`` expansion mask
   (``lab[l]``, kernel-BFS product states) for a delta edge labeled
   ``l``;
B. **moved hub** — the hub's own access rank changed (only delta
   endpoints can change score, and any crossing pair contains an
   endpoint whose rank moved);
C. **crossing** — a moved endpoint ``u`` crossed the hub in access
   order and either ``u`` is visited (a PR2 comparison flips) or
   ``u``'s output is readable by the phase's PR1 — Algorithm 1's
   case 1 needs ``u``'s entry at the hub AND at a visited vertex, on
   opposite sides;
D. **upstream diff** — an earlier re-run phase changed entries the
   phase's PR1 reads, with the same case-1 hub gating as C.

Clean phases are *replayed*: their old entries bulk-merge into the new
index from the carried replay tables and their recorded counters
accumulate — no traversal, no PR1 evaluation. Dirty phases re-run
through the very same :class:`repro.build.batched.PhaseRunner` a full
build uses, against the index state accumulated so far (which, by
induction over the schedule, equals the full build's pre-phase state at
every vertex the phase can read). Old entries of a dirty phase are
tombstoned — dropped from the replay tables — and superseded by
whatever the re-run derives; XOR-diffing the hub's packed coverage-
mirror rows yields the vertices whose rows changed, which feeds
condition D, the partial re-freeze, and the serving layer's targeted
cache invalidation. When the affected set exceeds
``fallback_frac * total_work`` the engine abandons the pass and falls
back to a full traced rebuild (:meth:`DeltaBuilder.rebuild_delta`).

To keep a small delta's cost proportional to what it touches, the
builder carries state across applies and patches it in place:

* the packed :class:`~repro.core.rlc_index.BitMirror` — replayed hubs'
  rows are exactly their old outputs, so only re-run hubs' rows move;
* the replay tables (``hub -> {row: mr-set}``) — the mr-sets are
  *shared* with the index dicts, which is safe because a row's per-hub
  set is only ever mutated during that hub's own phase, and a hub's
  phase only runs when its old entries were tombstoned, never replayed;
* the bits-tier packed adjacency and the scalar tier's neighbor lists —
  only the delta edges' endpoint rows are recomputed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.graph import GraphDelta, LabeledGraph
from repro.core.minimum_repeat import mr_id_space
from repro.core.rlc_index import RLCIndex
from repro.obs import NULL_OBS

from ..base import (BuildStats, PhaseProbe, access_schedule, get_backend,
                    mask_vertices, vertex_mask)
from ..batched import BatchedBackend, PhaseRunner
from .trace import BuildTrace, PhaseTrace

#: replay table: hub -> {row -> set of MRs} (sets shared with the index)
HubTable = Dict[int, Dict[int, Set[tuple]]]


class _FallbackNeeded(Exception):
    """Internal signal: the affected set blew the incremental budget."""


def _add_counters(stats: BuildStats, tup) -> None:
    for name, d in zip(BuildStats._COUNTERS, tup):
        setattr(stats, name, getattr(stats, name) + d)


def _sub_counters(a, b) -> Tuple[int, ...]:
    return tuple(x - y for x, y in zip(a, b))


def _rows_of(mask: int) -> np.ndarray:
    return np.fromiter(mask_vertices(mask), dtype=np.int64)


@dataclass
class DeltaResult:
    """Outcome of one :meth:`DeltaBuilder.apply`.

    ``stats`` carries the counters an equivalent full rebuild would
    report (replayed + re-run); the row arrays drive the partial
    re-freeze and targeted cache invalidation:

    * ``dirty_out``/``dirty_in`` — vertices whose L_out/L_in entry rows
      changed (answers involving them as source/target may change);
    * ``resort_out``/``resort_in`` — rows whose entries are unchanged
      but whose aid sort order may have shifted (they hold a hub whose
      access rank moved): they must re-freeze but never invalidate
      cached answers.

    On ``fallback`` every row counts as dirty and the arrays are empty —
    callers should re-freeze and invalidate wholesale.
    """

    stats: BuildStats
    fallback: bool = False
    #: why the incremental pass was abandoned (None when it succeeded):
    #: ``"static_budget"`` — conditions A/B alone blew the budget before
    #: any carried state was touched; ``"budget"`` — the re-run work
    #: crossed it mid-pass; ``"requested"`` — rebuild_delta called
    #: directly.
    fallback_reason: Optional[str] = None
    phases_total: int = 0
    phases_rerun: int = 0
    phases_replayed: int = 0
    dirty_out: np.ndarray = field(default_factory=lambda: np.empty(0, np.int64))
    dirty_in: np.ndarray = field(default_factory=lambda: np.empty(0, np.int64))
    resort_out: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))
    resort_in: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))
    #: why phases went dirty: traversal / moved_hub / crossing / upstream
    causes: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> dict:
        return dict(fallback=self.fallback,
                    fallback_reason=self.fallback_reason,
                    phases_total=self.phases_total,
                    phases_rerun=self.phases_rerun,
                    phases_replayed=self.phases_replayed,
                    dirty_rows=int(len(self.dirty_out) + len(self.dirty_in)),
                    resort_rows=int(len(self.resort_out)
                                    + len(self.resort_in)),
                    causes=dict(self.causes),
                    build=self.stats.as_dict())


class DeltaBuilder:
    """Stateful incremental builder: one traced index + its graph.

    ``backend`` must be a batched backend name (``numpy``/``pallas`` —
    the python reference has no phase runner to replay through);
    ``**backend_kw`` reaches its constructor (``use_pr1/2/3``, ``mode``,
    thresholds), so pruning ablations delta-build too.
    ``fallback_frac`` bounds the incremental pass at that fraction of
    the previous build's traversal work before the full-rebuild escape
    hatch fires; ``1.0`` disables the fallback entirely.
    """

    def __init__(self, graph: LabeledGraph, k: int, backend: str = "numpy",
                 fallback_frac: float = 0.25, obs=None, **backend_kw):
        if not (0.0 < fallback_frac <= 1.0):
            raise ValueError(
                f"fallback_frac must be in (0, 1], got {fallback_frac}")
        self.graph = graph
        self.k = int(k)
        self.fallback_frac = fallback_frac
        self._backend_name = backend
        self._backend_kw = dict(backend_kw)
        # delta-engine telemetry: apply outcomes, fallback attribution,
        # phase dispositions, dirty causes. Per-phase timings go through
        # BuildPhaseObserver ("delta" context for re-runs, "delta_full"
        # for the traced bootstraps/rebuilds).
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        self._m_apply = reg.counter(
            "rlc_delta_applies", desc="delta applies by outcome",
            labelnames=("outcome",))
        self._m_fb = reg.counter(
            "rlc_delta_fallbacks",
            desc="incremental applies abandoned to a full rebuild",
            labelnames=("reason",))
        phases = reg.counter("rlc_delta_phases",
                             desc="phases per incremental apply",
                             labelnames=("kind",))
        self._m_rerun = phases.labels(kind="rerun")
        self._m_replay = phases.labels(kind="replayed")
        self._m_cause = reg.counter(
            "rlc_delta_dirty_causes",
            desc="why phases went dirty (A/B/C/D conditions)",
            labelnames=("cause",))
        self._m_apply_s = reg.histogram(
            "rlc_delta_apply_seconds",
            desc="end-to-end wall time of one apply()", unit="s").labels()
        self._new_backend()     # fail fast on bad names/kwargs
        self.index: Optional[RLCIndex] = None
        self.trace: Optional[BuildTrace] = None
        self.stats: Optional[BuildStats] = None
        # carried across applies (see module doc): coverage mirror,
        # replay tables + output-row masks, packed adjacency, neighbor
        # lists. All patched in place per delta.
        self._mirror = None
        self._rep: Dict[bool, HubTable] = {True: {}, False: {}}
        self._omask: Dict[bool, Dict[int, int]] = {True: {}, False: {}}
        self._adjb: Dict[bool, tuple] = {}
        self._nbrs = None
        self._needs_full = False
        self.deltas_applied = 0
        self.fallbacks = 0

    def _new_backend(self, context: Optional[str] = None) -> BatchedBackend:
        b = get_backend(self._backend_name, **self._backend_kw)
        if not isinstance(b, BatchedBackend):
            raise ValueError(
                f"delta builds need a batched backend, got "
                f"{self._backend_name!r}")
        if context is not None:
            # None observer in disabled mode — phases stay untimed
            b.set_observer(self.obs.build_observer(context))
        return b

    # ------------------------------------------------------------------ #
    def _capture(self, runner: PhaseRunner, index: RLCIndex) -> None:
        """Carry the runner's reusable state into the builder."""
        self._mirror = index._mirror
        self._adjb = dict(runner.ctx._adjb) if runner.can_batch else {}
        self._nbrs = runner._nbrs
        runner.finish()

    def _rebuild_tables(self, index: RLCIndex) -> None:
        """Full re-derivation of the carried replay tables (out side =
        backward phases' output, in side = forward phases'). The per-row
        mr-sets are shared with the index, not copied."""
        for backward, maps in ((True, index.l_out), (False, index.l_in)):
            tab: HubTable = {}
            masks: Dict[int, int] = {}
            for y, d in enumerate(maps):
                bit_y = 1 << y
                for hub, mrs in d.items():
                    row = tab.get(hub)
                    if row is None:
                        row = tab[hub] = {}
                    row[y] = mrs
                    masks[hub] = masks.get(hub, 0) | bit_y
            self._rep[backward] = tab
            self._omask[backward] = masks

    def _traced_build(self, graph: LabeledGraph
                      ) -> Tuple[RLCIndex, BuildStats, BuildTrace]:
        """Full build through the phase runner, recording a trace."""
        nl = graph.num_labels
        stats = BuildStats(backend=f"{self._backend_name}+trace")
        t0 = time.perf_counter()
        order, aid = access_schedule(graph)
        index = RLCIndex(graph.num_vertices, self.k, aid)
        runner = PhaseRunner(self._new_backend("delta_full"), graph, self.k,
                             index, stats)
        trace = BuildTrace(graph.num_vertices, nl)
        for v in order:
            v = int(v)
            for backward in (True, False):
                probe = PhaseProbe(nl)
                c0 = stats.counters()
                runner.run(v, backward, probe)
                trace.put(v, backward, PhaseTrace(
                    probe.visited, probe.near, tuple(probe.lab),
                    _sub_counters(stats.counters(), c0)))
        self._capture(runner, index)
        stats.wall_time_s = time.perf_counter() - t0
        return index, stats, trace

    def full(self) -> Tuple[RLCIndex, BuildStats]:
        """(Re)build the index for the current graph from scratch, traced."""
        self.index, self.stats, self.trace = self._traced_build(self.graph)
        self._rebuild_tables(self.index)
        self._needs_full = False
        return self.index, self.stats

    def rebuild_delta(self, delta: GraphDelta, validate: bool = True,
                      reason: str = "requested") -> DeltaResult:
        """Escape hatch: apply the delta, then full traced rebuild.
        ``reason`` records *why* the incremental pass was abandoned
        (surfaced in ``DeltaResult.fallback_reason`` and the
        ``rlc_delta_fallbacks`` counter)."""
        if validate:
            delta.validate(self.graph)
        self.graph = self.graph.apply_delta(delta, validate=False)
        self.full()
        self.deltas_applied += 1
        self.fallbacks += 1
        self._m_apply.inc(1, outcome="fallback")
        self._m_fb.inc(1, reason=reason)
        V2 = 2 * self.graph.num_vertices
        return DeltaResult(stats=self.stats, fallback=True,
                           fallback_reason=reason,
                           phases_total=V2, phases_rerun=V2)

    # ------------------------------------------------------------------ #
    def _patch_adjacency(self, new_graph: LabeledGraph,
                         delta: GraphDelta) -> None:
        """Recompute the carried packed-adjacency and neighbor-list rows
        of the delta edges' tail vertices (everything else is shared)."""
        rows = [r for r in (delta.inserts, delta.deletes) if r.size]
        if not rows:
            return
        edges = np.concatenate(rows)
        nl = new_graph.num_labels
        for backward in (True, False):
            touched = np.unique(edges[:, 2 if backward else 0]).tolist()
            adj = self._adjb.get(backward)
            if adj is not None:
                by_label, by_vertex = adj
                lptr, lnbr = new_graph.label_csr(backward)
                for v in touched:
                    for lv in range(nl):
                        key = v * nl + lv
                        bits = 0
                        for n in lnbr[lptr[key]:lptr[key + 1]].tolist():
                            bits |= 1 << n
                        by_label[lv][v] = bits
                    row = tuple((lv, by_label[lv][v]) for lv in range(nl)
                                if by_label[lv][v])
                    by_vertex[v] = row if row else ()
            if self._nbrs is not None:
                indptr, other, lab = (new_graph.bwd if backward
                                      else new_graph.fwd)
                lists = self._nbrs._dir[backward]
                for v in touched:
                    lo, hi = int(indptr[v]), int(indptr[v + 1])
                    lists[v] = list(zip(other[lo:hi].tolist(),
                                        lab[lo:hi].tolist()))

    # ------------------------------------------------------------------ #
    def apply(self, delta: GraphDelta, validate: bool = True) -> DeltaResult:
        """Incrementally rebuild for ``graph + delta`` (see module doc).

        The resulting ``self.index`` (entries *and* counters) is
        bit-identical to ``full()`` on the mutated graph; falls back to
        :meth:`rebuild_delta` when the affected set exceeds
        ``fallback_frac`` of the previous build's traversal work.
        """
        if self.index is None:
            raise RuntimeError("DeltaBuilder.apply before full()")
        if self._needs_full:     # a previous apply died mid-mutation
            self.full()
        if validate:
            delta.validate(self.graph)
        t0 = time.perf_counter()
        old_graph = self.graph
        new_graph = old_graph.apply_delta(delta, validate=False)
        V, nl = new_graph.num_vertices, new_graph.num_labels
        old_trace = self.trace
        old_rank_l = np.asarray(self.index.aid).tolist()
        new_order, new_aid = access_schedule(new_graph)
        new_rank_l = new_aid.tolist()

        # -- condition A inputs: delta-edge tails per direction ---------- #
        edges = ([delta.inserts] if delta.inserts.size else []) + \
                ([delta.deletes] if delta.deletes.size else [])
        all_rows = (np.concatenate(edges) if edges
                    else np.empty((0, 3), np.int32))
        tails_any = {}
        tails_lab = {}
        for backward in (True, False):
            tail_col = all_rows[:, 2 if backward else 0]
            tails_any[backward] = vertex_mask(tail_col, V)
            per_lab = [0] * nl
            for lv in np.unique(all_rows[:, 1]).tolist():
                per_lab[lv] = vertex_mask(
                    tail_col[all_rows[:, 1] == lv], V)
            tails_lab[backward] = per_lab

        # -- condition B/C inputs: endpoints whose access rank moved ----- #
        movers = [int(u) for u in delta.endpoints()
                  if old_rank_l[u] != new_rank_l[u]]
        mover_set = set(movers)
        mover_bits = 0
        for u in movers:
            mover_bits |= 1 << u

        def bail(reason: str) -> DeltaResult:
            """Hand over to the full-rebuild escape hatch."""
            self.graph = old_graph
            res = self.rebuild_delta(delta, validate=False, reason=reason)
            res.stats.wall_time_s = time.perf_counter() - t0
            self._m_apply_s.observe(res.stats.wall_time_s)
            return res

        # -- static pre-pass: evaluate conditions A/B once for every
        #    phase (the main loop reuses the verdicts), and bail to the
        #    full rebuild before touching any carried state if they
        #    alone blow the budget (fallback_frac=1.0 never falls back) -- #
        budget = (float("inf") if self.fallback_frac >= 1.0
                  else max(1, int(self.fallback_frac
                                  * max(old_trace.total_work, 1))))
        est = 0
        static_cause: List[Optional[str]] = [None] * (2 * V)
        for v in range(V):
            bit_v = 1 << v
            for backward in (True, False):
                pt = old_trace.get(v, backward)
                c = None
                if (pt.near | bit_v) & tails_any[backward]:
                    c = "traversal"
                elif pt.lab:
                    for lmask, tmask in zip(pt.lab, tails_lab[backward]):
                        if lmask & tmask:
                            c = "traversal"
                            break
                if c is None and v in mover_set:
                    c = "moved_hub"
                if c is not None:
                    static_cause[(v << 1) | backward] = c
                    est += pt.work + 1
            if est > budget:
                return bail("static_budget")

        rep = self._rep
        old_mask = self._omask

        # -- the incremental pass over the new schedule ------------------ #
        self._needs_full = True    # cleared on success or clean fallback
        self._patch_adjacency(new_graph, delta)
        stats = BuildStats(backend=f"delta[{self._backend_name}]")
        index = RLCIndex(V, self.k, new_aid)
        runner = PhaseRunner(self._new_backend("delta"), new_graph, self.k,
                             index, stats, mirror=self._mirror)
        adopted = runner.adopted_mirror
        if runner.can_batch and self._adjb:
            runner.ctx._adjb.update(self._adjb)
        if self._nbrs is not None:
            runner._nbrs = self._nbrs
        acc = [0] * len(BuildStats._COUNTERS)   # replayed counters
        dirty_rows = {True: 0, False: 0}
        # per re-run hub: its new output-row masks, and the rows where
        # its output changed (condition C/D inputs)
        new_out_mask: Dict[bool, Dict[int, int]] = {True: {}, False: {}}
        changed_by_hub: Dict[bool, Dict[int, int]] = {True: {}, False: {}}
        causes: Dict[str, int] = {}
        # prefilter mask: rows holding any mover's output (old; new rows
        # OR in as mover phases re-run) — a phase can only be
        # crossing-dirty when its hub or visited set touches these
        mover_gate = mover_bits
        for u in movers:
            mover_gate |= (old_mask[True].get(u, 0)
                           | old_mask[False].get(u, 0))
        rerun_hubs: Dict[bool, List[int]] = {True: [], False: []}
        pending_tab: Dict[bool, HubTable] = {True: {}, False: {}}
        rerun = replayed = 0
        work = 0
        try:
            for v in new_order.tolist():
                rv_old, rv_new = old_rank_l[v], new_rank_l[v]
                bit_v = 1 << v
                for backward in (True, False):
                    pt = old_trace.get(v, backward)
                    # A/B evaluated once in the pre-pass
                    cause = static_cause[(v << 1) | backward]
                    dirty = cause is not None
                    # C: crossings (v itself cannot be a mover here —
                    # the pre-pass already marked those "moved_hub")
                    if not dirty and movers and (
                            (mover_bits & pt.visited)
                            or (mover_gate & bit_v)):
                        for u in movers:
                            ru_old, ru_new = old_rank_l[u], new_rank_l[u]
                            if (ru_old < rv_old) == (ru_new < rv_new):
                                continue          # no crossing with v
                            if (1 << u) & pt.visited:
                                cause = "crossing"
                                break
                            if ru_new < rv_new:
                                om_out = new_out_mask[True].get(u, 0)
                                om_in = new_out_mask[False].get(u, 0)
                            else:
                                om_out = old_mask[True].get(u, 0)
                                om_in = old_mask[False].get(u, 0)
                            if backward:
                                hit = (om_in & bit_v) and \
                                    (om_out & pt.visited)
                            else:
                                hit = (om_out & bit_v) and \
                                    (om_in & pt.visited)
                            if hit:
                                cause = "crossing"
                                break
                        dirty = cause is not None
                    # D: an earlier re-run changed entries the phase's
                    # PR1 reads. A backward phase reads the in-side row
                    # at the hub plus, via Algorithm 1's case 1, hub-u
                    # out-side rows at visited vertices — the latter only
                    # for hubs u that sit in the hub's in-row, so each
                    # changed hub is gated on having an opposite-side
                    # entry at v (mirrored for forward phases).
                    if not dirty:
                        gate_side = not backward
                        if dirty_rows[gate_side] & bit_v:
                            cause = "upstream"
                        elif dirty_rows[backward] & pt.visited:
                            for u, ch in changed_by_hub[backward].items():
                                if not (ch & pt.visited):
                                    continue
                                gate = (old_mask[gate_side].get(u, 0)
                                        | new_out_mask[gate_side].get(u, 0))
                                if gate & bit_v:
                                    cause = "upstream"
                                    break
                        dirty = cause is not None

                    old_out = rep[backward].get(v)
                    if not dirty:
                        replayed += 1
                        if old_out:
                            if adopted:
                                # mirror rows already hold this output —
                                # dict-only merge, sharing the mr-sets
                                maps = (index.l_out if backward
                                        else index.l_in)
                                for y, ms in old_out.items():
                                    maps[y][v] = ms
                            else:
                                # fresh mirror: inverted bulk insert so
                                # the mirror rows get repopulated too
                                by_mr: Dict[tuple, List[int]] = {}
                                for y, ms in old_out.items():
                                    for mr in ms:
                                        by_mr.setdefault(mr, []).append(y)
                                add = (index.add_out_many if backward
                                       else index.add_in_many)
                                for mr, ys in by_mr.items():
                                    add(ys, v, mr)
                        for i, d in enumerate(pt.counters):
                            acc[i] += d
                        continue

                    # re-run the phase (old entries are tombstoned: they
                    # are simply never replayed)
                    rerun += 1
                    rerun_hubs[backward].append(v)
                    causes[cause] = causes.get(cause, 0) + 1
                    work += pt.work + 1
                    if work > budget:
                        raise _FallbackNeeded
                    mirror = index._mirror
                    if mirror is not None:
                        side_rows = mirror.out if backward else mirror.in_
                        if adopted:
                            # the carried rows ARE the old output; zero
                            # them so the re-run derives from scratch
                            old_rows = side_rows[v].copy()
                            side_rows[v] = 0
                        else:
                            old_rows = np.zeros_like(side_rows[v])
                            if old_out:
                                mr_ids = index._mr_ids
                                for y, ms in old_out.items():
                                    yb, ybit = y >> 3, 1 << (y & 7)
                                    for mr in ms:
                                        old_rows[mr_ids[mr], yb] |= ybit
                    probe = PhaseProbe(nl)
                    c0 = stats.counters()
                    runner.run(v, backward, probe)
                    old_trace.put(v, backward, PhaseTrace(
                        probe.visited, probe.near, tuple(probe.lab),
                        _sub_counters(stats.counters(), c0)))
                    # diff old vs new output -> condition-D marks
                    if mirror is not None:
                        # vectorized: XOR the hub's packed mirror rows
                        # against its old output rows
                        new_rows = side_rows[v]
                        changed = int.from_bytes(np.bitwise_or.reduce(
                            new_rows ^ old_rows, axis=0).tobytes(),
                            "little")
                        new_ys = int.from_bytes(np.bitwise_or.reduce(
                            new_rows, axis=0).tobytes(), "little")
                    else:
                        side_maps = index.l_out if backward else index.l_in
                        old_ys = old_mask[backward].get(v, 0)
                        changed = 0
                        new_ys = 0
                        newtab: Dict[int, Set[tuple]] = {}
                        old_tab = old_out or {}
                        for y in mask_vertices(probe.visited | old_ys):
                            new_mrs = side_maps[y].get(v)
                            if new_mrs:
                                new_ys |= 1 << y
                                newtab[y] = new_mrs
                            if (new_mrs or set()) != (old_tab.get(y)
                                                     or set()):
                                changed |= 1 << y
                        pending_tab[backward][v] = newtab
                    new_out_mask[backward][v] = new_ys
                    if v in mover_set:
                        mover_gate |= new_ys
                    if changed:
                        changed_by_hub[backward][v] = changed
                        dirty_rows[backward] |= changed
        except _FallbackNeeded:
            return bail("budget")

        _add_counters(stats, acc)
        self._capture(runner, index)
        stats.wall_time_s = time.perf_counter() - t0
        self.graph = new_graph
        self.index = index
        self.stats = stats
        self.deltas_applied += 1

        # rows that must re-freeze because a mover hub's aid shifted the
        # row's sort order (entries themselves unchanged)
        resort = {True: 0, False: 0}
        for backward in (True, False):
            for u in movers:
                resort[backward] |= (old_mask[backward].get(u, 0)
                                     | new_out_mask[backward].get(u, 0))
            resort[backward] &= ~dirty_rows[backward]

        # refresh the carried replay tables for the re-run hubs (clean
        # hubs keep their shared rows untouched)
        mrs_by_c = (
            [mr for mr, _ in sorted(mr_id_space(nl, self.k).items(),
                                    key=lambda kv: kv[1])]
            if self._mirror is not None else None)
        for backward in (True, False):
            side_all = None
            if self._mirror is not None:
                side_all = (self._mirror.out if backward
                            else self._mirror.in_)
            tab = rep[backward]
            masks = old_mask[backward]
            for v in rerun_hubs[backward]:
                if side_all is not None:
                    rows = side_all[v]
                    newtab = {}
                    for c in np.nonzero(rows.any(axis=1))[0].tolist():
                        mr = mrs_by_c[c]
                        for y in np.nonzero(np.unpackbits(
                                rows[c], count=V,
                                bitorder="little"))[0].tolist():
                            row = newtab.get(y)
                            if row is None:
                                row = newtab[y] = set()
                            row.add(mr)
                else:
                    newtab = pending_tab[backward].get(v, {})
                if newtab:
                    tab[v] = newtab
                else:
                    tab.pop(v, None)
                new_ys = new_out_mask[backward][v]
                if new_ys:
                    masks[v] = new_ys
                else:
                    masks.pop(v, None)
        self._needs_full = False
        self._m_apply.inc(1, outcome="incremental")
        self._m_rerun.inc(rerun)
        self._m_replay.inc(replayed)
        for cause, n in causes.items():
            self._m_cause.inc(n, cause=cause)
        self._m_apply_s.observe(stats.wall_time_s)
        return DeltaResult(
            stats=stats,
            phases_total=2 * V,
            phases_rerun=rerun,
            phases_replayed=replayed,
            dirty_out=_rows_of(dirty_rows[True]),
            dirty_in=_rows_of(dirty_rows[False]),
            resort_out=_rows_of(resort[True]),
            resort_in=_rows_of(resort[False]),
            causes=causes)
