"""The ``parallel`` build backend: epoch/merge coordination.

Orchestrates Algorithm 2 as per-worker dispatch rounds over the phase
DAG (:mod:`.dag`) with LPT list scheduling (:mod:`.scheduler`), N
worker engines (:mod:`.worker`) and a sequential validation/merge pass
that makes the result *provably* bit-identical to the sequential
reference. Dispatch is asynchronous and work-conserving: each worker
gets its next batch ("epoch") the moment it goes idle — there is no
global barrier, so per-round stragglers cost only their own worker's
time:

* workers hold the **speculative union** of every broadcast result —
  committed or parked — which PR2 keeps out of earlier phases' read
  sets (a phase only writes at later-ranked vertices), so a phase's
  view of its *own* read set is the sequential prefix whenever its
  true dependencies were broadcast and survive validation unchanged;
* validation walks the positions in sequential order and **commits** a
  parked result only when the worker's view of the phase's read set
  provably equalled the authoritative prefix at that position (entry
  masks + counter deltas are then exactly what the sequential build
  would have produced, since the phase is a pure function of its read
  set). With PR2 on this uses **version-vector validation**: worker
  state is a deterministic replay of the broadcast event log plus the
  worker's own earlier results, so the coordinator knows exactly which
  result-versions the phase saw; the phase is valid unless some
  position whose output the worker missed (or held a since-corrected
  version of) actually *touches* the read scope — adds an entry at the
  hub's vertex, or rewrites a row of a hub listed there. With PR2
  ablated, later-positioned speculation could contaminate earlier read
  sets, so workers instead ship a content fingerprint of the read set
  and the coordinator re-computes it against the authoritative prefix
  (and results are only broadcast once committed);
* on mismatch the phase was run against a stale view: the coordinator
  re-runs it in place on the authoritative state — the re-run *is* the
  sequential execution, so termination and exactness need no retry
  loop — and broadcasts a retract/apply correction. Results for
  positions past a re-run stay parked and are validated later (their
  fingerprints embed whatever they read, so chains built on a
  corrected phase invalidate themselves).

Counters commute (per-phase deltas sum to the build totals — the same
property ``rlc_build_counter_deltas`` relies on), so committing them
per phase in frontier order reproduces ``BuildStats`` exactly.

Dense graphs where the PR1 dependency structure serializes the DAG
(critical-path share of estimated work above ``serial_fallback``) skip
the protocol entirely and run the phases sequentially on one sliced-
mirror engine — same bits, no epoch overhead; ``last_build_info``
records which path ran.

Speedup accounting: this container may have fewer cores than workers,
so ``last_build_info`` reports both the measured wall time *and* the
schedule's achieved-concurrency makespan, computed on a virtual
timeline: each batch completes at its dispatch time plus its measured
phase seconds, an idle worker is re-dispatched at the virtual time of
the collection that freed its work, and the coordinator's validation
seconds accrue on their own (pipelined) timeline; the makespan is the
max over all worker clocks and the coordinator clock. With the inline
executor collections are sequenced in virtual completion order, so the
schedule replays exactly what a concurrent run with those phase
timings would have done. The bench records both (``parallel_speedup``
from the makespan model, ``parallel_wall_speedup`` measured) with the
host's ``cpu_count`` alongside.
"""
from __future__ import annotations

import heapq
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import LabeledGraph
from repro.core.rlc_index import RLCIndex
from repro.build.base import (BuildBackend, BuildStats, access_schedule,
                              mask_vertices, register_backend)
from repro.build.batched import _two_hop_estimate

from .dag import PhaseDAG
from .scheduler import ListScheduler, PhaseCostModel
from .worker import Event, InlineExecutor, LocalEngine, ProcessExecutor

__all__ = ["ParallelBackend"]


def _add_counters(stats: BuildStats, delta: Tuple[int, ...]) -> None:
    for name, d in zip(BuildStats._COUNTERS, delta):
        if d:
            setattr(stats, name, getattr(stats, name) + d)


def _rec(masks: Dict[int, int]) -> Optional[Tuple[Dict[int, int], set]]:
    """One broadcast version of a phase output: its masks plus the set
    of vertices it wrote (so read-scope intersection tests are O(1)
    lookups instead of per-test big-int bit probes). ``None`` for empty
    outputs — every store skips those, so version records compare by
    object identity in the common all-seen case."""
    if not masks:
        return None
    vs: set = set()
    for m in masks.values():
        vs.update(mask_vertices(m))
    return (masks, vs)


class _Group:
    """One (worker, epoch) result batch's validation context.

    ``snap`` is the worker's replayed broadcast state frozen at
    dispatch, ``own`` its plan's nonzero outputs (which override the
    snapshot — the plan ran after the event slice was applied). The
    dirty sets accumulate, per commit the group missed (held version
    ``is not`` committed version), the union of written-vertex sets and
    writer hubs — keyed by which read scope they can contaminate:
    ``dirty_verts[backward]`` holds vertices whose membership map a
    ``backward``-direction phase iterates, ``dirty_hubs[backward]`` the
    hubs whose rows it may read. Phase validation is then two O(1)
    probes instead of a scan over the event log.

    ``ev_mark`` is the broadcast-event count at dispatch: the snapshot
    holds exactly the versions broadcast before it, so a valid commit
    whose apply event has index ``>= ev_mark`` is one this group's view
    missed — the only valid commits that need absorbing (a commit seen
    at dispatch is the identical record object). Stale corrections are
    newer than every live group's mark and always absorb."""

    __slots__ = ("snap", "own", "dirty_verts", "dirty_hubs", "refs",
                 "ev_mark")

    def __init__(self, snap: Dict, own: Dict, refs: int, ev_mark: int):
        self.snap = snap
        self.own = own
        self.dirty_verts = {True: set(), False: set()}
        self.dirty_hubs = {True: set(), False: set()}
        self.refs = refs
        self.ev_mark = ev_mark

    def absorb(self, pos: int, v: int, fin) -> None:
        """Fold the just-committed version of ``pos`` into the dirty
        sets if this group's view held something else."""
        held = self.own.get(pos)
        if held is None:
            held = self.snap.get(pos)
        if held is fin or held == fin:
            return
        fwd = (pos & 1) == 1
        dv = self.dirty_verts[fwd]
        if held:
            dv |= held[1]
        if fin:
            dv |= fin[1]
        self.dirty_hubs[not fwd].add(v)


class ParallelBackend(BuildBackend):
    """Hub-partitioned multi-worker construction (see module docstring).

    ``workers``: engine count (default: the ``RLC_PARALLEL_WORKERS``
    env var, else 4 — the env knob is how CI exercises the protocol at
    a fixed width); ``executor``: ``"process"`` (one OS process per
    worker, fork), ``"inline"`` (deterministic in-process —
    tests/1-core), or ``"auto"`` (process when ``workers > 1``).
    ``hot_prefix``/``locality`` shape the scheduling DAG (see
    :class:`~repro.build.parallel.dag.PhaseDAG`), and ``auto_thin``
    lets the backend swap in a thinner DAG when the default one's
    critical path dominates (:attr:`THIN_AT`); ``serial_fallback`` is
    the critical-path work share above which the build degrades to the
    sequential path. ``mode``/thresholds reach the per-worker
    :class:`~repro.build.batched.PhaseRunner` unchanged.
    """

    name = "parallel"

    #: critical-path work share of the default DAG above which the
    #: schedule is rebuilt with the thin knobs below: a serial chain
    #: costs the whole build every round, while the missed dependencies
    #: a thinner DAG gambles on cost one exact re-run each — measured
    #: on the AD stand-in (share 0.45) thinning roughly halves the
    #: makespan, while the wider EP/TW DAGs (shares <= 0.33) lose to
    #: the stale-re-run storms thinning causes there
    THIN_AT = 0.4
    THIN_HOT = 8
    THIN_LOCALITY = 1

    def __init__(self, use_pr1: bool = True, use_pr2: bool = True,
                 use_pr3: bool = True, workers: Optional[int] = None,
                 executor: str = "auto", mode: str = "hybrid",
                 scalar_threshold: Optional[int] = None,
                 gather_threshold: Optional[int] = None,
                 hot_prefix: int = 16, locality: Optional[int] = None,
                 balance: float = 1.6, serial_fallback: float = 0.92,
                 auto_thin: bool = True):
        super().__init__(use_pr1, use_pr2, use_pr3)
        if executor not in ("auto", "inline", "process"):
            raise ValueError(
                f"executor {executor!r} not in auto|inline|process")
        if workers is None:
            workers = int(os.environ.get("RLC_PARALLEL_WORKERS", "4"))
        self.workers = max(1, int(workers))
        self.executor = executor
        self.mode = mode
        self.scalar_threshold = scalar_threshold
        self.gather_threshold = gather_threshold
        self.hot_prefix = int(hot_prefix)
        self.locality = locality
        self.balance = float(balance)
        self.serial_fallback = float(serial_fallback)
        self.auto_thin = bool(auto_thin)
        #: populated by every build: schedule shape, epoch/stale counts,
        #: makespan decomposition (the bench artifact's source)
        self.last_build_info: Dict = {}

    def _engine_kw(self) -> Dict:
        return dict(use_pr1=self.use_pr1, use_pr2=self.use_pr2,
                    use_pr3=self.use_pr3, mode=self.mode,
                    scalar_threshold=self.scalar_threshold,
                    gather_threshold=self.gather_threshold)

    # ------------------------------------------------------------------ #
    def _build(self, graph: LabeledGraph, k: int, stats: BuildStats
               ) -> RLCIndex:
        order, aid = access_schedule(graph)
        V = graph.num_vertices
        dag = PhaseDAG(graph, k, order, hot_prefix=self.hot_prefix,
                       locality=self.locality)
        est = np.ones(2 * V)
        if V and graph.num_edges:
            bi, bn, _ = graph.bwd
            fi, fn, _ = graph.fwd
            est[0::2] = _two_hop_estimate(bi, bn, graph.in_degree())[order]
            est[1::2] = _two_hop_estimate(fi, fn,
                                          graph.out_degree())[order]
        cm = PhaseCostModel(est)
        dag_stats = dag.stats(cm.costs())
        info = self.last_build_info = dict(
            workers=self.workers, dag=dag_stats)
        serial_frac = dag_stats.get("serial_fraction", 1.0)
        if (self.auto_thin and self.workers > 1
                and self.THIN_AT <= serial_frac < self.serial_fallback):
            thin = PhaseDAG(graph, k, order, hot_prefix=self.THIN_HOT,
                            locality=self.THIN_LOCALITY)
            tstats = thin.stats(cm.costs())
            if tstats.get("serial_fraction", 1.0) < serial_frac:
                dag, dag_stats = thin, tstats
                serial_frac = dag_stats.get("serial_fraction", 1.0)
                info["dag"] = dag_stats
                info["thinned"] = True
        if (self.workers <= 1 or dag_stats["phases"] <= 2
                or serial_frac >= self.serial_fallback):
            info["mode"] = "sequential"
            info["reason"] = (
                "workers<=1" if self.workers <= 1
                else "trivial" if dag_stats["phases"] <= 2
                else f"serial_fraction={serial_frac}")
            return self._sequential(graph, k, stats, order, aid)
        info["mode"] = "parallel"
        return _Coordinator(self, graph, k, stats, order, aid, dag,
                            cm).run()

    # -- degenerate / dense path ---------------------------------------- #
    def _sequential(self, graph: LabeledGraph, k: int, stats: BuildStats,
                    order: np.ndarray, aid: np.ndarray) -> RLCIndex:
        eng = LocalEngine(graph, k, aid, **self._engine_kw())
        obs = self.observer
        for v in order:
            v = int(v)
            for backward in (True, False):
                if not (eng.runner.in_deg[v] if backward
                        else eng.runner.out_deg[v]):
                    continue
                delta, secs = eng.run_phase(v, backward)
                if obs is not None:
                    obs.phase(v, backward, secs, counter_delta=delta)
        eng.mirror.size_bytes()
        index = eng.runner.finish()
        for name in BuildStats._COUNTERS:
            setattr(stats, name, getattr(eng.stats, name))
        stats.peak_mirror_bytes = max(stats.peak_mirror_bytes,
                                      eng.mirror.peak_bytes)
        return index


class _Coordinator:
    """One build's epoch loop: dispatch, validate, commit, account."""

    def __init__(self, backend: ParallelBackend, graph: LabeledGraph,
                 k: int, stats: BuildStats, order: np.ndarray,
                 aid: np.ndarray, dag: PhaseDAG, cm: PhaseCostModel):
        self.backend = backend
        self.graph = graph
        self.k = k
        self.stats = stats
        self.order = order
        self.dag = dag
        self.cm = cm
        self.nw = backend.workers
        #: authoritative prefix state (also the stale re-run engine)
        self.parent = LocalEngine(graph, k, aid, **backend._engine_kw())
        self.sched = ListScheduler(dag, cm, self.nw,
                                   balance=backend.balance)
        self.committed = ~dag.active.copy()   # inactive = trivially done
        self.frontier = 0
        #: broadcast state stream: apply/retract, sliced per worker
        self.events: List[Event] = []
        self.cursors = [0] * self.nw
        #: pos -> (fingerprint, version record, counter delta, seconds,
        #: worker, validation group)
        self.pending: Dict[int, Tuple] = {}
        #: groups with unvalidated results — every commit is folded into
        #: each one's dirty sets (identity-hit no-op for versions the
        #: group's view already held)
        self.live: List[_Group] = []
        #: commits that can contaminate some view, in order (spec mode):
        #: a plan in flight *during* a commit has no group yet to absorb
        #: it — at collection the log suffix since its dispatch is
        #: replayed into the new group, so the dirty sets cover the full
        #: dispatch-to-validation window. Entries are
        #: ``(pos, hub, record, apply-event index)`` (-1: correction,
        #: absorbed unconditionally)
        self.commit_log: List[Tuple] = []
        #: pos -> index of its speculative apply event (absorb filter)
        self.evt_idx: Dict[int, int] = {}
        #: replayed model of each worker's applied state (event log only;
        #: own results ride in the group's own-plan dict)
        self.views: List[Dict] = [{} for _ in range(self.nw)]
        # when to broadcast results to workers: speculatively at collect
        # (PR2 keeps speculation out of earlier read sets), else only
        # once committed; with PR1 off phases are read-free and workers
        # need no entry state at all
        self.broadcast = ("none" if not backend.use_pr1
                          else "spec" if backend.use_pr2 else "commit")
        kind = backend.executor
        if kind == "auto":
            kind = "process" if self.nw > 1 else "inline"
        cls = ProcessExecutor if kind == "process" else InlineExecutor
        self.executor = cls(self.nw, graph, k, aid,
                            **backend._engine_kw())

    def run(self) -> RLCIndex:
        info = self.backend.last_build_info
        obs = self.backend.observer
        rounds = stale_total = 0
        now = 0.0                      # virtual time of last collection
        clock = [0.0] * self.nw        # per-worker last completion
        coord_clock = 0.0              # pipelined validation timeline
        parent_serial = 0.0
        busy_total = [0.0] * self.nw
        peak = 0
        #: wid -> (dispatch vtime, frozen snapshot, plan, commit mark)
        inflight: Dict[int, Tuple] = {}
        inflight_pos: set = set()
        #: eager (inline) completions, popped in virtual time order
        done: List[Tuple[float, int, Tuple]] = []
        try:
            while not self.committed.all():
                # 1) hand every idle worker a fresh plan — no barrier:
                # a straggler never stalls the other workers' batches
                for wid in range(self.nw):
                    if wid in inflight:
                        continue
                    plan = self.sched.plan_for(
                        self.committed, self.pending, inflight_pos,
                        self.frontier)
                    if not plan:
                        break   # stateless in wid: empty for all idle
                    events = self.events[self.cursors[wid]:]
                    self.cursors[wid] = len(self.events)
                    view = self.views[wid]
                    for ev in events:
                        if ev[0] == "apply":
                            view[ev[1]] = ev[4]
                        else:
                            view.pop(ev[1], None)
                    # frozen view at dispatch: what the worker's state
                    # will be when the plan runs (validation may happen
                    # many rounds later, after this view has moved on)
                    inflight[wid] = (now, dict(view), plan,
                                     len(self.commit_log))
                    inflight_pos.update(plan)
                    payload = self.executor.submit(wid, (events, [
                        (p, int(self.order[p >> 1]), p % 2 == 0)
                        for p in plan]))
                    if payload is not None:    # inline: runs eagerly
                        busy = sum(r[4] for r in payload[0])
                        heapq.heappush(done, (now + busy, wid, payload))
                if not inflight:
                    # nothing runnable anywhere: every remaining active
                    # position is parked — drain the frontier to finish
                    before = self.frontier
                    t0 = time.perf_counter()
                    stale_total += self._validate(obs)
                    val_s = time.perf_counter() - t0
                    parent_serial += val_s
                    coord_clock = max(coord_clock, now) + val_s
                    if self.committed.all():
                        break
                    if self.frontier == before:
                        raise RuntimeError(
                            "parallel build made no progress "
                            f"(frontier={self.frontier})")  # unreachable
                    continue
                # 2) collect the next completion: virtual order for the
                # inline executor, arrival order for processes
                if done:
                    comp, wid, payload = heapq.heappop(done)
                else:
                    wid, payload = self.executor.recv_any()
                    comp = inflight[wid][0] + sum(
                        r[4] for r in payload[0])
                now = max(now, comp)
                _, snap, plan, mark = inflight.pop(wid)
                inflight_pos.difference_update(plan)
                res_list, wpeak = payload
                peak = max(peak, wpeak)
                recs = {pos: _rec(masks)
                        for pos, _, masks, _, _ in res_list}
                own = {pos: r for pos, r in recs.items() if r}
                group = _Group(snap, own, len(res_list),
                               self.cursors[wid])
                # commits that landed while this plan was in flight are
                # in neither its snapshot nor (yet) its dirty sets —
                # replay the commit-log suffix before validation can
                # trust the group
                for cpos, cv, crec, ci in self.commit_log[mark:]:
                    if ci < 0 or ci >= group.ev_mark:
                        group.absorb(cpos, cv, crec)
                self.live.append(group)
                busy = 0.0
                for pos, fp, masks, cdelta, secs in res_list:
                    rec = recs[pos]
                    self.pending[pos] = (fp, rec, cdelta, secs, wid,
                                         group)
                    if rec and self.broadcast == "spec":
                        self.evt_idx[pos] = len(self.events)
                        self.events.append(
                            ("apply", pos, int(self.order[pos >> 1]),
                             pos % 2 == 0, rec))
                    busy += secs
                    self.cm.observe(pos, secs)
                busy_total[wid] += busy
                clock[wid] = comp
                rounds += 1
                if rounds % 8 == 0:
                    self.cm.refit()
                # 3) advance the frontier over everything now parked —
                # pipelined: with the process executor this genuinely
                # overlaps the other workers' compute, and the virtual
                # accounting models the same overlap for the inline one
                t0 = time.perf_counter()
                stale = self._validate(obs)
                val_s = time.perf_counter() - t0
                parent_serial += val_s
                coord_clock = max(coord_clock, now) + val_s
                stale_total += stale
                if obs is not None:
                    obs.epoch(busy + val_s, phases=len(res_list),
                              stale_reruns=stale)
        finally:
            self.executor.close()
        self.parent.mirror.size_bytes()
        index = self.parent.runner.finish()
        self.stats.peak_mirror_bytes = max(
            self.stats.peak_mirror_bytes, peak,
            self.parent.mirror.peak_bytes)
        info.update(
            epochs=rounds, stale_reruns=stale_total,
            makespan_s=round(max(max(clock), coord_clock), 6),
            worker_busy_s=[round(b, 6) for b in busy_total],
            parent_serial_s=round(parent_serial, 6),
            executor=self.executor.kind)
        return index

    def _validate(self, obs) -> int:
        """Advance the sequential commit frontier: validate parked
        results in position order, re-running stale ones in place on the
        authoritative prefix. Returns the number of stale re-runs."""
        stale = 0
        parent = self.parent
        while self.frontier < self.dag.npos:
            pos = self.frontier
            if self.committed[pos]:
                self.frontier += 1
                continue
            got = self.pending.pop(pos, None)
            if got is None:
                break                      # not yet executed: next epoch
            v = int(self.order[pos >> 1])
            backward = pos % 2 == 0
            fp, rec, cdelta, secs, wid, group = got
            ok = self._is_valid(v, backward, fp, group)
            if ok:
                parent.apply_output(v, backward, rec[0] if rec else {})
                worker = str(wid)
                if rec and self.broadcast == "commit":
                    self.events.append(
                        ("apply", pos, v, backward, rec))
            else:
                stale += 1
                cdelta, secs = parent.run_phase(v, backward)
                masks = parent.extract_output(v, backward)
                parent.apply_output(v, backward, masks, in_index=True)
                worker = "parent"
                rec = _rec(masks)
                # correct the mis-speculation everywhere
                if self.broadcast != "none":
                    self.events.append(("retract", pos))
                    if rec:
                        self.events.append(
                            ("apply", pos, v, backward, rec))
            # fold the committed version into every live group's dirty
            # sets: a group whose view held a different version (usually
            # "nothing yet" — a same-window cross-worker result) has its
            # later phases' read scopes contaminated at these vertices /
            # hub rows. PR2 bounds every output (even junk speculation —
            # the rank filter is applied at insert, whatever the input
            # state) to vertices ranked above its own hub, so commits at
            # or past a phase's position can never reach its read scope,
            # and commit order == position order makes this exact.
            if group.refs == 1:
                self.live.remove(group)
            else:
                group.refs -= 1
            if self.broadcast == "spec":
                if not ok:
                    # correction: newer than every live group's view
                    self.commit_log.append((pos, v, rec, -1))
                    for g in self.live:
                        g.absorb(pos, v, rec)
                elif rec is not None:
                    # a group missed this exact version only if it was
                    # dispatched before the result's broadcast; everyone
                    # else holds the identical record (empty outputs
                    # were never broadcast and contaminate nothing)
                    i = self.evt_idx[pos]
                    self.commit_log.append((pos, v, rec, i))
                    for g in self.live:
                        if g.ev_mark <= i:
                            g.absorb(pos, v, rec)
            _add_counters(self.stats, cdelta)
            if obs is not None:
                obs.phase(v, backward, secs, counter_delta=cdelta)
                obs.worker_phase(worker, secs)
            self.committed[pos] = True
            self.frontier += 1
        return stale

    def _is_valid(self, v: int, backward: bool, fp: int,
                  group: _Group) -> bool:
        """Did the worker's view of this phase's read set equal the
        authoritative prefix at its position? (All earlier positions are
        committed when the frontier reaches it, and every commit the
        group's view missed is in its dirty sets.) The read scope is the
        entry dict at ``v`` plus the rows of the hubs it lists: backward
        phases read ``l_in[v]`` (written by forward phases) and the
        out-rows of the hubs there (written by those hubs' backward
        phases); forward phases symmetrically."""
        backend = self.backend
        if not backend.use_pr1:
            return True                    # read-free phase
        if not backend.use_pr2:
            # content-fingerprint path (see module docstring)
            return fp == self.parent.fingerprint(v, backward)
        if v in group.dirty_verts[backward]:
            return False
        hubs = group.dirty_hubs[backward]
        if hubs:
            amap = (self.parent.index.l_in if backward
                    else self.parent.index.l_out)[v]
            if not hubs.isdisjoint(amap):
                return False
        return True


register_backend("parallel", ParallelBackend)
