"""Hub-sliced PR1 coverage mirror for the parallel build workers.

:class:`repro.core.rlc_index.BitMirror` allocates the dense
``2 * C * V * ceil(V/8)`` byte cube up front — the memory bound ROADMAP
item 2 names. A build worker only ever touches the rows of hubs it is
assigned plus the hubs those phases' PR1 reads (the entries at the hub
vertex), so :class:`HubSliceMirror` stores per-hub **sparse rows**
(python-int bitmasks, the representation the bits build tier and the
delta engine already speak) and materializes a dense ``(C, W)`` uint8
block per hub only on first access. It quacks exactly like
``BitMirror`` for every read/write the build path performs
(``side[hub]``, ``side[hub, c]``, ``set1``, ``set_many``), so
:class:`repro.build.batched.PhaseRunner` adopts it through its existing
``mirror=`` seam unchanged.

The split between ``rows`` and ``blocks`` is the epoch protocol's
retraction lever: broadcast state (committed prefix plus speculatively
forwarded parked results) lives in ``rows`` (updated only by
:meth:`_SideRows.apply_mask` at epoch boundaries), while a phase's own
in-flight writes land in its hub's ``blocks`` entry. A hub's write-side
row has exactly one writer — its own phase — so retracting a
mis-speculated result is an exact full-row wipe
(:meth:`_SideRows.clear_row`).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.rlc_index import _BIT

__all__ = ["HubSliceMirror"]


class _SideRows:
    """One direction of the sliced mirror (the ``out`` / ``in_`` twin)."""

    __slots__ = ("C", "W", "rows", "blocks", "_row_bytes")

    def __init__(self, num_mrs: int, words: int):
        self.C = num_mrs
        self.W = words
        #: committed prefix rows: hub -> {mr id -> packed int mask}
        self.rows: Dict[int, Dict[int, int]] = {}
        #: dense per-hub row blocks, materialized on first access
        self.blocks: Dict[int, np.ndarray] = {}
        #: running byte tally of ``rows`` (footprint reads are per
        #: worker epoch — a full walk there is quadratic over the build)
        self._row_bytes = 0

    def _materialize(self, hub: int) -> np.ndarray:
        blk = self.blocks.get(hub)
        if blk is None:
            blk = np.zeros((self.C, self.W), np.uint8)
            for c, m in self.rows.get(hub, {}).items():
                blk[c] = np.frombuffer(m.to_bytes(self.W, "little"),
                                       np.uint8)
            self.blocks[hub] = blk
        return blk

    # BitMirror-shaped indexing: side[hub] -> (C, W), side[hub, c] -> (W,)
    def __getitem__(self, key):
        if isinstance(key, tuple):
            hub, c = key
            blk = self.blocks.get(hub)   # np ints hash like python ints
            if blk is None:
                blk = self._materialize(int(hub))
            return blk[c]
        blk = self.blocks.get(key)
        if blk is None:
            blk = self._materialize(int(key))
        return blk

    # -- protocol extras (not part of the BitMirror surface) ----------- #
    def row_int(self, hub: int, c: int) -> int:
        """Current row content as a packed int (block view when dense,
        else the committed prefix row) — the fingerprint read path."""
        blk = self.blocks.get(hub)
        if blk is not None:
            return int.from_bytes(blk[c].tobytes(), "little")
        return self.rows.get(hub, {}).get(c, 0)

    def apply_mask(self, hub: int, c: int, mask: int) -> None:
        """Commit new entry bits into the prefix rows (and the dense
        block, when one is live) — the epoch-boundary delta apply."""
        d = self.rows.setdefault(hub, {})
        old = d.get(c, 0)
        d[c] = new = old | mask
        self._row_bytes += ((new.bit_length() + 7) // 8 + 16 if not old
                            else (new.bit_length() + 7) // 8
                            - (old.bit_length() + 7) // 8)
        blk = self.blocks.get(hub)
        if blk is not None:
            blk[c] |= np.frombuffer(mask.to_bytes(self.W, "little"),
                                    np.uint8)

    def masks(self, hub: int) -> Dict[int, int]:
        """Nonzero rows of the hub's dense block as packed ints — the
        phase-output extraction (the write-side hub block holds exactly
        the phase's inserts, because the prefix rows of an uncommitted
        hub are empty)."""
        blk = self.blocks.get(hub)
        if blk is None:
            return {}
        out: Dict[int, int] = {}
        for c in np.nonzero(blk.any(axis=1))[0].tolist():
            out[c] = int.from_bytes(blk[c].tobytes(), "little")
        return out

    def drop(self, hub: int) -> None:
        """Forget the hub's dense block (revert of uncommitted writes)."""
        self.blocks.pop(hub, None)

    def clear_row(self, hub: int) -> None:
        """Wipe the hub's row entirely — block *and* broadcast rows.
        Exact because a hub's write-side row has a single writer (its
        own phase), so the row content is that one phase's output."""
        self.blocks.pop(hub, None)
        d = self.rows.pop(hub, None)
        if d:
            self._row_bytes -= sum((m.bit_length() + 7) // 8 + 16
                                   for m in d.values())

    def bytes_now(self) -> int:
        return len(self.blocks) * self.C * self.W + self._row_bytes


class HubSliceMirror:
    """Drop-in ``BitMirror`` replacement holding only touched hub rows.

    ``out[x, c]`` / ``in_[x, c]`` have the same meaning as on
    ``BitMirror``; allocation is proportional to the hubs actually read
    or written instead of ``V``. :meth:`size_bytes` reports the current
    footprint and tracks the high-water mark in :attr:`peak_bytes`.
    """

    def __init__(self, num_mrs: int, num_vertices: int):
        self.num_vertices = num_vertices
        self.words = (num_vertices + 7) // 8
        self.out = _SideRows(num_mrs, self.words)
        self.in_ = _SideRows(num_mrs, self.words)
        self.peak_bytes = 0

    # -- BitMirror write surface ---------------------------------------- #
    def set1(self, side: _SideRows, c: int, hub: int, y: int) -> None:
        side._materialize(hub)[c, y >> 3] |= _BIT[y & 7]

    def set_many(self, side: _SideRows, c: int, hub: int, ys) -> None:
        row = side._materialize(hub)[c]
        if len(ys) <= 16:
            for y in ys:
                row[y >> 3] |= _BIT[y & 7]
            return
        dense = np.zeros(self.num_vertices, np.uint8)
        dense[np.asarray(ys)] = 1
        row |= np.packbits(dense, bitorder="little")[:self.words]

    def nbytes(self) -> int:
        return self.out.bytes_now() + self.in_.bytes_now()

    def size_bytes(self) -> int:
        """Current footprint (also bumps :attr:`peak_bytes`)."""
        cur = self.nbytes()
        if cur > self.peak_bytes:
            self.peak_bytes = cur
        return cur
