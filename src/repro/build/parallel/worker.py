"""Per-worker build engine + the epoch executors (inline / process).

A :class:`LocalEngine` is one process's view of the build: a dict
:class:`~repro.core.rlc_index.RLCIndex`, a
:class:`~repro.build.parallel.mirror.HubSliceMirror` for the PR1 rows,
and a :class:`~repro.build.batched.PhaseRunner` so every phase executes
*exactly* the hybrid scalar/bits/vector path a sequential batched build
would have used. The coordinator keeps one holding only the
authoritative committed prefix (for fingerprint validation and exact
stale re-runs); each worker's holds the **speculative union** — every
result the coordinator has broadcast, validated or not.

Speculative forwarding is what keeps the stale-re-run rate at the
missed-DAG-edge level instead of the commit-frontier-lag level: a
parked result is shipped to workers the epoch after it runs, so
dependents dispatched later read real (if unvalidated) content. PR2
makes this safe to apply eagerly — a phase only ever writes entries at
*later-ranked* vertices than its hub, so a result from sequential
position ``q`` can never appear in the read set of a phase at position
``p < q``; an earlier phase's view is never contaminated by speculation
from ahead of it. (With PR2 ablated the contamination is possible and
simply shows up as extra stale re-runs — never wrong bits, since
commits still require a fingerprint match against the authoritative
prefix.)

Worker epoch cycle:

1. apply the coordinator's event-log slice — ``apply`` events add a
   result's entry masks (idempotent re-delivery of its own results is
   skipped by mask equality), ``retract`` events wipe a mis-speculated
   result (exact: a hub's write-side row has only one writer);
2. run the assigned phases in position order, fingerprinting each
   phase's PR1 read set *before* running it (entries at the hub vertex
   + exact row contents of the hubs they name — row *content*, not
   counts: a predecessor that later turns out stale can leave
   equal-cardinality, different-bit rows);
3. ship ``(position, fingerprint, output masks, counter deltas, wall
   time)`` per phase; its own writes stay in place as speculation.

Within an epoch a worker's later phases see its earlier phases' writes
(local chaining); the fingerprints embed exactly what was seen, so the
coordinator's in-order validation catches any chain built on a phase
that had to be re-run.
"""
from __future__ import annotations

import time
import traceback
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import LabeledGraph
from repro.core.minimum_repeat import mr_id_space
from repro.core.rlc_index import RLCIndex
from repro.build.base import BuildStats, mask_vertices
from repro.build.batched import PhaseRunner
from repro.build.numpy_backend import NumpyBackend

from .mirror import HubSliceMirror

__all__ = ["LocalEngine", "BuildWorker", "InlineExecutor",
           "ProcessExecutor", "PhaseResult"]

#: one executed phase: (position, fingerprint, {mr id: new-entry mask},
#: BuildStats counter delta, wall seconds)
PhaseResult = Tuple[int, int, Dict[int, int], Tuple[int, ...], float]


class LocalEngine:
    """Prefix-state index + sliced mirror + the shared phase executor."""

    def __init__(self, graph: LabeledGraph, k: int, aid: np.ndarray,
                 use_pr1: bool = True, use_pr2: bool = True,
                 use_pr3: bool = True, mode: str = "hybrid",
                 scalar_threshold: Optional[int] = None,
                 gather_threshold: Optional[int] = None):
        self.graph = graph
        self.k = int(k)
        mr_ids = mr_id_space(graph.num_labels, k) if graph.num_labels \
            else {}
        self.index = RLCIndex(graph.num_vertices, k,
                              np.asarray(aid, dtype=np.int64))
        self.stats = BuildStats()
        self.mirror = HubSliceMirror(len(mr_ids), graph.num_vertices)
        # the sliced mirror is allocation-proportional, so the dense
        # budget guard must never push phases off the batched tiers
        self._backend = NumpyBackend(
            use_pr1=use_pr1, use_pr2=use_pr2, use_pr3=use_pr3, mode=mode,
            scalar_threshold=scalar_threshold,
            gather_threshold=gather_threshold, mirror_budget=1 << 62)
        self.runner = PhaseRunner(self._backend, graph, k, self.index,
                                  self.stats, mirror=self.mirror)
        if not self.runner.adopted_mirror:
            # scalar mode skips the batch setup; attach the mirror anyway
            # so inserts keep it in sync (output extraction reads it)
            self.index._mirror = self.mirror
            self.index._mr_ids = dict(mr_ids)
        self.mrs_by_c = [mr for mr, _ in
                         sorted(mr_ids.items(), key=lambda kv: kv[1])]
        self.use_pr1 = use_pr1
        self.use_pr2 = use_pr2

    # -- phase execution ------------------------------------------------ #
    def run_phase(self, v: int, backward: bool
                  ) -> Tuple[Tuple[int, ...], float]:
        """Run one phase; returns (counter delta, wall seconds)."""
        before = self.stats.counters()
        t0 = time.perf_counter()
        self.runner.run(v, backward)
        dt = time.perf_counter() - t0
        return tuple(a - b for a, b in
                     zip(self.stats.counters(), before)), dt

    def extract_output(self, v: int, backward: bool) -> Dict[int, int]:
        """The phase's new entries as ``{mr id: vertex mask}`` — the
        write-side hub block *is* the output (uncommitted hubs have
        empty prefix rows)."""
        side = self.mirror.out if backward else self.mirror.in_
        return side.masks(v)

    def fingerprint(self, v: int, backward: bool) -> int:
        """Digest of everything PR1 can read during phase ``(v, dir)``:
        the entry items at ``v`` plus the exact packed rows of the hubs
        they name (row *content*, not counts — a chained predecessor
        that later turns out stale can leave equal-cardinality,
        different-bit rows). A commutative sum of per-item tuple hashes:
        order-independent without sorting, deterministic across forked
        workers (int/tuple hashing is unseeded), and far cheaper than a
        cryptographic digest — this runs once per phase on every worker
        *and* once per phase inside the coordinator's serial merge.
        Zero with PR1 off — the phase is then read-free and can never
        be stale."""
        if not self.use_pr1:
            return 0
        row = self.index.l_in[v] if backward else self.index.l_out[v]
        side = self.mirror.out if backward else self.mirror.in_
        mr_ids = self.index._mr_ids
        acc = 0
        for x, mrs in row.items():
            for mr in mrs:
                c = mr_ids[mr]
                acc = (acc + hash((x, c, side.row_int(x, c)))) \
                    & 0xFFFFFFFFFFFFFFFF
        return acc

    # -- state mutation -------------------------------------------------- #
    def apply_output(self, v: int, backward: bool,
                     masks: Dict[int, int], in_index: bool = False
                     ) -> None:
        """Add a phase's output entries to the local state. ``in_index``
        skips the dict insert (the coordinator re-running a phase on its
        own index already holds the entries — only the rows lag)."""
        side = self.mirror.out if backward else self.mirror.in_
        maps = self.index.l_out if backward else self.index.l_in
        for c, mask in masks.items():
            side.apply_mask(v, c, mask)
            if not in_index:
                mr = self.mrs_by_c[c]
                for y in mask_vertices(mask):
                    maps[y].setdefault(v, set()).add(mr)

    def retract_output(self, v: int, backward: bool,
                       masks: Dict[int, int]) -> None:
        """Remove a phase's output (own writes or mis-speculated
        broadcast — exact either way: the hub's write-side row has no
        other writer)."""
        side = self.mirror.out if backward else self.mirror.in_
        side.clear_row(v)
        maps = self.index.l_out if backward else self.index.l_in
        mr_by_c = self.mrs_by_c
        for c, mask in masks.items():
            mr = mr_by_c[c]
            for y in mask_vertices(mask):
                s = maps[y].get(v)
                if s is not None:
                    s.discard(mr)
                    if not s:
                        del maps[y][v]


#: coordinator -> worker state event:
#: ("apply", pos, hub, backward, ({mr id: mask}, written-vertex set))
#: | ("retract", pos)
Event = Tuple


class BuildWorker:
    """One worker's epoch loop over a :class:`LocalEngine`."""

    def __init__(self, graph: LabeledGraph, k: int, aid: np.ndarray,
                 **engine_kw):
        self.engine = LocalEngine(graph, k, aid, **engine_kw)
        #: results currently applied locally: pos -> (hub, backward,
        #: masks). Own runs land here too, so re-delivery of an
        #: unchanged own result is a no-op and a corrected one retracts
        #: cleanly.
        self.applied: Dict[int, Tuple[int, bool, Dict[int, int]]] = {}

    def run_epoch(self, events: List[Event],
                  phases: List[Tuple[int, int, bool]]
                  ) -> Tuple[List[PhaseResult], int]:
        """Apply the coordinator's event-log slice, then run
        ``(pos, hub, backward)`` phases in order; returns (results, peak
        mirror bytes). Writes stay in place as speculation."""
        eng = self.engine
        for ev in events:
            if ev[0] == "apply":
                _, pos, v, backward, rec = ev
                masks = rec[0]      # (masks, written-vertex set) record
                held = self.applied.get(pos)
                if held is not None:
                    if held[2] == masks:
                        continue
                    eng.retract_output(*held)
                eng.apply_output(v, backward, masks)
                self.applied[pos] = (v, backward, masks)
            else:   # ("retract", pos)
                held = self.applied.pop(ev[1], None)
                if held is not None:
                    eng.retract_output(*held)
        # content fingerprints back the PR2-ablated validation path; with
        # PR2 on the coordinator validates from its event-log replay of
        # this worker's state instead, and the digest work is skipped
        need_fp = eng.use_pr1 and not eng.use_pr2
        results: List[PhaseResult] = []
        for pos, v, backward in phases:
            fp = eng.fingerprint(v, backward) if need_fp else 0
            counter_delta, secs = eng.run_phase(v, backward)
            masks = eng.extract_output(v, backward)
            self.applied[pos] = (v, backward, masks)
            results.append((pos, fp, masks, counter_delta, secs))
        return results, eng.mirror.size_bytes()


class InlineExecutor:
    """Deterministic in-process executor (tests, 1-core fallbacks).

    ``submit`` runs the batch immediately and returns its payload — the
    coordinator then sequences collections in *virtual* completion
    order (dispatch time + measured busy seconds), so the scheduling
    decisions replay what a truly concurrent run with these phase
    timings would have made. ``recv_any`` is never called on this
    executor."""

    kind = "inline"

    def __init__(self, workers: int, graph: LabeledGraph, k: int,
                 aid: np.ndarray, **engine_kw):
        self._workers = [BuildWorker(graph, k, aid, **engine_kw)
                         for _ in range(workers)]

    def submit(self, wid: int,
               job: Tuple[List[Event], List[Tuple[int, int, bool]]]
               ) -> Tuple[List[PhaseResult], int]:
        return self._workers[wid].run_epoch(*job)

    def recv_any(self):  # pragma: no cover - inline submits are eager
        raise RuntimeError("InlineExecutor completes jobs at submit")

    def close(self) -> None:
        pass


def _worker_main(conn, graph, k, aid, engine_kw):  # pragma: no cover
    # (child process body; exercised via ProcessExecutor tests)
    worker = BuildWorker(graph, k, aid, **engine_kw)
    while True:
        msg = conn.recv()
        if msg is None:
            conn.close()
            return
        try:
            conn.send(("ok", worker.run_epoch(*msg)))
        except Exception:
            conn.send(("err", traceback.format_exc()))


class ProcessExecutor:
    """One OS process per worker, pipe-speaking the batch protocol.
    ``submit`` returns as soon as the job is on the pipe; ``recv_any``
    blocks for whichever in-flight worker finishes first, so the
    coordinator re-dispatches each worker the moment it goes idle and
    its validation/merge pass genuinely overlaps worker compute."""

    kind = "process"

    def __init__(self, workers: int, graph: LabeledGraph, k: int,
                 aid: np.ndarray, **engine_kw):
        import multiprocessing as mp
        import os
        # fork is the only start method that works for arbitrary
        # (un-import-guarded) caller scripts — spawn/forkserver re-import
        # __main__ in the child. It does mean forking a parent whose jax
        # runtime has live threads (the service path builds after jax is
        # up), which CPython warns about; the workers themselves are
        # jax-free and the pipes are the only shared state. Deployments
        # that hit the fork-vs-threads hazard can set
        # RLC_PARALLEL_MP_CONTEXT=forkserver (their entrypoints are
        # import-guarded) — workers then fork from a clean helper.
        method = os.environ.get("RLC_PARALLEL_MP_CONTEXT", "fork")
        try:
            ctx = mp.get_context(method)
        except ValueError:  # pragma: no cover - non-fork platforms
            ctx = mp.get_context()
        self._conns = []
        self._procs = []
        self._inflight: set = set()
        for _ in range(workers):
            parent, child = ctx.Pipe()
            p = ctx.Process(target=_worker_main,
                            args=(child, graph, k, aid, engine_kw),
                            daemon=True)
            p.start()
            child.close()
            self._conns.append(parent)
            self._procs.append(p)

    def submit(self, wid: int, job) -> None:
        self._conns[wid].send(job)
        self._inflight.add(wid)

    def recv_any(self) -> Tuple[int, Tuple[List[PhaseResult], int]]:
        from multiprocessing.connection import wait
        conn = wait([self._conns[w] for w in self._inflight])[0]
        wid = self._conns.index(conn)
        self._inflight.discard(wid)
        status, payload = conn.recv()
        if status != "ok":
            self.close()
            raise RuntimeError(
                f"parallel build worker {wid} failed:\n{payload}")
        return wid, payload

    def close(self) -> None:
        for conn, p in zip(self._conns, self._procs):
            try:
                conn.send(None)
                conn.close()
            except OSError:
                pass
        for p in self._procs:
            p.join(timeout=5)
            if p.is_alive():  # pragma: no cover
                p.terminate()
