"""Phase dependency DAG over Algorithm 2's ``(hub, direction)`` phases.

Algorithm 2 runs ``2V`` phases in a fixed total order (IN-OUT access
order, backward before forward per hub — the "line 36" constraint
documented in ``build/README.md``). The only *true* cross-phase data
flow runs through the entries at the hub's own vertex: phase
``(v, bwd)`` reads ``L_in(v)`` items plus the out-side mirror rows of
the hubs appearing in them, ``(v, fwd)`` symmetrically via ``L_out(v)``
— everything else a phase touches is static graph structure. A hub
``x`` can only have written at ``v`` if ``v`` is reachable from ``x``
(forward writes) or reaches ``x`` (backward writes), so most phase
pairs on real graphs are independent and the true DAG is far wider
than the sequential chain.

The exact write set is unknowable before building (PR1/PR3 prune most
candidate entries), so this DAG is a *scheduling heuristic*, not a
correctness device — the epoch/merge protocol in
:mod:`repro.build.parallel.backend` validates every phase's actual
read fingerprint and re-runs conflicts exactly. Edges come from three
over-approximations of "x may write at v" (for ``rank(x) < rank(v)``):

* **intra-hub**: ``(v, bwd) -> (v, fwd)`` always (fwd reads L_out(v),
  which bwd writes);
* **hot prefix**: for the first ``hot_prefix`` hubs in access order —
  the ones whose entries blanket the graph — the *single-label*
  reachability cone: a phase's only writes beyond its ``k``-hop ball
  come from kernel-BFS walks, and the long-range mass of those is the
  ``m = 1`` kernels (paths spelling ``a^j``), whose write set is
  exactly the per-label closure. ``v`` in any label closure of ``x``
  adds ``(x, *) -> (v, bwd)`` edges; symmetric backward closures add
  ``(x, *) -> (v, fwd)``. (Full reachability would chain nearly every
  phase behind every hot hub on a connected graph — measured on the
  bench stand-ins it pushes the critical-path share past 0.4 for no
  stale-re-run savings.)
* **locality**: kernel-search writes land within ``k`` hops of the
  hub, so ``x`` within ``locality`` (default ``k``) backward hops of
  ``v`` adds ``(x, *) -> (v, bwd)``, within forward hops
  ``(x, *) -> (v, fwd)``.

Multi-label cyclic kernels (``m >= 2``) beyond the ball are the one
write family deliberately left out — they are rare, and a missed edge
costs one exact re-run, not correctness.

Beyond these the scheduler is optimistic: a long-range kernel-BFS
write from a cold hub surfaces as a stale fingerprint and an exact
re-run, never as a wrong bit.

Positions: phase ``(order[r], bwd)`` is node ``2r``, ``(order[r],
fwd)`` is ``2r + 1`` — ascending position *is* the sequential total
order, so every edge points forward and one ascending pass computes
levels.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import LabeledGraph

__all__ = ["PhaseDAG"]

#: above this vertex count the packed-int reachability/ball passes are
#: skipped (hot + locality edges off; the protocol still re-runs any
#: conflict exactly, the schedule is just more optimistic).
_EDGE_ANALYSIS_MAX_V = 20_000


def _adj_bits(graph: LabeledGraph
              ) -> Tuple[List[int], List[int], List[List[int]],
                         List[List[int]]]:
    """Packed adjacency: label-blind ``fwd[v]`` / ``bwd[v]`` neighbor
    bitsets plus the per-label views (one shifted-OR per edge)."""
    V, L = graph.num_vertices, graph.num_labels
    fwd = [0] * V
    bwd = [0] * V
    fwd_l = [[0] * V for _ in range(L)]
    bwd_l = [[0] * V for _ in range(L)]
    for s, lab, d in graph.edges.tolist():
        db, sb = 1 << d, 1 << s
        fwd[s] |= db
        bwd[d] |= sb
        fwd_l[lab][s] |= db
        bwd_l[lab][d] |= sb
    return fwd, bwd, fwd_l, bwd_l


def _closure(src: int, adj: List[int]) -> int:
    """Packed-int BFS closure from ``src`` (excluding ``src`` unless on
    a cycle)."""
    vis = 0
    fr = adj[src]
    while fr:
        vis |= fr
        nxt = 0
        while fr:
            b = fr & -fr
            fr ^= b
            nxt |= adj[b.bit_length() - 1]
        fr = nxt & ~vis
    return vis


def _ball(src: int, adj: List[int], hops: int) -> int:
    """Vertices within ``hops`` steps of ``src`` along ``adj``."""
    vis = 0
    fr = adj[src]
    for _ in range(hops):
        if not fr:
            break
        vis |= fr
        nxt = 0
        while fr:
            b = fr & -fr
            fr ^= b
            nxt |= adj[b.bit_length() - 1]
        fr = nxt & ~vis
    return vis


class PhaseDAG:
    """Dependency DAG + static stats over the ``2V`` phase positions."""

    def __init__(self, graph: LabeledGraph, k: int, order: np.ndarray,
                 hot_prefix: int = 16, locality: int | None = None):
        V = graph.num_vertices
        self.npos = 2 * V
        self.order = np.asarray(order, dtype=np.int64)
        self.rank = np.empty(V, dtype=np.int64)
        self.rank[self.order] = np.arange(V)
        out_deg, in_deg = graph.out_degree(), graph.in_degree()
        self.active = np.zeros(self.npos, dtype=bool)
        self.active[0::2] = in_deg[self.order] > 0    # (v, bwd)
        self.active[1::2] = out_deg[self.order] > 0   # (v, fwd)
        preds: List[set] = [set() for _ in range(self.npos)]
        for r in range(V):
            if self.active[2 * r] and self.active[2 * r + 1]:
                preds[2 * r + 1].add(2 * r)
        hops = int(k if locality is None else locality)
        if V and V <= _EDGE_ANALYSIS_MAX_V and graph.num_edges:
            fwd, bwd, fwd_l, bwd_l = _adj_bits(graph)
            self._hot_edges(preds, fwd_l, bwd_l, min(int(hot_prefix), V))
            if hops > 0:
                self._local_edges(preds, fwd, bwd, hops)
        self.preds: List[Tuple[int, ...]] = [
            tuple(sorted(p)) for p in preds]
        self.num_edges = sum(len(p) for p in self.preds)

    # -- edge passes ---------------------------------------------------- #
    def _add_hub_edges(self, preds: List[set], i: int, pos: int) -> None:
        """Both phases of the rank-``i`` hub become preds of ``pos``."""
        if self.active[2 * i]:
            preds[pos].add(2 * i)
        if self.active[2 * i + 1]:
            preds[pos].add(2 * i + 1)

    def _hot_edges(self, preds, fwd_l, bwd_l, hot: int) -> None:
        for i in range(hot):
            x = int(self.order[i])
            if not (self.active[2 * i] or self.active[2 * i + 1]):
                continue
            reach = coreach = 0
            for adj_f, adj_b in zip(fwd_l, bwd_l):
                reach |= _closure(x, adj_f)
                coreach |= _closure(x, adj_b)
            for j in range(i + 1, len(self.order)):
                v = int(self.order[j])
                vb = 1 << v
                if reach & vb and self.active[2 * j]:
                    self._add_hub_edges(preds, i, 2 * j)
                if coreach & vb and self.active[2 * j + 1]:
                    self._add_hub_edges(preds, i, 2 * j + 1)

    def _local_edges(self, preds, fwd, bwd, hops: int) -> None:
        rank = self.rank
        for j in range(len(self.order)):
            v = int(self.order[j])
            for pos, ball in ((2 * j, _ball(v, bwd, hops)),
                              (2 * j + 1, _ball(v, fwd, hops))):
                if not self.active[pos]:
                    continue
                f = ball
                while f:
                    b = f & -f
                    f ^= b
                    i = int(rank[b.bit_length() - 1])
                    if i < j:
                        self._add_hub_edges(preds, i, pos)

    # -- static structure stats ----------------------------------------- #
    def levels(self) -> np.ndarray:
        """ASAP level per position (0 for inactive); one ascending pass
        (edges always point to higher positions)."""
        lv = np.zeros(self.npos, dtype=np.int64)
        for p in range(self.npos):
            if not self.active[p]:
                continue
            lv[p] = 1 + max((lv[q] for q in self.preds[p]), default=0)
        return lv

    def stats(self, cost: np.ndarray | None = None) -> Dict:
        """Width/depth + (when per-position ``cost`` estimates are
        given) the critical-path share of total work — the sequential-
        fallback signal and the bench's DAG-width artifact fields."""
        lv = self.levels()
        act = lv[self.active]
        depth = int(act.max()) if act.size else 0
        widths = (np.bincount(act, minlength=depth + 1)[1:]
                  if depth else np.zeros(0, np.int64))
        out = dict(
            phases=int(self.active.sum()), edges=self.num_edges,
            depth=depth,
            max_width=int(widths.max()) if widths.size else 0,
            mean_width=round(float(widths.mean()), 2) if widths.size
            else 0.0)
        if cost is not None:
            cpl = np.zeros(self.npos)
            for p in range(self.npos):
                if self.active[p]:
                    cpl[p] = cost[p] + max(
                        (cpl[q] for q in self.preds[p]), default=0.0)
            total = float(cost[self.active].sum())
            out["serial_fraction"] = round(
                float(cpl.max()) / total, 4) if total > 0 else 1.0
        return out
