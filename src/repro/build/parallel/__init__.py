"""Parallel hub-partitioned index construction (ROADMAP item 2).

Partitions Algorithm 2's ``(hub, direction)`` phases across N worker
engines, scheduled over a dependency DAG instead of the fixed access
order, with an epoch/merge protocol that keeps the result bit-identical
(entries *and* pruning counters) to the sequential reference. See
``build/README.md`` ("Parallel construction") and the module docstrings:

- :mod:`.dag` — which phases are actually independent;
- :mod:`.scheduler` — cost-modeled, frontier-windowed list scheduling
  of per-worker batches (no global epoch barrier);
- :mod:`.worker` — prefix-snapshot engines + inline/process executors;
- :mod:`.mirror` — hub-sliced ``BitMirror`` replacement (the memory
  bound lifter);
- :mod:`.backend` — the coordinator and the registered ``parallel``
  backend.
"""
from .backend import ParallelBackend
from .dag import PhaseDAG
from .mirror import HubSliceMirror
from .scheduler import ListScheduler, PhaseCostModel
from .worker import BuildWorker, LocalEngine

__all__ = ["BuildWorker", "HubSliceMirror", "ListScheduler",
           "LocalEngine", "ParallelBackend", "PhaseCostModel", "PhaseDAG"]
