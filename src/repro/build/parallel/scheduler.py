"""Cost model + list scheduler for the parallel build dispatch rounds.

Replaces Algorithm 2's fixed access-order loop. The coordinator asks
for one worker's plan at a time (:meth:`ListScheduler.plan_for`) the
moment that worker goes idle — there is no global epoch barrier, so a
straggling phase on one worker never stalls the other workers' next
batches. A position is *dispatchable* when every predecessor is

* **committed** or **parked** (executed earlier, result awaiting the
  validation frontier) — parked outputs are not yet guaranteed
  correct, so this is the protocol's optimism: if the dependency was
  real and the parked result turns out stale, the phase's validation
  catches it and the coordinator re-runs it exactly. Requiring
  *committed* predecessors instead couples DAG levels to the
  sequential commit frontier and inflates round counts far past the
  DAG depth;
* or assigned **earlier in this same plan** — the worker runs its plan
  in position order, so the chain's writes are locally visible and the
  phase reads exactly what the sequential build would have produced
  (if the chain head was right). Chains across two in-flight plans
  wait instead; unbounded cross-worker chaining is what degenerates
  into one worker owning the whole build.

Positions currently in flight on *other* workers are neither
dispatchable nor dependency-satisfying (their results are not back
yet), so plans never overlap and never chain across workers.

Each plan takes free (no-chain-needed) phases in ascending position
order up to ``balance`` times the worker's fair share of the free
set's cost — the free set is an antichain, so whatever this worker
leaves is immediately dispatchable to the next idle worker — then
extends chains rooted in the plan up to the same budget (with a small
floor of :attr:`ListScheduler.CHAIN_MIN` phases so serial chain
regions don't degenerate into one-phase round trips). Dispatch is
bounded to :attr:`ListScheduler.WINDOW` positions past the validation
frontier so the coordinator's merge cost stays flat (see the
attribute's note).

Costs start from the same two-hop state proxy the hybrid tier dispatch
uses (``PhaseRunner._est``); as phases complete,
:meth:`PhaseCostModel.observe` collects measured wall times and
:meth:`PhaseCostModel.refit` re-derives the seconds-per-state
coefficient (median ratio — robust to the handful of scalar-tier
outliers), so later rounds balance on real per-(hub, direction)
timings, exactly the signal PR 6's ``build_obs`` series record.

The earliest active position that is neither executed nor in flight
always has all predecessors executed (the validation frontier commits
in position order), so it is always free: whenever work remains and
nothing is in flight, a nonempty plan exists and the build progresses.
"""
from __future__ import annotations

from typing import Iterable, List, Set, Tuple

import numpy as np

from .dag import PhaseDAG

__all__ = ["PhaseCostModel", "ListScheduler"]


class PhaseCostModel:
    """Per-position wall-time estimates, refit from measurements."""

    #: starting seconds-per-two-hop-state (order of magnitude only; the
    #: first refit replaces it)
    INIT_COEF = 2e-6
    BASE_S = 5e-5

    def __init__(self, est: np.ndarray):
        self.est = np.maximum(np.asarray(est, dtype=np.float64), 1.0)
        self.coef = self.INIT_COEF
        self._samples: List[Tuple[float, float]] = []

    def cost(self, pos: int) -> float:
        return self.BASE_S + self.coef * float(self.est[pos])

    def costs(self) -> np.ndarray:
        return self.BASE_S + self.coef * self.est

    def observe(self, pos: int, seconds: float) -> None:
        self._samples.append((float(self.est[pos]), float(seconds)))

    def refit(self) -> float:
        """Median measured seconds-per-state over everything observed so
        far; returns the (possibly unchanged) coefficient."""
        if self._samples:
            ratios = sorted(s / e for e, s in self._samples)
            self.coef = max(ratios[len(ratios) // 2], 1e-9)
        return self.coef


class ListScheduler:
    """Per-worker plans: windowed, budgeted antichain slices + chains."""

    #: minimum chain extension depth per plan — in serial chain regions
    #: the cost budget is near zero and would hand out one phase per
    #: round trip; a short fixed allowance amortizes dispatch overhead
    #: without letting a chain hoard parallel work
    CHAIN_MIN = 4
    #: dispatch horizon past the validation frontier, in positions.
    #: Unbounded run-ahead piles up parked results whose views miss
    #: every commit in between, and the coordinator's per-commit
    #: dirty-set fan-out grows with that lag — the window keeps the
    #: parked population (and so the merge cost) O(1) while still
    #: holding many plans' worth of dispatchable work
    WINDOW = 128

    def __init__(self, dag: PhaseDAG, cost_model: PhaseCostModel,
                 workers: int, balance: float = 1.6):
        self.dag = dag
        self.cost = cost_model
        self.workers = max(1, int(workers))
        self.balance = float(balance)
        # incremental readiness: per position, the predecessors never yet
        # executed (executed = committed or parked — monotone, so edges
        # are retired exactly once over the build instead of the whole
        # pred list being rescanned every round)
        self._succs: List[List[int]] = [[] for _ in range(dag.npos)]
        for p, ps in enumerate(dag.preds):
            for q in ps:
                self._succs[q].append(p)
        self._unexec: List[set] = [set(ps) for ps in dag.preds]
        self._exec_mask = np.zeros(dag.npos, dtype=bool)

    def plan_for(self, committed: np.ndarray, pending: Iterable[int],
                 inflight: Set[int], frontier: int = 0) -> List[int]:
        """One idle worker's next batch (ascending — its local execution
        order); empty when nothing is dispatchable. ``committed`` marks
        validated positions (inactive ones pre-marked), ``pending``
        positions have a parked un-validated result (not re-dispatched,
        but dependency-satisfying — see the module docstring), and
        ``inflight`` positions are on some worker's in-flight plan
        (neither). Only positions within :attr:`WINDOW` of ``frontier``
        (the coordinator's commit frontier) are considered."""
        dag, nw = self.dag, self.workers
        npos = dag.npos
        pend_mask = np.zeros(npos, dtype=bool)
        pend_list = list(pending)
        if pend_list:
            pend_mask[pend_list] = True
        # retire dependency edges of everything newly executed
        exec_now = committed | pend_mask
        unexec = self._unexec
        for q in np.nonzero(exec_now & ~self._exec_mask)[0].tolist():
            for s in self._succs[q]:
                unexec[s].discard(q)
        self._exec_mask = exec_now
        avail = dag.active & ~exec_now
        if inflight or frontier + self.WINDOW < npos:
            avail = avail.copy()
            avail[frontier + self.WINDOW:] = False
            if inflight:
                avail[list(inflight)] = False
        todo = np.nonzero(avail)[0].tolist()
        if not todo:
            return []
        costs = self.cost.costs()
        free = [p for p in todo if not unexec[p]]
        budget = self.balance * sum(
            float(costs[p]) for p in free) / nw
        plan: List[int] = []
        load = 0.0
        # lowest positions first up to the fair share; the rest of the
        # antichain stays immediately dispatchable to the next idle
        # worker, so leaving it behind wastes nothing. Position order
        # (not LPT) keeps dispatch hugging the validation frontier, so
        # parked results commit soon after collection and the
        # coordinator's per-commit dirty-set fan-out stays small —
        # batch-level imbalance is cheap here, since an early finisher
        # is re-dispatched immediately rather than waiting on a barrier
        for p in free:
            if plan and load >= budget:
                break
            plan.append(p)
            load += float(costs[p])
        aset = set(plan)
        # chain extensions in position order (a chain pred must be in
        # the plan before its dependents are considered)
        for p in todo:
            if p in aset or not unexec[p]:
                continue
            if not unexec[p] <= aset:
                continue          # off-plan / cross-plan chain: waits
            if load >= budget and len(plan) >= self.CHAIN_MIN:
                continue
            aset.add(p)
            plan.append(p)
            load += float(costs[p])
        plan.sort()
        return plan
