"""Numpy build backend: kernel-BFS waves as label-partitioned CSR gathers.

One wave expands *every* frontier pair of a hub's phase in a single
vectorized pass (BitPath-style frontier batching): the per-(vertex,
label) neighbor slices are located in the shared
:meth:`LabeledGraph.label_csr` layout and gathered as one concatenated
segment array; dedup/visited/pruning happen in
:mod:`repro.build.batched` as packed-bitset arithmetic. No per-state
python executes on the hot path.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.graph import LabeledGraph

from .base import register_backend
from .batched import BatchedBackend, FrontierEngine

_EMPTY = np.empty(0, dtype=np.int64)


def _gather_concat(starts: np.ndarray, counts: np.ndarray, total: int
                   ) -> np.ndarray:
    """Indices covering ``[starts[i], starts[i]+counts[i])`` back-to-back
    (the standard repeat/cumsum slice-concatenation trick)."""
    ends = np.cumsum(counts)
    return np.arange(total, dtype=np.int64) + np.repeat(
        starts - ends + counts, counts)


class NumpyEngine(FrontierEngine):
    def __init__(self, graph: LabeledGraph):
        self.V = graph.num_vertices
        self.nl = graph.num_labels
        self._lab_csr = (graph.label_csr(backward=False),
                         graph.label_csr(backward=True))
        self._csr = (graph.fwd, graph.bwd)

    def expand(self, rows: np.ndarray, ys: np.ndarray, rowlab: np.ndarray,
               dstrow: np.ndarray, backward: bool
               ) -> Tuple[np.ndarray, np.ndarray]:
        lptr, lnbr = self._lab_csr[backward]
        keys = ys * self.nl + rowlab[rows]
        starts = lptr[keys]
        counts = lptr[keys + 1] - starts
        total = int(counts.sum())
        if not total:
            return _EMPTY, _EMPTY
        seg = np.repeat(dstrow[rows], counts)
        return seg, lnbr[_gather_concat(starts, counts, total)].astype(
            np.int64)

    def expand_fanout(self, rows: np.ndarray, ys: np.ndarray,
                      backward: bool) -> Tuple[np.ndarray, np.ndarray]:
        indptr, other, lab = self._csr[backward]
        starts = indptr[ys]
        counts = indptr[ys + 1] - starts
        total = int(counts.sum())
        if not total:
            return _EMPTY, _EMPTY
        ptr = _gather_concat(starts, counts, total)
        child = np.repeat(rows, counts) * self.nl + lab[ptr]
        return child, other[ptr].astype(np.int64)


class NumpyBackend(BatchedBackend):
    """Hybrid scalar/vectorized build over the numpy wave engine."""

    name = "numpy"

    def _make_engine(self, graph: LabeledGraph) -> FrontierEngine:
        return NumpyEngine(graph)


register_backend("numpy", NumpyBackend)
