"""Batched index-construction engine (Algorithm 2 as a staged pipeline).

Public surface::

    from repro.build import build_rlc_index, build_rlc_index_with_stats
    idx = build_rlc_index(g, k=2)                       # auto -> numpy
    idx, st = build_rlc_index_with_stats(g, 2, backend="pallas")
    get_backend("numpy", mode="vector").build(g, 2)     # explicit control

Backends (see ``README.md`` in this package for the design):

============  ==========================================================
``python``    faithful sequential Algorithm 2 — the reference oracle
``numpy``     hybrid scalar / vectorized bitset waves on label CSR
``pallas``    hybrid with waves batched through the TPU ``frontier_step``
              kernels (interpreted on CPU; request explicitly)
``parallel``  hub-partitioned epoch/merge workers over a list-scheduled
              phase DAG (``workers=N``; each worker runs the numpy
              hybrid on a hub-sliced mirror)
============  ==========================================================

All backends produce bit-identical index entries and pruning counters.
"""
from __future__ import annotations

from typing import Tuple

from repro.core.graph import LabeledGraph
from repro.core.rlc_index import RLCIndex

from .base import (AUTO_ORDER, BuildBackend, BuildStats, PrunedInserter,
                   access_schedule, get_backend, list_backends,
                   register_backend)
from .reference import IndexBuilder, PythonBackend
from .numpy_backend import NumpyBackend

try:  # jax is optional at import time; the registry entry follows it
    from .pallas_backend import PallasBackend  # noqa: F401
except Exception:  # pragma: no cover - environments without jax
    PallasBackend = None

# multi-worker epoch/merge construction over the phase DAG
from .parallel import ParallelBackend

# the incremental engine rides on the registered batched backends
from .delta import DeltaBuilder, DeltaResult, GraphDelta

__all__ = [
    "AUTO_ORDER", "BuildBackend", "BuildStats", "DeltaBuilder",
    "DeltaResult", "GraphDelta", "IndexBuilder", "NumpyBackend",
    "PallasBackend", "ParallelBackend", "PrunedInserter", "PythonBackend",
    "access_schedule", "build_rlc_index", "build_rlc_index_with_stats",
    "get_backend", "list_backends", "register_backend",
]


def build_rlc_index_with_stats(graph: LabeledGraph, k: int,
                               backend: str = "auto", observer=None, **kw
                               ) -> Tuple[RLCIndex, BuildStats]:
    """Build the RLC index with the chosen backend; returns (index, stats).

    ``**kw`` reaches the backend constructor (``use_pr1/2/3`` everywhere;
    ``mode``/``scalar_threshold`` on the batched backends; ``interpret``
    on pallas). ``observer``: optional
    :class:`repro.obs.BuildPhaseObserver` receiving per-(hub, direction)
    phase timings and counter deltas.
    """
    return get_backend(backend, **kw).set_observer(observer).build(graph, k)


def build_rlc_index(graph: LabeledGraph, k: int, backend: str = "auto",
                    **kw) -> RLCIndex:
    return build_rlc_index_with_stats(graph, k, backend=backend, **kw)[0]
