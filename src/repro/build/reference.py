"""The python reference backend — faithful, sequential Algorithm 2.

This is the paper's reference semantics and the oracle every batched
backend is property-tested against; it deliberately stays the plain
pseudocode transcription (per-state CSR slicing, direct
``minimum_repeat`` calls) rather than chasing constants — speed is the
batched backends' job.

The scalar stage implementations are module-level and parameterized by a
neighbor accessor, so the hybrid batched builders reuse them verbatim
(with pre-materialized adjacency lists and a memoized MR table) for
low-degree hubs: running the identical code path is what makes the
hybrid dispatch trivially bit-identical.

Semantics notes (Algorithm 2 deviations and readings) live in
``src/repro/build/README.md``.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.graph import LabeledGraph
from repro.core.minimum_repeat import LabelSeq, minimum_repeat
from repro.core.rlc_index import RLCIndex

from .base import (BuildBackend, BuildStats, PrunedInserter, access_schedule,
                   register_backend)

#: ``neighbors(x, backward)`` -> iterable of (neighbor, label) pairs
NeighborFn = Callable[[int, bool], list]


class _GraphNeighbors:
    """Seed-faithful accessor: slice the CSR per visited state."""

    def __init__(self, graph: LabeledGraph):
        self.g = graph

    def __call__(self, x: int, backward: bool):
        nbrs, labs = (self.g.in_edges(x) if backward
                      else self.g.out_edges(x))
        return zip(nbrs.tolist(), labs.tolist())


class _NeighborLists:
    """Pre-materialized ``[(nbr, lab), ...]`` lists in CSR order — the
    hybrid backends' scalar-tier accessor (one conversion per build
    instead of one numpy slice + ``tolist`` per visited state)."""

    def __init__(self, graph: LabeledGraph):
        self._dir = (self._mk(graph, backward=False),
                     self._mk(graph, backward=True))

    @staticmethod
    def _mk(graph: LabeledGraph, backward: bool) -> List[list]:
        indptr, other, lab = graph.bwd if backward else graph.fwd
        other = other.tolist()
        lab = lab.tolist()
        bounds = indptr.tolist()
        return [list(zip(other[bounds[v]:bounds[v + 1]],
                         lab[bounds[v]:bounds[v + 1]]))
                for v in range(graph.num_vertices)]

    def __call__(self, x: int, backward: bool) -> list:
        return self._dir[backward][x]


class _MemoMR:
    """Memoized ``minimum_repeat`` over the (tiny) depth-<=k seq space."""

    def __init__(self):
        self._memo: Dict[LabelSeq, LabelSeq] = {}

    def __call__(self, seq: LabelSeq) -> LabelSeq:
        mr = self._memo.get(seq)
        if mr is None:
            mr = self._memo[seq] = minimum_repeat(seq)
        return mr


def kernel_search_scalar(neighbors: NeighborFn, inserter: PrunedInserter,
                         stats: BuildStats, mr_fn, v: int, k: int,
                         backward: bool, probe=None
                         ) -> Dict[LabelSeq, Set[int]]:
    """Stage 2 (scalar): exhaustive BFS to depth ``k`` over (vertex, seq)
    states. Inserts entries for every state whose MR has length <= k (PR3
    does not apply here, paper §V-B) and returns the eager kernel
    candidates ``{L: frontier vertices whose path-so-far equals L^h}``.
    ``probe`` (a :class:`repro.build.base.PhaseProbe`) records the
    traversal footprint for the delta engine.
    """
    seen: Set[Tuple[int, LabelSeq]] = {(v, ())}
    frontier: deque = deque([(v, ())])
    kernels: Dict[LabelSeq, Set[int]] = {}
    if probe is not None:
        probe.visited |= 1 << v
        probe.near |= 1 << v
    while frontier:
        x, seq = frontier.popleft()
        for y, lab in neighbors(x, backward):
            seq2 = ((lab,) + seq) if backward else (seq + (lab,))
            state = (y, seq2)
            if state in seen:
                continue
            seen.add(state)
            stats.kernel_search_states += 1
            if probe is not None:
                probe.visited |= 1 << y
            L = mr_fn(seq2)
            if len(L) <= k:
                # |MR| <= k  =>  seq2 == L^h: a genuine entry AND an
                # eager kernel candidate seeded at y (repeat boundary).
                inserter.insert(y, v, L, backward)
                kernels.setdefault(L, set()).add(y)
            if len(seq2) < k:
                frontier.append((y, seq2))
                if probe is not None:
                    probe.near |= 1 << y
    return kernels


def kernel_bfs_scalar(neighbors: NeighborFn, inserter: PrunedInserter,
                      stats: BuildStats, use_pr3: bool,
                      v: int, L: LabelSeq, seeds: Set[int],
                      backward: bool, probe=None) -> None:
    """Stage 3 (scalar): product-automaton BFS guided by ``L^+``.

    State ``(y, p)``: ``p`` labels consumed since the last full-repeat
    boundary. Backward search prepends labels, so from state ``p`` the
    expected edge label is ``L[m-1-p]``; forward appends, expecting
    ``L[p]``. Stage-4 insertion fires when ``p`` wraps to 0; a pruned
    insertion (PR1/PR2 fired) triggers the PR3 subtree cut. ``probe``
    records expansion tails per label (PR3-cut states are never popped,
    so they stay out of the label masks — exactly the states that do
    not expand).
    """
    m = len(L)
    visited: Set[Tuple[int, int]] = {(x, 0) for x in seeds}
    q: deque = deque(visited)
    while q:
        x, p = q.popleft()
        want = L[m - 1 - p] if backward else L[p]
        if probe is not None:
            probe.lab[want] |= 1 << x
        for y, lab in neighbors(x, backward):
            if lab != want:
                continue
            p2 = (p + 1) % m
            if (y, p2) in visited:
                continue
            stats.kernel_bfs_states += 1
            if probe is not None:
                probe.visited |= 1 << y
            if p2 == 0:
                if not inserter.insert(y, v, L, backward):
                    if use_pr3:
                        # PR3: cut the subtree behind y (do not expand).
                        stats.pr3_cuts += 1
                        visited.add((y, p2))
                        continue
            visited.add((y, p2))
            q.append((y, p2))


class PythonBackend(BuildBackend):
    """Sequential Algorithm 2 — the reference oracle."""

    name = "python"

    def _build(self, graph: LabeledGraph, k: int, stats: BuildStats
               ) -> RLCIndex:
        order, aid = access_schedule(graph)
        index = RLCIndex(graph.num_vertices, k, aid)
        inserter = PrunedInserter(index, stats, self.use_pr1, self.use_pr2)
        neighbors = _GraphNeighbors(graph)
        obs = self.observer
        for v in order:
            v = int(v)
            for backward in (True, False):
                if obs is not None:
                    before = stats.counters()
                    t0 = time.perf_counter()
                self._phase(neighbors, inserter, stats, v, k, backward)
                if obs is not None:
                    obs.phase(v, backward, time.perf_counter() - t0,
                              counter_delta=tuple(
                                  a - b for a, b in zip(stats.counters(),
                                                        before)))
        return index

    def _phase(self, neighbors, inserter, stats, v: int, k: int,
               backward: bool) -> None:
        kernels = kernel_search_scalar(
            neighbors, inserter, stats, minimum_repeat, v, k, backward)
        for L, seeds in kernels.items():
            kernel_bfs_scalar(neighbors, inserter, stats,
                              self.use_pr3, v, L, seeds, backward)


register_backend("python", PythonBackend)


# --------------------------------------------------------------------- #
# Back-compat surface (the pre-refactor ``core.index_builder`` API)
# --------------------------------------------------------------------- #
class IndexBuilder:
    """Drop-in for the historical ``core.index_builder.IndexBuilder``."""

    def __init__(self, graph: LabeledGraph, k: int,
                 use_pr1: bool = True, use_pr2: bool = True,
                 use_pr3: bool = True):
        self.g = graph
        self.k = int(k)
        self._backend = PythonBackend(use_pr1, use_pr2, use_pr3)
        self.stats = BuildStats(backend=self._backend.name)
        self.index: Optional[RLCIndex] = None

    def build(self) -> RLCIndex:
        self.index, self.stats = self._backend.build(self.g, self.k)
        return self.index
