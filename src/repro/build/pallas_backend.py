"""JAX/Pallas build backend: hub waves through ``frontier_step_many``.

The wave contract is the same as the numpy engine's — expand a batch of
``(row, vertex)`` frontier pairs one label step — but the expansion runs
as an OR-AND matmul against the dense label-sliced adjacency stack on
the accelerator, batching every kernel/phase row of a hub's product
automaton through one :func:`repro.kernels.label_frontier.
frontier_step_many` call. Frontier hand-off between device and the
host-side pruned-insert loop travels bit-packed through
:mod:`repro.kernels.bitpack` (32 vertices per word — 32x less transfer
than the f32 frontier it replaces).

Hub batching deliberately stops at one hub: PR1 reads the entries every
earlier hub completed, so cross-hub waves cannot stay bit-identical
(see :mod:`repro.build.batched`). On a TPU the win is the per-hub wave
batch; on CPU the kernels only *interpret*, so this backend defaults to
hybrid dispatch (device waves for the widest hubs) and exists there for
validation — request ``mode="vector"`` to force every hub through the
kernel path, as the equivalence tests do.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.graph import LabeledGraph

from .base import register_backend
from .batched import BatchedBackend, FrontierEngine

_EMPTY = np.empty(0, dtype=np.int64)


def _on_cpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "cpu"
    except Exception:
        return True


def _pad128(n: int) -> int:
    return max(128, -(-n // 128) * 128)


class PallasEngine(FrontierEngine):
    def __init__(self, graph: LabeledGraph, interpret: Optional[bool] = None):
        import jax.numpy as jnp  # deferred: backend is optional

        self.V = graph.num_vertices
        self.nl = graph.num_labels
        self.Vp = _pad128(self.V)
        self.interpret = _on_cpu() if interpret is None else interpret
        A = np.zeros((self.nl, self.Vp, self.Vp), dtype=np.float32)
        e = graph.edges
        A[e[:, 1], e[:, 0], e[:, 2]] = 1
        self._A = (jnp.asarray(A),                      # forward: u -> v
                   jnp.asarray(np.swapaxes(A, 1, 2)))  # backward: v -> u

    # ------------------------------------------------------------------ #
    def _step(self, F: np.ndarray, labels: np.ndarray, backward: bool
              ) -> np.ndarray:
        """One device wave: returns the (R, V) boolean next frontier.
        The device result round-trips bit-packed (kernels/bitpack)."""
        import jax.numpy as jnp
        from repro.kernels.bitpack import pack_bits
        from repro.kernels.label_frontier import frontier_step_many

        G = frontier_step_many(jnp.asarray(F), self._A[backward],
                               jnp.asarray(labels.astype(np.int32)),
                               interpret=self.interpret)
        packed = np.asarray(pack_bits(G))               # (R, Vp/32) uint32
        bits = (packed[..., None] >> np.arange(32, dtype=np.uint32)) & 1
        return bits.reshape(len(F), self.Vp)[:, :self.V].astype(bool)

    def expand(self, rows: np.ndarray, ys: np.ndarray, rowlab: np.ndarray,
               dstrow: np.ndarray, backward: bool
               ) -> Tuple[np.ndarray, np.ndarray]:
        R = len(rowlab)
        F = np.zeros((R, self.Vp), dtype=np.float32)
        F[rows, ys] = 1.0
        dense = self._step(F, rowlab, backward)
        nr, ny = np.nonzero(dense)
        if not nr.size:
            return _EMPTY, _EMPTY
        return dstrow[nr], ny.astype(np.int64)

    def expand_fanout(self, rows: np.ndarray, ys: np.ndarray,
                      backward: bool) -> Tuple[np.ndarray, np.ndarray]:
        # duplicate each active parent row once per label; the multi-label
        # kernel then expands all (parent, label) fans in one call
        parents = np.unique(rows)
        P, nl = len(parents), self.nl
        F = np.zeros((P * nl, self.Vp), dtype=np.float32)
        loc = np.searchsorted(parents, rows)
        for l in range(nl):
            F[loc * nl + l, ys] = 1.0
        labels = np.tile(np.arange(nl, dtype=np.int32), P)
        dense = self._step(F, labels, backward)
        nr, ny = np.nonzero(dense)
        if not nr.size:
            return _EMPTY, _EMPTY
        child = parents[nr // nl] * nl + (nr % nl)
        return child, ny.astype(np.int64)


class PallasBackend(BatchedBackend):
    """Hybrid build whose wide-hub waves run on the Pallas kernels."""

    name = "pallas"

    def __init__(self, *args, interpret: Optional[bool] = None, **kw):
        super().__init__(*args, **kw)
        self.interpret = interpret

    def _make_engine(self, graph: LabeledGraph) -> FrontierEngine:
        return PallasEngine(graph, interpret=self.interpret)


register_backend("pallas", PallasBackend)
