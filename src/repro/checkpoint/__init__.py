from .store import (CheckpointManager, latest_step, restore_pytree,
                    save_pytree)

__all__ = ["save_pytree", "restore_pytree", "latest_step",
           "CheckpointManager"]
