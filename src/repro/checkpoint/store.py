"""Sharded, async, restart-safe checkpointing (no external deps).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (keyed by
its flattened path, one file per process shard in multi-host mode) plus a
``manifest.json`` (tree structure, shapes, dtypes, process count) written
LAST — a step directory without a manifest is incomplete and ignored, so
killed writers never corrupt restore (atomicity via rename).

Async: ``CheckpointManager.save_async`` snapshots to host memory
synchronously (device -> np) and writes on a background thread —
training resumes immediately (the overlap trick; see ft/ for the
failure-drill test).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "__"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(directory: str, step: int, tree: PyTree,
                extra: Optional[Dict] = None,
                process_index: int = 0, num_processes: int = 1) -> str:
    """Write one checkpoint step (atomic via tmp-dir rename)."""
    flat = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".tmp_step{step}_")
    try:
        for key, arr in flat.items():
            np.save(os.path.join(tmp, f"{key}.p{process_index}.npy"), arr)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "num_processes": num_processes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_pytree(directory: str, step: int, template: PyTree,
                   process_index: int = 0) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``template`` (values ignored)."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.load(os.path.join(d, f"{key}.p{process_index}.npy"))
        leaves.append(arr.astype(manifest["dtypes"][key]))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class CheckpointManager:
    """Keeps the last ``keep`` steps; async background writes."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: PyTree,
                   extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_pytree(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None):
        self.wait()
        save_pytree(self.directory, step, tree, extra)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.directory, n, "manifest.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, template: PyTree
                       ) -> Optional[Tuple[int, PyTree, Dict]]:
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None
        tree, extra = restore_pytree(self.directory, step, template)
        return step, tree, extra
