"""internvl2-26b [vlm] — InternViT + InternLM2 backbone.

[arXiv:2404.16821; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. Assignment rule: transformer BACKBONE only; the vision
frontend is a STUB — ``input_specs()`` provides precomputed patch
embeddings (256 tokens of InternViT width 3200, pixel-shuffled), projected
and prepended to the token stream (early fusion).
"""
from .base import ArchConfig, dense_pattern, register

FULL = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    block_pattern=dense_pattern(48),
    rope_theta=1_000_000.0,
    frontend="patch_stub",
    frontend_len=256,
    frontend_dim=3200,
))

SMOKE = register(FULL.replace(
    name="internvl2-26b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=503, block_pattern=dense_pattern(2),
    frontend_len=8, frontend_dim=24, vocab_pad_multiple=8,
    param_dtype="float32", compute_dtype="float32",
))
