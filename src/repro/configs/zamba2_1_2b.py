"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf]

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Mamba2 backbone with a *shared* attention+MLP block applied every 6th
layer (Zamba2 shares one transformer block's weights across its uses; we
keep that sharing — one ``hybrid_attn`` param set reused at every
occurrence). Attention uses a 4096 sliding window so the 500k-decode cell
is sub-quadratic (deviation + rationale in DESIGN.md §4).
"""
from .base import ArchConfig, hybrid_pattern, register

FULL = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    block_pattern=hybrid_pattern(38, period=6),
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    sliding_window=4096,
))

SMOKE = register(FULL.replace(
    name="zamba2-1.2b-smoke",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, block_pattern=hybrid_pattern(4, period=2),
    ssm_state=16, ssm_headdim=16, sliding_window=32,
    vocab_pad_multiple=8, param_dtype="float32", compute_dtype="float32",
))
