"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936. Qwen3 uses an
explicit head_dim=128 (16*128 != d_model) and RMSNorm on q/k heads.
"""
from .base import ArchConfig, dense_pattern, register

FULL = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    block_pattern=dense_pattern(28),
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
))

SMOKE = register(FULL.replace(
    name="qwen3-0.6b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=24,
    d_ff=128, vocab_size=512, block_pattern=dense_pattern(2),
    vocab_pad_multiple=8, param_dtype="float32", compute_dtype="float32",
))
