"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified]

32L d_model=2560 32H (GQA kv=32 == MHA) d_ff=6912 vocab=50304.
StableLM-2 family uses LayerNorm and partial-rotary attention; we keep
LayerNorm and full rotary (deviation noted in DESIGN.md §4).
"""
from .base import ArchConfig, dense_pattern, register

FULL = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    block_pattern=dense_pattern(32),
    norm="layernorm",
))

SMOKE = register(FULL.replace(
    name="stablelm-3b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=160, vocab_size=499, block_pattern=dense_pattern(2),
    vocab_pad_multiple=8, param_dtype="float32", compute_dtype="float32",
))
