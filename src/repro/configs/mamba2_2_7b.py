"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified]

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
expand=2 -> d_inner=5120, headdim=64 -> 80 SSD heads, 1 B/C group.
The long_500k flagship: O(S) prefill chunks, O(1) decode state.
"""
from .base import ArchConfig, register, ssm_pattern

FULL = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    block_pattern=ssm_pattern(64),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
))

SMOKE = register(FULL.replace(
    name="mamba2-2.7b-smoke",
    num_layers=2, d_model=64, vocab_size=512,
    block_pattern=ssm_pattern(2), ssm_state=16, ssm_headdim=16,
    ssm_chunk=16, vocab_pad_multiple=8,
    param_dtype="float32", compute_dtype="float32",
))
