"""llama4-scout-17b-a16e [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 + 1 shared expert per layer (Scout routes every layer). The
interleaved RoPE/NoPE schedule is kept as RoPE throughout (DESIGN.md §4).
"""
from .base import ArchConfig, moe_pattern, register

FULL = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=moe_pattern(48),
    num_experts=16,
    top_k=1,
    moe_d_ff=8192,
    num_shared_experts=1,
    rope_theta=500_000.0,
))

SMOKE = register(FULL.replace(
    name="llama4-scout-17b-a16e-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=96, vocab_size=512, block_pattern=moe_pattern(2),
    num_experts=4, top_k=1, num_shared_experts=1,
    moe_capacity_factor=8.0,   # no drops at smoke scale (see deepseek smoke)
    vocab_pad_multiple=8, param_dtype="float32", compute_dtype="float32",
))
