"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297; hf]

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from .base import ArchConfig, dense_pattern, register

FULL = register(ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    block_pattern=dense_pattern(24),
    rope_theta=1_000_000.0,
))

SMOKE = register(FULL.replace(
    name="internlm2-1.8b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, block_pattern=dense_pattern(2),
    vocab_pad_multiple=8, param_dtype="float32", compute_dtype="float32",
))
