"""Config registry: import every arch module so `--arch <id>` resolves."""
from .base import (SHAPES, ArchConfig, ShapeCell, cell_supported,
                   get_config, list_configs)
from . import (command_r_plus_104b, deepseek_v3_671b, internlm2_1_8b,
               internvl2_26b, llama4_scout_17b_a16e, mamba2_2_7b,
               qwen3_0_6b, rlc_paper, stablelm_3b, whisper_tiny,
               zamba2_1_2b)

ASSIGNED = (
    "internvl2-26b", "stablelm-3b", "internlm2-1.8b", "qwen3-0.6b",
    "command-r-plus-104b", "llama4-scout-17b-a16e", "deepseek-v3-671b",
    "zamba2-1.2b", "mamba2-2.7b", "whisper-tiny",
)

__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "get_config",
           "list_configs", "cell_supported", "ASSIGNED"]
