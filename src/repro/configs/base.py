"""Architecture + shape configuration registry.

One ``ArchConfig`` per assigned architecture (exact figures from the
assignment table; ``[source]`` notes in each arch file) plus reduced smoke
variants. Shapes are the assignment's four input-shape cells; skip rules
(sub-quadratic requirement for ``long_500k``) are encoded here and
surfaced by the dry-run/roofline reports.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple

import jax.numpy as jnp

_REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # block kinds per layer; built by helpers below
    block_pattern: Tuple[str, ...] = ()

    # normalization / misc
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # attention
    attention: str = "gqa"          # gqa | mla
    sliding_window: int = 0         # 0 = full causal
    # chunked online-softmax attention (flash-style, pure JAX): never
    # materializes (S, T) scores — KV streamed in `attn_chunk` blocks.
    # 0 = off (dense scores). §Perf lever for 32k+ prefill cells.
    attn_chunk: int = 0
    # MLA (DeepSeek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4
    moe_combine: str = "scatter"    # scatter (EP-friendly) | gather

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # encoder-decoder / modality frontend (STUB per assignment)
    encoder_layers: int = 0
    frontend: str = "none"          # none | audio_stub | patch_stub
    frontend_len: int = 0           # precomputed frames / patches
    frontend_dim: int = 0           # stub embedding dim

    # dtypes / padding
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    remat: str = "full"             # full | dots | none
    # scan layers (small HLO, fast compile) vs unroll (accurate
    # cost_analysis: XLA visits while-loop bodies once, so scanned flops
    # under-count by ~num_layers; the dry-run unrolls).
    scan_stages: bool = True

    # ------------------------------------------------------------------ #
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def stages(self) -> Tuple[Tuple[str, int], ...]:
        """Run-length encoded block pattern -> scan stages."""
        out = []
        for kind in self.block_pattern:
            if out and out[-1][0] == kind:
                out[-1][1] += 1
            else:
                out.append([kind, 1])
        return tuple((k, n) for k, n in out)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k: SSM-only, or attention under a sliding
        window (zamba2). Full-attention kinds: attn/moe/xattn/hybrid."""
        kinds = set(self.block_pattern)
        quad = {"attn", "moe", "xattn", "hybrid_attn"} & kinds
        return (not quad) or (self.sliding_window > 0)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)

    def dtype(self, what: str = "param"):
        return jnp.dtype(self.param_dtype if what == "param"
                         else self.compute_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------------------ #
def dense_pattern(n: int) -> Tuple[str, ...]:
    return ("attn",) * n


def moe_pattern(n: int, first_dense: int = 0) -> Tuple[str, ...]:
    return ("attn",) * first_dense + ("moe",) * (n - first_dense)


def ssm_pattern(n: int) -> Tuple[str, ...]:
    return ("ssm",) * n


def hybrid_pattern(n: int, period: int = 6) -> Tuple[str, ...]:
    """Zamba-style: shared attention block every ``period`` layers."""
    out = []
    for i in range(n):
        out.append("hybrid_attn" if (i % period) == (period - 1) else "ssm")
    return tuple(out)


# ------------------------------------------------------------------ #
@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(supported, reason-if-skipped) per assignment skip rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: 500k dense decode is not "
                       "sub-quadratic (assignment skip rule; DESIGN.md §4)")
    return True, ""


# ------------------------------------------------------------------ #
def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs  # ensure arch modules imported
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401 — populates _REGISTRY
    return tuple(sorted(_REGISTRY))
