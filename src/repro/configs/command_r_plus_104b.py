"""command-r-plus-104b [dense] — GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified]

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000. The memory-
dominant assigned cell: 104B dense params; needs FSDP x TP (+ SP) on the
production mesh.
"""
from .base import ArchConfig, dense_pattern, register

FULL = register(ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    block_pattern=dense_pattern(64),
    use_bias=False,
    rope_theta=75_000_000.0,
))

SMOKE = register(FULL.replace(
    name="command-r-plus-104b-smoke",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=500, block_pattern=dense_pattern(2),
    vocab_pad_multiple=4, param_dtype="float32", compute_dtype="float32",
))
