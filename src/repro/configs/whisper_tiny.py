"""whisper-tiny [audio] — enc-dec, conv frontend (STUB) [arXiv:2212.04356]

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. Encoder-decoder; the
mel/conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (1500 frames x d_model). Decode shapes run
through the decoder (cross-attending the stub-encoded audio); 6 heads are
not divisible by the 16-way model axis, so attention params fall back to
replication under the divisibility guard (sharding/partition.py) while
FFN/vocab still shard.
"""
from .base import ArchConfig, register

FULL = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    block_pattern=("xattn",) * 4,   # decoder blocks cross-attend the encoder
    norm="layernorm",
    frontend="audio_stub",
    frontend_len=1500,
    frontend_dim=384,
))

SMOKE = register(FULL.replace(
    name="whisper-tiny-smoke",
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
    block_pattern=("xattn",) * 2, frontend_len=12, frontend_dim=64,
    vocab_pad_multiple=8, param_dtype="float32", compute_dtype="float32",
))
