"""The paper's own workload configs: RLC index build + query serving cells.

Not an LM architecture — these parameterize the dense semiring engine
(core/dense.py) for the dry-run/roofline of the paper's technique itself:
``rlc-index`` cells lower the hub-batched build step and the batched
query join on the production mesh.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class RLCCell:
    name: str
    num_vertices: int
    num_labels: int
    k: int
    hub_batch: int
    query_batch: int
    row_len: int  # padded index row length for the query join


RLC_CELLS = {
    # pod-scale dense engine: 64k-vertex partition per pod, |L|=8, k=2
    "rlc-build-64k": RLCCell("rlc-build-64k", 65_536, 8, 2,
                             hub_batch=256, query_batch=0, row_len=0),
    # serving: 1M queries/batch against a 1M-vertex frozen index
    "rlc-query-1m": RLCCell("rlc-query-1m", 1_048_576, 8, 2,
                            hub_batch=0, query_batch=1_048_576,
                            row_len=128),
    # §Perf iteration 1: sorted-key searchsorted join (same workload)
    "rlc-query-1m-sorted": RLCCell("rlc-query-1m-sorted", 1_048_576, 8, 2,
                                   hub_batch=0, query_batch=1_048_576,
                                   row_len=128),
}
