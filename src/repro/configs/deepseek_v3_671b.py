"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8
[arXiv:2412.19437; hf]

61L d_model=7168 128H d_ff=2048 (routed-expert width; the 3 leading dense
layers use the model's 18432 FFN) vocab=129280, MoE 256e top-8. MLA:
q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128 — the latent KV
cache (512+64 per token) is the serving win; decode uses the absorbed
formulation (models/attention.py). MTP (depth-1 multi-token prediction)
is available as ``train.mtp`` but off by default (DESIGN.md §4).
"""
from .base import ArchConfig, moe_pattern, register

FULL = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,   # assignment lists kv=128; MLA has no separate KV heads
    head_dim=128,
    d_ff=18432,         # dense-layer FFN (first 3 layers)
    vocab_size=129280,
    block_pattern=moe_pattern(61, first_dense=3),
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=256,
    top_k=8,
    moe_d_ff=2048,
    num_shared_experts=1,
))

SMOKE = register(FULL.replace(
    name="deepseek-v3-671b-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=160, moe_d_ff=64, vocab_size=512,
    block_pattern=moe_pattern(3, first_dense=1),
    q_lora_rank=32, kv_lora_rank=24, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, num_experts=8, top_k=2, num_shared_experts=1,
    moe_capacity_factor=8.0,   # no token drops at smoke scale: keeps
    vocab_pad_multiple=8,      # prefill/decode bit-equivalent in tests
    param_dtype="float32", compute_dtype="float32",
))
