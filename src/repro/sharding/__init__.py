from .partition import (ACT_RULES, PARAM_RULES, constrain,
                        logical_to_sharding, logical_to_spec)

__all__ = ["PARAM_RULES", "ACT_RULES", "logical_to_spec",
           "logical_to_sharding", "constrain"]
