"""Logical-axis sharding rules (DESIGN §5).

Every parameter carries a tuple of *logical axis names*; activations are
constrained at block boundaries. Rules map logical names to mesh axes;
``logical_to_spec`` drops any assignment whose dimension is not divisible
by the mesh-axis size (e.g. whisper-tiny's 6 heads on a 16-way ``model``
axis fall back to replication) so one rule set serves all 10 assigned
architectures on every mesh.

Parallelism mapping (train):
  * DP/FSDP — ``batch`` over ("pod","data"); params' ``fsdp`` (largest
    non-TP dim) over "data" (ZeRO-3 gather on use);
  * TP — ``heads``/``kv``/``ff``/``vocab`` over "model";
  * EP — ``experts`` over "model";
  * SP — activation ``act_seq`` over "model" between blocks (norm/residual
    segments), re-gathered by XLA inside attention.
Serving: KV-cache ``cache_seq`` over "model" (long-context decode), batch
over ("pod","data").
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Axes = Tuple[Optional[Union[str, Tuple[str, ...]]], ...]

# parameter logical axes
PARAM_RULES: Dict[str, Optional[Union[str, Tuple[str, ...]]]] = {
    "vocab": "model",
    "heads": "model",      # fused heads*head_dim output dims
    "kv": "model",
    "ff": "model",
    "experts": "model",
    "fsdp": "data",        # ZeRO-3 shard of the non-TP major dim
    "embed": None,
    "layers": None,        # stacked scan axis (pipeline axis at >4k chips)
    "conv": None,
    "state": None,
    "lora": None,
    None: None,
}

# activation logical axes
ACT_RULES: Dict[str, Optional[Union[str, Tuple[str, ...]]]] = {
    "act_batch": ("pod", "data"),
    "act_batch_nopod": "data",
    "act_seq": "model",     # sequence parallelism between blocks
    "act_embed": None,
    "act_heads": "model",
    "cache_seq": "model",   # KV cache length dim for decode
    "act_experts": "model",
    None: None,
}


def _filter_assignment(mesh, assignment):
    """Drop mesh axes absent from this mesh (e.g. 'pod' on single-pod);
    returns (normalized assignment or None, product of axis sizes)."""
    if assignment is None:
        return None, 1
    names = mesh.axis_names
    axes = (assignment,) if isinstance(assignment, str) else tuple(assignment)
    present = tuple(a for a in axes if a in names)
    if not present:
        return None, 1
    size = 1
    for a in present:
        size *= mesh.shape[a]
    return (present[0] if len(present) == 1 else present), size


def logical_to_spec(shape: Sequence[int], axes: Axes, mesh: Mesh,
                    rules: Dict) -> P:
    """PartitionSpec from logical axes, with divisibility fallback."""
    assert len(shape) == len(axes), (shape, axes)
    parts = []
    for dim, ax in zip(shape, axes):
        assignment, size = _filter_assignment(mesh, rules.get(ax, None))
        if assignment is None or size == 1 or dim % size != 0:
            parts.append(None)
        else:
            parts.append(assignment)
    return P(*parts)


def logical_to_sharding(shape: Sequence[int], axes: Axes, mesh: Mesh,
                        rules: Optional[Dict] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(
        shape, axes, mesh, rules or PARAM_RULES))


def constrain(x: jax.Array, axes: Axes, rules: Optional[Dict] = None
              ) -> jax.Array:
    """with_sharding_constraint under the ambient mesh (no-op when no mesh
    is set — smoke tests and benches run unconstrained on 1 device)."""
    mesh = None
    try:
        env = jax.sharding.get_abstract_mesh()
        if env is not None and env.axis_names:
            mesh = env
    except Exception:
        mesh = None
    if mesh is None:
        return x
    spec = logical_to_spec(x.shape, axes, mesh, rules or ACT_RULES)
    return jax.lax.with_sharding_constraint(x, spec)


def tree_shardings(tree, axes_tree, mesh: Mesh, rules: Optional[Dict] = None):
    """Map a pytree of arrays/ShapeDtypeStructs + matching logical-axes tree
    to NamedShardings."""
    return jax.tree.map(
        lambda leaf, ax: logical_to_sharding(leaf.shape, ax, mesh,
                                             rules or PARAM_RULES),
        tree, axes_tree,
        is_leaf=lambda l: hasattr(l, "shape"))
