from .generators import (barabasi_albert, erdos_renyi, fig1_graph,
                         fig2_graph, random_delta, random_labeled_graph,
                         zipf_labels)

__all__ = ["erdos_renyi", "barabasi_albert", "zipf_labels",
           "random_labeled_graph", "random_delta", "fig2_graph",
           "fig1_graph"]
