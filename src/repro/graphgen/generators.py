"""Synthetic graph generation (paper §VI-b).

ER (Erdős–Rényi) and BA (Barabási–Albert) digraphs with Zipfian edge-label
assignment (exponent 2, matching the paper / gMark), plus the paper's two
illustration graphs (Fig. 1 and Fig. 2) for examples and tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.graph import LabeledGraph


def zipf_labels(num_edges: int, num_labels: int, rng: np.random.Generator,
                exponent: float = 2.0) -> np.ndarray:
    """Zipfian label ids (exponent 2 per the paper) in ``[0, num_labels)``."""
    ranks = np.arange(1, num_labels + 1, dtype=np.float64)
    p = ranks ** (-exponent)
    p /= p.sum()
    return rng.choice(num_labels, size=num_edges, p=p).astype(np.int32)


def erdos_renyi(num_vertices: int, avg_degree: float, num_labels: int,
                seed: int = 0, allow_loops: bool = True) -> LabeledGraph:
    """Directed ER graph: ``n * avg_degree`` edges drawn uniformly."""
    rng = np.random.default_rng(seed)
    m = int(round(num_vertices * avg_degree))
    src = rng.integers(0, num_vertices, size=m, dtype=np.int64)
    dst = rng.integers(0, num_vertices, size=m, dtype=np.int64)
    if not allow_loops:
        loops = src == dst
        dst[loops] = (dst[loops] + 1) % num_vertices
    lab = zipf_labels(m, num_labels, rng)
    edges = np.stack([src, lab, dst], axis=1)
    return LabeledGraph.from_edges(num_vertices, num_labels, edges)


def barabasi_albert(num_vertices: int, m_attach: int, num_labels: int,
                    seed: int = 0) -> LabeledGraph:
    """Directed BA graph: start from a complete core of ``m_attach + 1``
    vertices; each new vertex attaches ``m_attach`` out-edges preferentially
    (classic BA; direction new -> target, plus a reverse edge with p=0.5 to
    mimic the cyclic character of the paper's datasets)."""
    rng = np.random.default_rng(seed)
    core = m_attach + 1
    src_l, dst_l = [], []
    # complete directed core (both directions, no self loops)
    for u in range(core):
        for v in range(core):
            if u != v:
                src_l.append(u)
                dst_l.append(v)
    degree = np.zeros(num_vertices, dtype=np.float64)
    degree[:core] = 2 * (core - 1)
    total = degree.sum()
    for v in range(core, num_vertices):
        p = degree[:v] / total
        targets = rng.choice(v, size=min(m_attach, v), replace=False, p=p)
        for t in targets:
            src_l.append(v)
            dst_l.append(int(t))
            if rng.random() < 0.5:
                src_l.append(int(t))
                dst_l.append(v)
            degree[t] += 1
            degree[v] += 1
            total += 2
    m = len(src_l)
    lab = zipf_labels(m, num_labels, rng)
    edges = np.stack([np.asarray(src_l), lab, np.asarray(dst_l)], axis=1)
    return LabeledGraph.from_edges(num_vertices, num_labels, edges)


def random_labeled_graph(num_vertices: int, num_edges: int, num_labels: int,
                         seed: int = 0, self_loop_frac: float = 0.05
                         ) -> LabeledGraph:
    """Uniform random graph with a controlled fraction of self loops —
    the stress shape for RLC indexing (cycles of length 1, paper §II)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    dst = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    n_loop = int(num_edges * self_loop_frac)
    if n_loop:
        which = rng.choice(num_edges, size=n_loop, replace=False)
        dst[which] = src[which]
    lab = rng.integers(0, num_labels, size=num_edges, dtype=np.int64)
    edges = np.stack([src, lab, dst], axis=1)
    return LabeledGraph.from_edges(num_vertices, num_labels, edges)


def random_delta(graph: LabeledGraph, n_ins: int, n_del: int,
                 rng: np.random.Generator, max_tries: int = 1000):
    """A random :class:`repro.core.graph.GraphDelta` for ``graph``:
    ``n_del`` uniformly drawn existing edges deleted plus up to
    ``n_ins`` fresh (absent) edges inserted. The insert search is
    bounded by ``max_tries`` rejection samples so a near-complete
    (src, label, dst) space degrades to a smaller insert batch instead
    of spinning — the shared workload generator for the delta tests,
    benchmarks and examples."""
    from repro.core.graph import GraphDelta
    keys = set(map(tuple, graph.edges.tolist()))
    n_del = min(n_del, graph.num_edges)
    dels = [graph.edges[i].tolist()
            for i in rng.choice(graph.num_edges, size=n_del,
                                replace=False)] if n_del else []
    ins: list = []
    seen = set()
    for _ in range(max_tries):
        if len(ins) >= n_ins:
            break
        e = (int(rng.integers(graph.num_vertices)),
             int(rng.integers(graph.num_labels)),
             int(rng.integers(graph.num_vertices)))
        if e not in keys and e not in seen:
            seen.add(e)
            ins.append(list(e))
    return GraphDelta.of(ins, dels)


# ------------------------------------------------------------------ #
# Paper illustration graphs
# ------------------------------------------------------------------ #
def fig2_graph() -> Tuple[LabeledGraph, Dict[str, int]]:
    """The running-example graph of paper Fig. 2 (reconstructed from the
    example text + Table II). Labels: l1=0, l2=1, l3=2; vertices v1..v6."""
    names = {f"v{i}": i - 1 for i in range(1, 7)}
    l1, l2, l3 = 0, 1, 2
    E = [
        ("v1", l2, "v3"), ("v3", l1, "v2"), ("v3", l1, "v6"),
        ("v3", l2, "v4"), ("v4", l1, "v1"), ("v2", l2, "v5"),
        ("v5", l1, "v1"), ("v4", l3, "v6"), ("v3", l2, "v1"),
        ("v2", l1, "v1"),
    ]
    edges = np.array([[names[s], l, names[t]] for s, l, t in E])
    return LabeledGraph.from_edges(6, 3, edges), names


def fig1_graph() -> Tuple[LabeledGraph, Dict[str, int], Dict[str, int]]:
    """The social/professional/financial network of paper Fig. 1 (Example 1).

    Vertices: persons P10..P13, P16; accounts A14, A17, A19; employers
    E15, E18 (account-like transfer hops). Labels: knows, worksFor, debits,
    credits, holds. Encodes the two example queries:
      Q1(A14, A19, (debits, credits)+) = true
      Q2(P10, P13, (knows, knows, worksFor)+) = false
    """
    labels = {"knows": 0, "worksFor": 1, "debits": 2, "credits": 3,
              "holds": 4}
    names = {}
    for i, nm in enumerate(["P10", "P11", "P12", "P13", "P16",
                            "A14", "E15", "A17", "E18", "A19"]):
        names[nm] = i
    K, W, D, C, H = (labels[x] for x in
                     ("knows", "worksFor", "debits", "credits", "holds"))
    E = [
        # social / professional
        ("P10", K, "P11"), ("P11", W, "P12"), ("P12", K, "P13"),
        ("P13", W, "P16"), ("P11", K, "P12"), ("P12", K, "P16"),
        ("P16", K, "P10"),
        # account holdings
        ("P10", H, "A14"), ("P12", H, "A17"), ("P13", H, "A19"),
        # money movement: A14 -debits-> E15 -credits-> A17 -debits-> E18
        #                 -credits-> A19
        ("A14", D, "E15"), ("E15", C, "A17"), ("A17", D, "E18"),
        ("E18", C, "A19"),
    ]
    edges = np.array([[names[s], l, names[t]] for s, l, t in E])
    return LabeledGraph.from_edges(10, 5, edges), names, labels
