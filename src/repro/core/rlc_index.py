"""The RLC index (paper §V, Definition 4) and Algorithm 1 (query).

Index layout
------------
For every vertex ``v`` the index holds two entry sets

    L_in(v)  = {(u, mr) : u ~~mr^+~~> v}      (u reaches v, MR recorded)
    L_out(v) = {(w, mr) : v ~~mr^+~~> w}

Entries are stored per-vertex as ``dict[hub_vertex] -> set[mr tuple]`` for
O(1) membership, and can be *frozen* into aid-sorted flat numpy arrays (the
paper's merge-join layout, also consumed by the batched JAX/Pallas query
engines in :mod:`repro.core.device_index`).

Query semantics (Definition 4 / Theorem 3): ``(s, t, L^+)`` is true iff
  * Case 2: ``(t, L) in L_out(s)`` or ``(s, L) in L_in(t)``; or
  * Case 1: ``exists x: (x, L) in L_out(s) and (x, L) in L_in(t)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .minimum_repeat import LabelSeq

Entry = Tuple[int, LabelSeq]          # (hub vertex id, minimum repeat)
EntryMap = Dict[int, Set[LabelSeq]]   # hub vertex id -> set of MRs

_BIT = np.left_shift(np.uint8(1), np.arange(8, dtype=np.uint8))


class BitMirror:
    """Bit-packed mirror of the entry sets, keyed per minimum repeat.

    ``out[x, c]`` is a little-endian packed bitset over visited vertices
    ``y`` with bit ``y`` set iff ``(x, mr_c) in L_out(y)`` (``in_`` is the
    symmetric L_in mirror). One row is one hub's footprint for one MR, so
    Algorithm 2's PR1 coverage check for a whole frontier collapses to a
    handful of row ORs + a bit gather (:meth:`RLCIndex.pr1_cover_out`) —
    the numpy twin of the 32-wide TPU packing in
    :mod:`repro.kernels.bitpack`. The hub axis leads so a hub's whole
    footprint (``side[hub]`` — what :meth:`RLCIndex.pr1_cover_all` and
    the delta engine's output diff read) is one contiguous slice.
    """

    def __init__(self, num_mrs: int, num_vertices: int):
        self.num_vertices = num_vertices
        self.words = (num_vertices + 7) // 8
        self.out = np.zeros((num_vertices, num_mrs, self.words), np.uint8)
        self.in_ = np.zeros((num_vertices, num_mrs, self.words), np.uint8)

    def nbytes(self) -> int:
        return self.out.nbytes + self.in_.nbytes

    def size_bytes(self) -> int:
        """Allocation footprint of the mirror (both sides). The dense
        mirror allocates everything up front, so this is also its peak —
        the number `BuildStats.peak_mirror_bytes` reports and the quantity
        the hub-sliced worker mirrors (:mod:`repro.build.parallel.mirror`)
        exist to shrink."""
        return self.nbytes()

    def set1(self, side: np.ndarray, c: int, hub: int, y: int) -> None:
        side[hub, c, y >> 3] |= _BIT[y & 7]

    def set_many(self, side: np.ndarray, c: int, hub: int, ys) -> None:
        if len(ys) <= 16:                      # bulk update doesn't pay
            row = side[hub, c]
            for y in ys:
                row[y >> 3] |= _BIT[y & 7]
            return
        row = np.zeros(self.num_vertices, np.uint8)
        row[np.asarray(ys)] = 1
        side[hub, c] |= np.packbits(row, bitorder="little")[:self.words]


def merge_join_rows(out_hub: np.ndarray, out_mr: np.ndarray,
                    in_hub: np.ndarray, in_mr: np.ndarray,
                    aid: np.ndarray, s: int, t: int, mr_id: int) -> bool:
    """Algorithm 1 on two explicit aid-sorted entry rows.

    ``out_hub/out_mr`` is L_out(s) and ``in_hub/in_mr`` is L_in(t), both in
    the frozen ``(aid(hub), mr_id)`` order. Factored out of
    :meth:`FrozenRLCIndex.query` so a shard that owns only ``t``'s in-side
    can join against an out-row digest shipped from ``s``'s owning shard
    (:mod:`repro.service.sharded`) — the rows don't have to come from the
    same index object, only from the same ``aid`` space.
    """
    # Case 2: direct entries.
    if (np.any((out_hub == t) & (out_mr == mr_id))
            or np.any((in_hub == s) & (in_mr == mr_id))):
        return True
    # Case 1: merge join on aid(hub).
    a, b = 0, 0
    while a < len(out_hub) and b < len(in_hub):
        ka, kb = aid[out_hub[a]], aid[in_hub[b]]
        if ka < kb:
            a += 1
        elif kb < ka:
            b += 1
        else:
            # same hub: scan the equal-aid runs for the queried MR.
            hub_aid = ka
            a2 = a
            found_a = found_b = False
            while a2 < len(out_hub) and aid[out_hub[a2]] == hub_aid:
                found_a |= out_mr[a2] == mr_id
                a2 += 1
            b2 = b
            while b2 < len(in_hub) and aid[in_hub[b2]] == hub_aid:
                found_b |= in_mr[b2] == mr_id
                b2 += 1
            if found_a and found_b:
                return True
            a, b = a2, b2
    return False


@dataclass
class RLCIndex:
    """A (possibly partially built) RLC index for a graph with ``n`` vertices.

    ``aid`` maps vertex -> 1-based access id (IN-OUT order); entries are kept
    in dictionaries during construction and optionally frozen to flat arrays.
    """

    num_vertices: int
    k: int
    aid: np.ndarray  # (n,) int64, 1-based access ids
    l_in: List[EntryMap] = field(default_factory=list)
    l_out: List[EntryMap] = field(default_factory=list)
    # optional packed coverage mirror (attached by the batched builders)
    _mirror: Optional[BitMirror] = field(default=None, repr=False,
                                         compare=False)
    _mr_ids: Optional[Dict[LabelSeq, int]] = field(default=None, repr=False,
                                                   compare=False)

    def __post_init__(self):
        if not self.l_in:
            self.l_in = [dict() for _ in range(self.num_vertices)]
        if not self.l_out:
            self.l_out = [dict() for _ in range(self.num_vertices)]

    # -- construction-time mutation ------------------------------------- #
    def attach_bit_mirror(self, mr_ids: Dict[LabelSeq, int]) -> BitMirror:
        """Attach (and backfill) a :class:`BitMirror` so subsequent
        ``add_out``/``add_in`` calls keep it in sync and the vectorized PR1
        batch queries become available."""
        self._mr_ids = dict(mr_ids)
        self._mirror = BitMirror(len(mr_ids), self.num_vertices)
        for side, maps in ((self._mirror.out, self.l_out),
                           (self._mirror.in_, self.l_in)):
            for y, d in enumerate(maps):
                for hub, mrs in d.items():
                    for mr in mrs:
                        self._mirror.set1(side, self._mr_ids[mr], hub, y)
        return self._mirror

    def add_out(self, v: int, hub: int, mr: LabelSeq) -> None:
        """Record ``(hub, mr)`` in ``L_out(v)`` (v ~~mr^+~~> hub)."""
        self.l_out[v].setdefault(hub, set()).add(mr)
        if self._mirror is not None:
            self._mirror.set1(self._mirror.out, self._mr_ids[mr], hub, v)

    def add_in(self, v: int, hub: int, mr: LabelSeq) -> None:
        """Record ``(hub, mr)`` in ``L_in(v)`` (hub ~~mr^+~~> v)."""
        self.l_in[v].setdefault(hub, set()).add(mr)
        if self._mirror is not None:
            self._mirror.set1(self._mirror.in_, self._mr_ids[mr], hub, v)

    def add_out_many(self, vs: Sequence[int], hub: int, mr: LabelSeq
                     ) -> None:
        """Bulk :meth:`add_out`: one ``(hub, mr)`` entry at every vertex in
        ``vs`` (one batched mirror update instead of |vs| bit pokes)."""
        for v in vs:
            self.l_out[v].setdefault(hub, set()).add(mr)
        if self._mirror is not None and len(vs):
            self._mirror.set_many(self._mirror.out, self._mr_ids[mr], hub,
                                  vs)

    def add_in_many(self, vs: Sequence[int], hub: int, mr: LabelSeq
                    ) -> None:
        """Bulk :meth:`add_in` (see :meth:`add_out_many`)."""
        for v in vs:
            self.l_in[v].setdefault(hub, set()).add(mr)
        if self._mirror is not None and len(vs):
            self._mirror.set_many(self._mirror.in_, self._mr_ids[mr], hub,
                                  vs)

    def has_out(self, v: int, hub: int, mr: LabelSeq) -> bool:
        s = self.l_out[v].get(hub)
        return s is not None and mr in s

    def has_in(self, v: int, hub: int, mr: LabelSeq) -> bool:
        s = self.l_in[v].get(hub)
        return s is not None and mr in s

    # -- Algorithm 1 ------------------------------------------------------ #
    def query(self, s: int, t: int, L: Sequence[int]) -> bool:
        """Algorithm 1. ``L`` must be its own minimum repeat with |L| <= k."""
        L = tuple(L)
        # Case 2: direct entries.
        if self.has_out(s, t, L) or self.has_in(t, s, L):
            return True
        # Case 1: merge join over L_out(s) x L_in(t) on the hub vertex.
        # Dict intersection is semantically identical to the paper's
        # aid-sorted merge join (the frozen/device path uses the sorted
        # layout verbatim); iterate the smaller side.
        out_s, in_t = self.l_out[s], self.l_in[t]
        if len(out_s) > len(in_t):
            for hub, mrs in in_t.items():
                if L in mrs:
                    o = out_s.get(hub)
                    if o is not None and L in o:
                        return True
        else:
            for hub, mrs in out_s.items():
                if L in mrs:
                    i = in_t.get(hub)
                    if i is not None and L in i:
                        return True
        return False

    def explain(self, s: int, t: int, L: Sequence[int],
                mr_id: Optional[int] = None, max_hubs: int = 8) -> dict:
        """Witness-mode Algorithm 1 over the dict layout: the same
        Case-2 / Case-1 decision as :meth:`query`, but returning the
        derivation (see :mod:`repro.obs.explain`). ``mr_id`` only stamps
        the witness — the dict layout joins on MR tuples."""
        from repro.obs.explain import build_witness
        L = tuple(L)
        if mr_id is None and self._mr_ids is not None:
            mr_id = self._mr_ids.get(L)
        return build_witness(
            s, t, mr_id,
            case2_out=self.has_out(s, t, L),
            case2_in=self.has_in(t, s, L),
            out_row=sum(len(ms) for ms in self.l_out[s].values()),
            in_row=sum(len(ms) for ms in self.l_in[t].values()),
            out_candidates=[h for h, ms in self.l_out[s].items()
                            if L in ms],
            in_candidates=[h for h, ms in self.l_in[t].items()
                           if L in ms],
            aid=self.aid, max_hubs=max_hubs)

    # -- vectorized PR1 batch query (Algorithm 2 insert-side) -------------- #
    def pr1_cover_out(self, hub: int, mr: LabelSeq) -> np.ndarray:
        """Packed bitset over ``y`` of ``Query(y, hub, mr^+)`` — the PR1
        predicate a backward KBS of ``hub`` evaluates at every visited
        vertex. Requires an attached bit mirror; a handful of row ORs:
        Case-2 direct rows plus Case-1 through each hub of ``L_in(hub)``.
        """
        m, c = self._mirror, self._mr_ids[mr]
        cov = m.out[hub, c].copy()               # (hub, mr) in L_out(y)
        for x, mrs in self.l_in[hub].items():
            if mr in mrs:
                cov |= m.out[x, c]               # Case 1 via hub x
                cov[x >> 3] |= _BIT[x & 7]       # (y, mr) in L_in(hub)
        return cov

    def pr1_cover_in(self, hub: int, mr: LabelSeq) -> np.ndarray:
        """Symmetric to :meth:`pr1_cover_out`: packed ``Query(hub, y, mr^+)``
        over ``y`` — PR1 for the forward KBS of ``hub``."""
        m, c = self._mirror, self._mr_ids[mr]
        cov = m.in_[hub, c].copy()
        for x, mrs in self.l_out[hub].items():
            if mr in mrs:
                cov |= m.in_[x, c]
                cov[x >> 3] |= _BIT[x & 7]
        return cov

    def pr1_cover_all(self, hub: int, backward: bool = True) -> np.ndarray:
        """(C, W) packed PR1 coverage rows for *every* MR at once — row
        ``c`` equals :meth:`pr1_cover_out` (backward) /
        :meth:`pr1_cover_in` (forward) for ``mr_c``. The batched builders
        fetch this once per (hub, direction) phase; Algorithm 2 guarantees
        the phase's PR1 outcomes depend only on the pre-phase snapshot."""
        m = self._mirror
        side = m.out if backward else m.in_
        row_src = self.l_in[hub] if backward else self.l_out[hub]
        cov = side[hub].copy()
        for x, mrs in row_src.items():
            xb, xbit = x >> 3, _BIT[x & 7]
            for mr in mrs:
                c = self._mr_ids[mr]
                cov[c] |= side[x, c]
                cov[c, xb] |= xbit
        return cov

    def pr1_batch(self, ys: Sequence[int], hub: int, mr: LabelSeq,
                  backward: bool = True) -> np.ndarray:
        """Vectorized PR1: ``[Query(y, hub, mr^+)]`` (backward) or
        ``[Query(hub, y, mr^+)]`` (forward) for every ``y`` in ``ys``.
        Uses the packed mirror when attached, else falls back to per-query
        Algorithm 1."""
        ys = np.asarray(ys, dtype=np.int64)
        if self._mirror is not None:
            cov = (self.pr1_cover_out(hub, mr) if backward
                   else self.pr1_cover_in(hub, mr))
            return (cov[ys >> 3] & _BIT[ys & 7]) != 0
        if backward:
            return np.array([self.query(int(y), hub, mr) for y in ys],
                            dtype=bool)
        return np.array([self.query(hub, int(y), mr) for y in ys],
                        dtype=bool)

    # -- stats & invariants ------------------------------------------------ #
    def num_entries(self) -> int:
        return (sum(len(m) for d in self.l_in for m in d.values())
                + sum(len(m) for d in self.l_out for m in d.values()))

    def size_bytes(self) -> int:
        """Paper-comparable size: each entry = 4B vid + k bytes of labels."""
        per_entry = 4 + self.k
        return self.num_entries() * per_entry

    def is_condensed(self) -> bool:
        """Definition 5: no direct entry is also derivable via a 2-hop pair."""
        for t in range(self.num_vertices):
            for s, mrs in self.l_in[t].items():
                if s == t:
                    continue
                for L in mrs:
                    for hub, o_mrs in self.l_out[s].items():
                        if hub in (s, t):
                            continue
                        if L in o_mrs and L in self.l_in[t].get(hub, ()):
                            return False
        for s in range(self.num_vertices):
            for t, mrs in self.l_out[s].items():
                if s == t:
                    continue
                for L in mrs:
                    for hub, i_mrs in self.l_in[t].items():
                        if hub in (s, t):
                            continue
                        if L in i_mrs and L in self.l_out[s].get(hub, ()):
                            return False
        return True

    # -- frozen merge-join layout ------------------------------------------ #
    def freeze(self, mr_ids: Dict[LabelSeq, int]) -> "FrozenRLCIndex":
        return FrozenRLCIndex.from_index(self, mr_ids)


@dataclass
class FrozenRLCIndex:
    """Aid-sorted flat layout of an :class:`RLCIndex` (paper §V-C query cost).

    Per direction: CSR over vertices; per vertex a run of entries sorted by
    ``(aid(hub), mr_id)`` — exactly the order Algorithm 1's merge join
    expects. This layout feeds the batched JAX query engine.
    """

    num_vertices: int
    k: int
    aid: np.ndarray
    out_indptr: np.ndarray  # (n+1,)
    out_hub: np.ndarray     # (#out,) hub vertex ids
    out_mr: np.ndarray      # (#out,) dense MR ids
    in_indptr: np.ndarray
    in_hub: np.ndarray
    in_mr: np.ndarray

    @staticmethod
    def _flatten(maps: List[EntryMap], aid: np.ndarray,
                 mr_ids: Dict[LabelSeq, int]):
        indptr = np.zeros(len(maps) + 1, dtype=np.int64)
        hubs: List[int] = []
        mrs: List[int] = []
        for v, d in enumerate(maps):
            rows = sorted(
                ((int(aid[h]), mr_ids[m], h) for h, ms in d.items()
                 for m in ms))
            indptr[v + 1] = indptr[v] + len(rows)
            hubs.extend(r[2] for r in rows)
            mrs.extend(r[1] for r in rows)
        return (indptr, np.asarray(hubs, dtype=np.int32),
                np.asarray(mrs, dtype=np.int32))

    @staticmethod
    def from_index(idx: RLCIndex, mr_ids: Dict[LabelSeq, int]
                   ) -> "FrozenRLCIndex":
        oi, oh, om = FrozenRLCIndex._flatten(idx.l_out, idx.aid, mr_ids)
        ii, ih, im = FrozenRLCIndex._flatten(idx.l_in, idx.aid, mr_ids)
        return FrozenRLCIndex(idx.num_vertices, idx.k, idx.aid,
                              oi, oh, om, ii, ih, im)

    @staticmethod
    def _row_sorted(d: EntryMap, aid: np.ndarray,
                    mr_ids: Dict[LabelSeq, int]):
        rows = sorted(((int(aid[h]), mr_ids[m], h) for h, ms in d.items()
                       for m in ms))
        return (np.asarray([r[2] for r in rows], dtype=np.int32),
                np.asarray([r[1] for r in rows], dtype=np.int32))

    def patch_rows(self, index: RLCIndex, mr_ids: Dict[LabelSeq, int],
                   dirty_out, dirty_in, aid=None) -> "FrozenRLCIndex":
        """Re-freeze ``index`` reusing this frozen layout's clean rows.

        ``dirty_out``/``dirty_in`` are the vertex sets (any container
        supporting ``in``) whose entry rows may differ from this frozen
        snapshot — rows whose entries changed, plus rows whose aid sort
        order may have shifted (they hold a hub whose access rank moved).
        Dirty rows are re-derived from ``index``'s dict layout; clean rows
        are copied from this object's flat arrays, skipping the per-entry
        python sort that dominates a full :meth:`RLCIndex.freeze`. The
        result is bit-identical to ``index.freeze(mr_ids)`` provided the
        dirty sets cover every changed/re-ordered row — the delta-build
        property suite enforces exactly that.

        ``aid``: the hub sort order of the result; defaults to
        ``index.aid`` (the current access order). Algorithm 1 only needs
        *one consistent* total order on both sides of the merge join, so
        a caller that mixes patched and unpatched row ranges across hosts
        (the sharded service) passes ``self.aid`` instead — the stable
        order it froze with — and then rows whose entries did not change
        never need re-freezing at all, whatever happened to access ranks.
        """
        aid = np.asarray(index.aid if aid is None else aid)

        def patch(old_indptr, old_hub, old_mr, maps, dirty):
            n = len(maps)
            hubs, mrs = [], []
            indptr = np.zeros(n + 1, dtype=np.int64)
            for v in range(n):
                if v in dirty:
                    h, m = self._row_sorted(maps[v], aid, mr_ids)
                else:
                    lo, hi = old_indptr[v], old_indptr[v + 1]
                    h, m = old_hub[lo:hi], old_mr[lo:hi]
                indptr[v + 1] = indptr[v] + len(h)
                hubs.append(h)
                mrs.append(m)
            cat = lambda parts: (np.concatenate(parts)  # noqa: E731
                                 if parts else np.empty(0, np.int32))
            return indptr, cat(hubs).astype(np.int32), \
                cat(mrs).astype(np.int32)

        oi, oh, om = patch(self.out_indptr, self.out_hub, self.out_mr,
                           index.l_out, dirty_out)
        ii, ih, im = patch(self.in_indptr, self.in_hub, self.in_mr,
                           index.l_in, dirty_in)
        return FrozenRLCIndex(index.num_vertices, index.k, aid,
                              oi, oh, om, ii, ih, im)

    def row_out(self, s: int) -> Tuple[np.ndarray, np.ndarray]:
        """Zero-copy ``(hub, mr)`` view of L_out(s), aid-sorted."""
        o0, o1 = self.out_indptr[s], self.out_indptr[s + 1]
        return self.out_hub[o0:o1], self.out_mr[o0:o1]

    def row_in(self, t: int) -> Tuple[np.ndarray, np.ndarray]:
        """Zero-copy ``(hub, mr)`` view of L_in(t), aid-sorted."""
        i0, i1 = self.in_indptr[t], self.in_indptr[t + 1]
        return self.in_hub[i0:i1], self.in_mr[i0:i1]

    def query(self, s: int, t: int, mr_id: int) -> bool:
        """Algorithm 1 over the flat layout (true aid-ordered merge join)."""
        oh, om = self.row_out(s)
        ih, im = self.row_in(t)
        return merge_join_rows(oh, om, ih, im, self.aid, s, t, mr_id)

    def explain(self, s: int, t: int, mr_id: int,
                max_hubs: int = 8) -> dict:
        """Witness-mode :meth:`query`: the derivation Algorithm 1's
        merge join performs over this layout's two CSR rows (see
        :mod:`repro.obs.explain` for the witness shape)."""
        from repro.obs.explain import explain_rows
        oh, om = self.row_out(int(s))
        ih, im = self.row_in(int(t))
        return explain_rows(oh, om, ih, im, int(s), int(t), int(mr_id),
                            aid=self.aid, max_hubs=max_hubs)

    def query_batch(self, s: Sequence[int], t: Sequence[int],
                    mr_id: Sequence[int], witness: bool = False):
        """Vectorized-per-query Algorithm 1 over the flat numpy layout.

        The frozen-numpy serving backend: no device transfer, no padding —
        each query touches only its two CSR rows. With ``witness=True``
        returns ``(answers, witnesses)`` — one :meth:`explain` record per
        query — instead of the bare answer array (opt-in: the witness
        walk is strictly more work than the merge join).
        """
        s = np.asarray(s)
        t = np.asarray(t)
        mr_id = np.asarray(mr_id)
        out = np.zeros(len(s), dtype=bool)
        for q in range(len(s)):
            out[q] = self.query(int(s[q]), int(t[q]), int(mr_id[q]))
        if witness:
            ws = [self.explain(int(s[q]), int(t[q]), int(mr_id[q]))
                  for q in range(len(s))]
            return out, ws
        return out

    @property
    def max_row(self) -> int:
        return int(max(np.max(np.diff(self.out_indptr), initial=0),
                       np.max(np.diff(self.in_indptr), initial=0)))

    # -- shard slicing ----------------------------------------------------- #
    def num_entries(self) -> int:
        return len(self.out_hub) + len(self.in_hub)

    def size_bytes(self) -> int:
        """Paper-comparable size (matches :meth:`RLCIndex.size_bytes`)."""
        return self.num_entries() * (4 + self.k)

    def entry_weights(self) -> np.ndarray:
        """Per-vertex entry counts (out + in) — the shard planner's balance
        weight."""
        return (np.diff(self.out_indptr) + np.diff(self.in_indptr))

    def slice_rows(self, lo: int, hi: int) -> "FrozenRLCIndex":
        """Zero-copy shard slice owning vertex rows ``[lo, hi)``.

        The result keeps global vertex ids (``num_vertices``/``aid`` are
        shared, not re-numbered): rows inside the range are numpy *views* of
        this index's entry arrays (rows are contiguous because vertices
        are), rows outside are empty. Queries with both endpoints in range
        behave exactly like on the full index; a query whose ``s`` is
        outside the range sees an empty out-row — that is the two-sided
        routing contract: the caller must ship s's out-row digest in via
        :func:`merge_join_rows` (or the device-side equivalent) instead.
        """
        if not (0 <= lo <= hi <= self.num_vertices):
            raise ValueError(
                f"slice [{lo}, {hi}) out of range "
                f"[0, {self.num_vertices}]")

        def cut(indptr, hub, mr):
            base0, base1 = int(indptr[lo]), int(indptr[hi])
            new = np.clip(indptr, base0, base1) - base0
            return new, hub[base0:base1], mr[base0:base1]

        oi, oh, om = cut(self.out_indptr, self.out_hub, self.out_mr)
        ii, ih, im = cut(self.in_indptr, self.in_hub, self.in_mr)
        return FrozenRLCIndex(self.num_vertices, self.k, self.aid,
                              oi, oh, om, ii, ih, im)
