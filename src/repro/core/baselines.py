"""Baseline evaluators (paper §VI-a): NFA-guided BFS, BiBFS, and ETC.

The RLC constraint ``L^+`` compiles to a cyclic automaton over positions
``{0..m-1}``; an online query is a BFS over the product space
``V x positions``. These evaluators double as the *oracle* in tests —
they are exact under arbitrary-path semantics because the product space is
finite. A small NFA class additionally supports concatenations of plus-
blocks such as the paper's extended query Q4 = ``a+ ∘ b+``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Sequence, Set, Tuple


from .graph import LabeledGraph
from .minimum_repeat import LabelSeq, minimum_repeat


# --------------------------------------------------------------------- #
# L^+ product-automaton traversals (the paper's BFS / BiBFS baselines)
# --------------------------------------------------------------------- #
def bfs_rlc(g: LabeledGraph, s: int, t: int, L: Sequence[int]) -> bool:
    """Forward BFS over ``V x {0..m-1}``; true iff s ~~L+~~> t."""
    L = tuple(L)
    m = len(L)
    seen = {(s, 0)}
    q = deque([(s, 0)])
    while q:
        x, p = q.popleft()
        for y in g.out_neighbors_with_label(x, L[p]).tolist():
            p2 = (p + 1) % m
            if p2 == 0 and y == t:
                return True
            if (y, p2) not in seen:
                seen.add((y, p2))
                q.append((y, p2))
    return False


def bibfs_rlc(g: LabeledGraph, s: int, t: int, L: Sequence[int]) -> bool:
    """Bidirectional BFS over the product automaton (expand smaller side).

    Forward state ``(v, p)``: consumed ``p (mod m)`` labels of ``L``-cycles
    from ``s``. Backward state ``(v, p)``: a path ``v -> t`` consumes labels
    ``L[p:]`` then whole cycles. Meeting at an identical state closes a path
    whose total consumption is a multiple of ``m``; the zero-length meet at
    ``s == t`` is discounted by seeding *after* one expansion step each.
    """
    L = tuple(L)
    m = len(L)
    # One-step-expanded seeds avoid the trivial s==t zero-length match.
    fwd: Set[Tuple[int, int]] = set()
    fq: deque = deque()
    for y in g.out_neighbors_with_label(s, L[0]).tolist():
        st = (y, 1 % m)
        if st not in fwd:
            if st == (t, 0):
                return True
            fwd.add(st)
            fq.append(st)
    bwd: Set[Tuple[int, int]] = set()
    bq: deque = deque()
    for x in g.in_neighbors_with_label(t, L[m - 1]).tolist():
        st = (x, m - 1)
        if st not in bwd:
            bwd.add(st)
            bq.append(st)
    if fwd & bwd or (s, 0) in bwd:
        return True
    while fq and bq:
        if len(fq) <= len(bq):
            for _ in range(len(fq)):
                x, p = fq.popleft()
                for y in g.out_neighbors_with_label(x, L[p]).tolist():
                    st = (y, (p + 1) % m)
                    if st in bwd or st == (t, 0):
                        return True
                    if st not in fwd:
                        fwd.add(st)
                        fq.append(st)
        else:
            for _ in range(len(bq)):
                y, p = bq.popleft()
                pprev = (p - 1) % m
                for x in g.in_neighbors_with_label(y, L[pprev]).tolist():
                    st = (x, pprev)
                    if st in fwd or st == (s, 0):
                        return True
                    if st not in bwd:
                        bwd.add(st)
                        bq.append(st)
    return False


# --------------------------------------------------------------------- #
# Generic small NFA (for extended queries, e.g. Q4 = a+ ∘ b+)
# --------------------------------------------------------------------- #
@dataclass
class NFA:
    """Label-transition NFA; ``delta[state][label] -> set of states``."""

    num_states: int
    delta: List[Dict[int, Set[int]]]
    start: FrozenSet[int]
    accept: FrozenSet[int]

    @staticmethod
    def from_plus_blocks(blocks: Sequence[Sequence[int]]) -> "NFA":
        """NFA for ``(B1)^+ ∘ (B2)^+ ∘ ...`` where each block is a label
        concatenation. State = (block, position); each block must complete
        at least one full repeat before moving to the next block."""
        delta: List[Dict[int, Set[int]]] = []
        offsets = []
        for b in blocks:
            offsets.append(len(delta))
            for _ in b:
                delta.append({})
        # boundary states: entering block i at position 0
        n = len(delta)
        accept_state = n
        delta.append({})  # explicit accept sink (no out-transitions needed)
        for bi, b in enumerate(blocks):
            off = offsets[bi]
            m = len(b)
            for p, lab in enumerate(b):
                src = off + p
                dsts = delta[src].setdefault(lab, set())
                if p + 1 < m:
                    dsts.add(off + p + 1)
                else:
                    # completed a repeat of block bi: loop, advance, or accept
                    dsts.add(off)  # another repeat
                    if bi + 1 < len(blocks):
                        dsts.add(offsets[bi + 1])  # start next block
                    if bi == len(blocks) - 1:
                        dsts.add(accept_state)
        return NFA(num_states=n + 1, delta=delta,
                   start=frozenset({offsets[0]}),
                   accept=frozenset({accept_state}))

    def step(self, states: Set[int], label: int) -> Set[int]:
        out: Set[int] = set()
        for s in states:
            out |= self.delta[s].get(label, set())
        return out


def bfs_nfa(g: LabeledGraph, s: int, t: int, nfa: NFA) -> bool:
    """NFA-guided BFS (paper §III-B first naive approach, also used for
    extended queries). True iff an s->t path spells a word the NFA accepts."""
    seen: Set[Tuple[int, int]] = {(s, q) for q in nfa.start}
    dq = deque(seen)
    while dq:
        x, qs = dq.popleft()
        nbrs, labs = g.out_edges(x)
        for y, lab in zip(nbrs.tolist(), labs.tolist()):
            for q2 in nfa.delta[qs].get(lab, ()):  # type: ignore[arg-type]
                if q2 in nfa.accept and y == t:
                    return True
                if (y, q2) not in seen:
                    seen.add((y, q2))
                    dq.append((y, q2))
    return False


def rlc_index_plus_traversal(index, g: LabeledGraph, s: int, t: int,
                             blocks: Sequence[Sequence[int]]) -> bool:
    """Paper §VI-C Q4 technique: evaluate ``(B1)^+ ∘ (B2)^+ ∘ ...`` with the
    RLC index answering each ``B_i^+`` hop instead of a graph BFS.

    For a non-final block the next boundary frontier is seeded from the
    index itself: hubs ``x`` with ``(x, B_i) in L_out(u)`` are witnessed
    ``B_i^+``-reachable, and every vertex ``w`` whose ``L_in(w)`` row joins
    the frontier under ``B_i`` is added via Case-1/Case-2 checks. The final
    block is a single batch of index lookups against ``t``.
    """
    frontier: Set[int] = {s}
    for bi, b in enumerate(blocks):
        L = tuple(b)
        if bi == len(blocks) - 1:
            return any(index.query(u, t, L) for u in frontier)
        nxt: Set[int] = set()
        for u in frontier:
            # direct witnesses: hubs with (hub, L) in L_out(u)
            for hub, mrs in index.l_out[u].items():
                if L in mrs:
                    nxt.add(hub)
        for w in range(g.num_vertices):
            if w not in nxt and any(index.query(u, w, L) for u in frontier):
                nxt.add(w)
        frontier = nxt
        if not frontier:
            return False
    return False


# --------------------------------------------------------------------- #
# ETC — extended transitive closure (paper §VI-a baseline)
# --------------------------------------------------------------------- #
class ETC:
    """Extended transitive closure: hashmap ``(u, v) -> set of k-MRs``.

    Built by a forward KBS from every vertex with NO pruning rules —
    exactly the paper's ETC. Doubles as the ground-truth ``S^k``.
    """

    def __init__(self, g: LabeledGraph, k: int):
        self.g = g
        self.k = k
        self.table: Dict[Tuple[int, int], Set[LabelSeq]] = {}
        self._build()

    def _build(self) -> None:
        for v in range(self.g.num_vertices):
            self._forward_kbs(int(v))

    def _record(self, u: int, y: int, L: LabelSeq) -> None:
        self.table.setdefault((u, y), set()).add(L)

    def _forward_kbs(self, v: int) -> None:
        k = self.k
        seen: Set[Tuple[int, LabelSeq]] = {(v, ())}
        q: deque = deque([(v, ())])
        kernels: Dict[LabelSeq, Set[int]] = {}
        while q:
            x, seq = q.popleft()
            nbrs, labs = self.g.out_edges(x)
            for y, lab in zip(nbrs.tolist(), labs.tolist()):
                seq2 = seq + (lab,)
                if (y, seq2) in seen:
                    continue
                seen.add((y, seq2))
                L = minimum_repeat(seq2)
                if len(L) <= k:
                    self._record(v, y, L)
                    kernels.setdefault(L, set()).add(y)
                if len(seq2) < k:
                    q.append((y, seq2))
        for L, seeds in kernels.items():
            m = len(L)
            visited: Set[Tuple[int, int]] = {(x, 0) for x in seeds}
            dq: deque = deque(visited)
            while dq:
                x, p = dq.popleft()
                for y in self.g.out_neighbors_with_label(x, L[p]).tolist():
                    p2 = (p + 1) % m
                    if (y, p2) in visited:
                        continue
                    if p2 == 0:
                        self._record(v, y, L)
                    visited.add((y, p2))
                    dq.append((y, p2))

    # -- queries --------------------------------------------------------- #
    def s_k(self, u: int, v: int) -> Set[LabelSeq]:
        return self.table.get((u, v), set())

    def query(self, s: int, t: int, L: Sequence[int]) -> bool:
        return tuple(L) in self.table.get((s, t), ())

    def num_entries(self) -> int:
        return sum(len(v) for v in self.table.values())

    def size_bytes(self) -> int:
        # hashmap entry: 8B key + k bytes per recorded MR (paper-comparable)
        return len(self.table) * 8 + self.num_entries() * self.k
