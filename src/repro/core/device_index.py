"""Batched RLC query evaluation on device (serving path).

The frozen index is laid out as padded per-vertex rows sorted by
``(aid(hub), mr_id)`` — Algorithm 1's merge-join order. A query batch
``(s, t, mr)`` evaluates Case 2 (direct entry) and Case 1 (hub join) with
pure vectorized compares; the hot loop optionally dispatches to the Pallas
merge-join kernel (:mod:`repro.kernels.mergejoin`).

Row padding uses hub id ``-1`` (never matches a real hub / query vertex).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .constants import PAD
from .minimum_repeat import LabelSeq, mr_id_space
from .rlc_index import FrozenRLCIndex, RLCIndex


@dataclass
class DeviceIndex:
    """Padded dense layout: (n, E) hub-id and mr-id arrays per direction.

    Two query formulations (EXPERIMENTS.md §Perf, cell rlc-query-1m):
      * dense — (E x E) broadcast join per query (VPU-friendly inside the
        Pallas kernel where the tile stays in VMEM);
      * sorted — rows re-encoded as ascending ``hub * C + mr`` keys; the
        join is a vectorized ``searchsorted`` intersection, moving (Q, E)
        instead of (Q, E, E) through HBM — the XLA-lowered serving path.

    With ``row_lo > 0`` the arrays hold only the vertex-row window
    ``[row_lo, row_lo + rows)`` (a shard's slice): query/hub *ids* stay
    global, only row storage is windowed — a shard's device memory then
    really is ~1/S of the whole index. Callers must only query vertices
    inside the window (the sharded router's contract).
    """

    num_vertices: int
    k: int
    row_len: int
    out_hub: jax.Array  # (rows, E) int32, PAD-filled
    out_mr: jax.Array   # (rows, E) int32
    in_hub: jax.Array
    in_mr: jax.Array
    mr_ids: Dict[LabelSeq, int]
    num_mrs: int = 0
    out_key: Optional[jax.Array] = None  # (rows, E) int32 sorted asc
    in_key: Optional[jax.Array] = None
    row_lo: int = 0     # first vertex id stored; ids below/above are
                        # outside this window (other shards)

    @staticmethod
    def from_index(idx: RLCIndex, num_labels: int,
                   row_len: Optional[int] = None,
                   pad_to_multiple: int = 8) -> "DeviceIndex":
        ids = mr_id_space(num_labels, idx.k)
        return DeviceIndex.from_frozen(idx.freeze(ids), ids,
                                       row_len=row_len,
                                       pad_to_multiple=pad_to_multiple)

    @staticmethod
    def from_frozen(frozen: FrozenRLCIndex, mr_ids: Dict[LabelSeq, int],
                    row_len: Optional[int] = None,
                    pad_to_multiple: int = 8,
                    rows: Optional[Tuple[int, int]] = None) -> "DeviceIndex":
        """Device transfer of an already-frozen index (the service path
        freezes once and reuses the CSR layout for the numpy backend).

        ``rows=(lo, hi)`` packs only that vertex-row window — pair it with
        :meth:`FrozenRLCIndex.slice_rows` so a shard's device arrays cover
        just the rows it owns instead of full height.
        """
        E = row_len or max(1, frozen.max_row)
        E = ((E + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple
        lo, hi = (0, frozen.num_vertices) if rows is None else rows
        if not (0 <= lo <= hi <= frozen.num_vertices):
            raise ValueError(
                f"rows [{lo}, {hi}) out of range "
                f"[0, {frozen.num_vertices}]")

        def pack(indptr, hub, mr):
            H = np.full((hi - lo, E), PAD, np.int32)
            M = np.full((hi - lo, E), PAD, np.int32)
            for v in range(lo, hi):
                a, b = indptr[v], indptr[v + 1]
                ln = min(b - a, E)
                H[v - lo, :ln] = hub[a:a + ln]
                M[v - lo, :ln] = mr[a:a + ln]
            return jnp.asarray(H), jnp.asarray(M)

        oh, om = pack(frozen.out_indptr, frozen.out_hub, frozen.out_mr)
        ih, im = pack(frozen.in_indptr, frozen.in_hub, frozen.in_mr)
        C = len(mr_ids)

        def keys(hub, mr):
            h = np.asarray(hub)
            m = np.asarray(mr)
            key = np.where(h == PAD, np.iinfo(np.int32).max,
                           h.astype(np.int64) * C + m).astype(np.int32)
            return jnp.asarray(np.sort(key, axis=1))

        return DeviceIndex(frozen.num_vertices, frozen.k, E, oh, om, ih, im,
                           mr_ids, C, keys(oh, om), keys(ih, im), lo)

    # ---------------------------------------------------------------- #
    def query_batch(self, s: np.ndarray, t: np.ndarray, mr: np.ndarray,
                    use_pallas: bool = False,
                    method: str = "dense") -> np.ndarray:
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        mr = jnp.asarray(mr, jnp.int32)
        if use_pallas:
            from repro.kernels import ops
            out = ops.mergejoin_query(
                self.out_hub, self.out_mr, self.in_hub, self.in_mr,
                s, t, mr, row_base_out=self.row_lo, row_base_in=self.row_lo)
        elif method == "sorted":
            out = _query_batch_sorted_rows(
                self.out_key, self.in_key, s - self.row_lo,
                t - self.row_lo, s, t, mr, self.num_mrs)
        else:
            out = _query_batch_rows(self.out_hub, self.out_mr, self.in_hub,
                                    self.in_mr, s - self.row_lo,
                                    t - self.row_lo, s, t, mr)
        return np.asarray(out)

    def query(self, s: int, t: int, L: Sequence[int]) -> bool:
        c = self.mr_ids.get(tuple(L))
        if c is None:
            return False
        return bool(self.query_batch(np.array([s]), np.array([t]),
                                     np.array([c]))[0])

    def explain_batch(self, s: np.ndarray, t: np.ndarray, mr: np.ndarray,
                      max_hubs: int = 8) -> list:
        """Witness mode for the device join path: per query, the
        derivation over exactly the padded row digests the kernels join
        (gathered host-side, PAD slots dropped). Device rows carry no
        access-id table, so join hubs report ``aid: null`` and sort by
        vertex id; row lengths reflect the ``row_len`` truncation the
        device layout actually serves with."""
        from repro.obs.explain import explain_rows
        s = np.asarray(s)
        t = np.asarray(t)
        mr = np.asarray(mr)
        oh, om = self.gather_out_rows(s)
        ih, im = self.gather_in_rows(t)
        oh, om = np.asarray(oh), np.asarray(om)
        ih, im = np.asarray(ih), np.asarray(im)
        return [explain_rows(oh[q], om[q], ih[q], im[q],
                             int(s[q]), int(t[q]), int(mr[q]),
                             pad=PAD, max_hubs=max_hubs)
                for q in range(len(s))]

    # -- shard scatter/gather helpers -------------------------------------- #
    def gather_out_rows(self, s: np.ndarray) -> Tuple[jax.Array, jax.Array]:
        """Padded ``(Q, E)`` out-row digests for a batch of source vertices
        — what a shard ships to the in-side owner for a cross-shard join
        (:func:`join_rows`). ``s`` is in global vertex ids."""
        s = jnp.asarray(s, jnp.int32) - self.row_lo
        return self.out_hub[s], self.out_mr[s]

    def gather_in_rows(self, t: np.ndarray) -> Tuple[jax.Array, jax.Array]:
        t = jnp.asarray(t, jnp.int32) - self.row_lo
        return self.in_hub[t], self.in_mr[t]


@jax.jit
def join_rows(oh, om, ih, im, s, t, mr):
    """Batched Algorithm 1 on pre-gathered rows.

    ``oh/om`` are (Q, Eo) out-rows of each query's ``s``; ``ih/im`` are
    (Q, Ei) in-rows of each query's ``t`` (Eo and Ei may differ — e.g. two
    shards with different row paddings). Case 2 via direct compares, Case 1
    via an (Eo x Ei) broadcast join (rows are aid-sorted; the dense compare
    is the merge join's VPU-friendly analog). Separated from the row gather
    so the sharded fan-out path can join a shipped digest against local
    in-rows without materializing one global index.
    """
    q_mr = mr[:, None]
    case2 = jnp.any((oh == t[:, None]) & (om == q_mr), axis=1) | \
        jnp.any((ih == s[:, None]) & (im == q_mr), axis=1)
    o_ok = (om == q_mr) & (oh != PAD)            # (Q, Eo)
    i_ok = (im == q_mr) & (ih != PAD)            # (Q, Ei)
    join = (oh[:, :, None] == ih[:, None, :]) & \
        o_ok[:, :, None] & i_ok[:, None, :]      # (Q, Eo, Ei)
    case1 = jnp.any(join, axis=(1, 2))
    return case2 | case1


@jax.jit
def _query_batch_rows(out_hub, out_mr, in_hub, in_mr, s_row, t_row,
                      s, t, mr):
    """Row-windowed batched Algorithm 1: gather by *storage* row index
    (``s_row = s - row_lo``), compare by global vertex id — the shard
    layouts store a window of rows but keep the global id space."""
    return join_rows(out_hub[s_row], out_mr[s_row],
                     in_hub[t_row], in_mr[t_row], s, t, mr)


@jax.jit
def _query_batch_ref(out_hub, out_mr, in_hub, in_mr, s, t, mr):
    """Reference batched Algorithm 1 (also the Pallas kernel oracle):
    gather rows out[s_q], in[t_q], then :func:`join_rows`. Full-height
    (row_lo = 0) layout form, kept for the distributed/dryrun harnesses."""
    return join_rows(out_hub[s], out_mr[s], in_hub[t], in_mr[t], s, t, mr)


@jax.jit
def _query_batch_sorted_rows(out_key, in_key, s_row, t_row, s, t, mr,
                             num_mrs):
    """Sorted-key intersection join: O(E log E) per query, (Q, E) HBM
    traffic (§Perf iteration 1 on rlc-query-1m). Key = hub * C + mr;
    PAD rows sort to INT32_MAX and never match. Rows are gathered by
    storage index; key compares use global ids."""
    ok = out_key[s_row]                   # (Q, E) ascending
    ik = in_key[t_row]
    q_mr = mr[:, None]
    # Case 1: out keys with the queried mr present in the in row
    pos = jax.vmap(jnp.searchsorted)(ik, ok)        # (Q, E)
    pos = jnp.minimum(pos, ik.shape[1] - 1)
    hit = jnp.take_along_axis(ik, pos, axis=1) == ok
    mr_match = (ok % num_mrs) == q_mr
    big = jnp.iinfo(jnp.int32).max
    case1 = jnp.any(hit & mr_match & (ok != big), axis=1)
    # Case 2: direct entries (t, mr) in L_out(s) / (s, mr) in L_in(t)
    kt = (t * num_mrs + mr)[:, None]
    ks = (s * num_mrs + mr)[:, None]
    p2 = jax.vmap(jnp.searchsorted)(ok, kt[:, 0][:, None])
    p2 = jnp.minimum(p2, ok.shape[1] - 1)
    c2a = jnp.take_along_axis(ok, p2, axis=1) == kt
    p3 = jax.vmap(jnp.searchsorted)(ik, ks[:, 0][:, None])
    p3 = jnp.minimum(p3, ik.shape[1] - 1)
    c2b = jnp.take_along_axis(ik, p3, axis=1) == ks
    return case1 | jnp.any(c2a, axis=1) | jnp.any(c2b, axis=1)


@jax.jit
def _query_batch_sorted(out_key, in_key, s, t, mr, num_mrs):
    """Full-height (row_lo = 0) form of the sorted-key join, kept for the
    distributed/dryrun harnesses."""
    return _query_batch_sorted_rows(out_key, in_key, s, t, s, t, mr,
                                    num_mrs)
