"""Back-compat shim — Algorithm 2 now lives in :mod:`repro.build`.

The historical surface (``IndexBuilder``, ``build_rlc_index``,
``build_rlc_index_with_stats``, ``BuildStats``) is re-exported unchanged;
``build_rlc_index(g, k)`` now resolves ``backend="auto"`` (the vectorized
numpy pipeline, bit-identical to the python reference). The faithful
sequential implementation is :class:`repro.build.reference.PythonBackend`;
the stage decomposition and the Algorithm 2 line-36 note moved to
``src/repro/build/README.md``.
"""
from __future__ import annotations

from repro.build import (BuildStats, IndexBuilder, build_rlc_index,
                         build_rlc_index_with_stats)

__all__ = ["BuildStats", "IndexBuilder", "build_rlc_index",
           "build_rlc_index_with_stats"]
