"""Algorithm 2 — the RLC indexing algorithm (paper §V-B), faithful version.

Per vertex ``v`` in IN-OUT access order, a *kernel-based search* (KBS) runs
backward (creating ``L_out`` entries at the vertices it visits) then forward
(creating ``L_in`` entries). Each KBS has two phases:

* **kernel-search** — an exhaustive BFS over (vertex, label-sequence) states
  up to depth ``k``. Every visited state with ``|MR(seq)| <= k`` yields a
  tentative index entry (subject to PR1/PR2) *and* contributes its MR as an
  eager kernel candidate with the visited vertex as a frontier seed.
* **kernel-BFS** — per kernel candidate ``L`` (``m = |L|``), a BFS over the
  product automaton ``V x {0..m-1}`` that only follows ``L``-cyclic label
  transitions. Whenever a full repeat boundary is crossed into vertex ``y``
  (state 0), the entry ``(v, L)`` is inserted at ``y``; if PR1/PR2 prune the
  insertion, **PR3** cuts the whole search subtree behind ``y``.

Pruning rules (backward case; forward is symmetric):
  PR1  skip the entry if ``Query(y, v, L^+)`` already holds on the current
       index snapshot;
  PR2  skip if ``aid(v) > aid(y)`` (the visited vertex is a better hub and
       its own KBS covers the pair);
  PR3  on PR1/PR2 firing *during kernel-BFS*, also skip ``y``'s search
       subtree (Theorem 3 proves completeness is preserved).

Note on the paper's Algorithm 2 listing: line 36 reads
``if i=1 and insert(y,v,L) then continue`` — taken literally that prunes on
*successful* insertion, contradicting PR3's definition, Example 6 and the
Lemma 5 proof, all of which prune when PR1/PR2 *fire* (insert fails). We
follow the prose + proofs (prune on failure); tests validate soundness +
completeness against the product-automaton oracle on thousands of graphs.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .graph import LabeledGraph
from .minimum_repeat import LabelSeq, minimum_repeat
from .rlc_index import RLCIndex


@dataclass
class BuildStats:
    kernel_search_states: int = 0
    kernel_bfs_states: int = 0
    inserted: int = 0
    pruned_pr1: int = 0
    pruned_pr2: int = 0
    pr3_cuts: int = 0


class IndexBuilder:
    """Faithful, sequential Algorithm 2 (the paper's reference semantics)."""

    def __init__(self, graph: LabeledGraph, k: int,
                 use_pr1: bool = True, use_pr2: bool = True,
                 use_pr3: bool = True):
        self.g = graph
        self.k = int(k)
        self.use_pr1 = use_pr1
        self.use_pr2 = use_pr2
        self.use_pr3 = use_pr3
        self.stats = BuildStats()
        self.index = RLCIndex(graph.num_vertices, self.k,
                              graph.access_ids())

    # ------------------------------------------------------------------ #
    def build(self) -> RLCIndex:
        order = self.g.access_order()
        for v in order:
            self._kbs(int(v), backward=True)
            self._kbs(int(v), backward=False)
        return self.index

    # -- insert with PR1/PR2 (paper Algorithm 2, lines 19-24) ----------- #
    def _insert(self, y: int, v: int, L: LabelSeq, backward: bool) -> bool:
        """Try to record hub ``v`` at visited vertex ``y``. Returns True if
        the entry was added, False if pruned (PR1/PR2) — the PR3 signal."""
        idx = self.index
        if self.use_pr2 and idx.aid[v] > idx.aid[y]:
            self.stats.pruned_pr2 += 1
            return False
        if backward:
            s, t = y, v   # entry (v, L) in L_out(y):  y ~~L+~~> v
        else:
            s, t = v, y   # entry (v, L) in L_in(y):   v ~~L+~~> y
        if self.use_pr1 and idx.query(s, t, L):
            self.stats.pruned_pr1 += 1
            return False
        if backward:
            idx.add_out(y, v, L)
        else:
            idx.add_in(y, v, L)
        self.stats.inserted += 1
        return True

    # -- one full KBS from v --------------------------------------------- #
    def _kbs(self, v: int, backward: bool) -> None:
        kernels = self._kernel_search(v, backward)
        for L, frontier in kernels.items():
            self._kernel_bfs(v, L, frontier, backward)

    def _neighbors(self, x: int, backward: bool):
        return (self.g.in_edges(x) if backward else self.g.out_edges(x))

    def _kernel_search(self, v: int, backward: bool
                       ) -> Dict[LabelSeq, Set[int]]:
        """Phase 1: exhaustive BFS to depth k over (vertex, seq) states.

        Inserts entries for every state whose MR has length <= k (PR3 does
        not apply here, paper §V-B) and returns eager kernel candidates:
        ``{L: frontier vertices whose path-so-far equals L^h}``.
        """
        k = self.k
        seen: Set[Tuple[int, LabelSeq]] = {(v, ())}
        frontier: deque = deque([(v, ())])
        kernels: Dict[LabelSeq, Set[int]] = {}
        while frontier:
            x, seq = frontier.popleft()
            nbrs, labs = self._neighbors(x, backward)
            for y, lab in zip(nbrs.tolist(), labs.tolist()):
                seq2 = ((lab,) + seq) if backward else (seq + (lab,))
                state = (y, seq2)
                if state in seen:
                    continue
                seen.add(state)
                self.stats.kernel_search_states += 1
                L = minimum_repeat(seq2)
                if len(L) <= k:
                    # |MR| <= k  =>  seq2 == L^h: a genuine entry AND an
                    # eager kernel candidate seeded at y (repeat boundary).
                    self._insert(y, v, L, backward)
                    kernels.setdefault(L, set()).add(y)
                if len(seq2) < k:
                    frontier.append((y, seq2))
        return kernels

    def _kernel_bfs(self, v: int, L: LabelSeq, seeds: Set[int],
                    backward: bool) -> None:
        """Phase 2: product-automaton BFS guided by ``L^+`` from ``seeds``.

        State ``(y, p)``: ``p`` labels consumed since the last full-repeat
        boundary. Backward search prepends labels, so from state ``p`` the
        expected edge label is ``L[m-1-p]``; forward appends, expecting
        ``L[p]``. Insertion fires when ``p`` wraps to 0 (full repeat).
        """
        m = len(L)
        visited: Set[Tuple[int, int]] = {(x, 0) for x in seeds}
        q: deque = deque(visited)
        while q:
            x, p = q.popleft()
            want = L[m - 1 - p] if backward else L[p]
            nbrs, labs = self._neighbors(x, backward)
            for y, lab in zip(nbrs.tolist(), labs.tolist()):
                if lab != want:
                    continue
                p2 = (p + 1) % m
                if (y, p2) in visited:
                    continue
                self.stats.kernel_bfs_states += 1
                if p2 == 0:
                    if not self._insert(y, v, L, backward):
                        if self.use_pr3:
                            # PR3: cut the subtree behind y (do not expand).
                            self.stats.pr3_cuts += 1
                            visited.add((y, p2))
                            continue
                visited.add((y, p2))
                q.append((y, p2))


def build_rlc_index(graph: LabeledGraph, k: int, **kw) -> RLCIndex:
    return IndexBuilder(graph, k, **kw).build()


def build_rlc_index_with_stats(graph: LabeledGraph, k: int, **kw
                               ) -> Tuple[RLCIndex, BuildStats]:
    b = IndexBuilder(graph, k, **kw)
    idx = b.build()
    return idx, b.stats
