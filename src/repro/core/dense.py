"""TPU-native dense boolean-semiring engine (DESIGN.md §3).

The paper's kernel-BFS guided by ``L^+`` is a BFS over the product automaton
``V x {0..m-1}``; one step is a boolean mat-vec with the label-sliced
adjacency. Batching all sources turns the whole index computation into
boolean *matrix-matrix* products — MXU work. This module provides:

* ``mr_step_matrix``   — ``M_L = A[l1] (x) ... (x) A[lm]`` (OR-AND chain);
* ``plus_closure``     — ``M^+`` by log-doubling (``h <= |V|`` repeats);
* ``DenseEngine``      — ETC-equivalent all-pairs ``S^k`` oracle on device;
* ``build_condensed_device`` — hub-batched pruned 2-hop labeling: the
  paper's Algorithm 2 re-derived as masked matmuls. PR2 is the aid mask;
  PR1 is a vectorized coverage query (one boolean matmul per hub batch);
  batch size 1 reproduces the sequential pruning schedule, larger batches
  trade a few redundant entries for data-parallel throughput (soundness +
  completeness preserved — the PLL-style argument in DESIGN.md §3).

Boolean values ride in float32/bf16 (MXU dtype); OR == saturating add via
``dot > 0``. The inner product is swappable for the Pallas kernel in
:mod:`repro.kernels.bool_semiring`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import LabeledGraph
from .minimum_repeat import LabelSeq, enumerate_mrs, mr_id_space
from .rlc_index import RLCIndex

MatMul = Callable[[jax.Array, jax.Array], jax.Array]


def bool_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """OR-AND semiring product for 0/1 float arrays (reference path)."""
    return (jnp.matmul(a, b, preferred_element_type=jnp.float32) > 0
            ).astype(a.dtype)


def mr_step_matrix(A: jax.Array, mr: Sequence[int],
                   matmul: MatMul = bool_matmul) -> jax.Array:
    """``M_L[u, v] = 1`` iff a path u->v spells exactly ``L``. ``A`` is the
    (|L|, n, n) label-sliced adjacency stack."""
    M = A[mr[0]]
    for lab in mr[1:]:
        M = matmul(M, A[lab])
    return M


def plus_closure(M: jax.Array, n_iters: Optional[int] = None,
                 matmul: MatMul = bool_matmul) -> jax.Array:
    """``M^+ = M | M^2 | ...`` via log-doubling: R_{i+1} = R_i | R_i R_i
    covers powers 1..2^(i+1); minimal repeat count is <= |V|."""
    n = M.shape[-1]
    iters = n_iters if n_iters is not None else max(1, math.ceil(
        math.log2(max(n, 2))))
    R = M
    for _ in range(iters):
        R = jnp.maximum(R, matmul(R, R))
    return R


@partial(jax.jit, static_argnames=("mrs", "matmul"))
def _all_mr_reach(A: jax.Array, mrs: Tuple[LabelSeq, ...],
                  matmul: MatMul = bool_matmul) -> jax.Array:
    """Stack of ``R_L`` for every MR (C, n, n). MRs grouped by length so
    the per-length chains share compiled code."""
    outs = []
    for mr in mrs:
        outs.append(plus_closure(mr_step_matrix(A, mr, matmul),
                                 matmul=matmul))
    return jnp.stack(outs)


@dataclass
class DenseEngine:
    """All-pairs ``S^k`` on device — the TPU analog of the paper's ETC."""

    graph: LabeledGraph
    k: int
    mrs: Tuple[LabelSeq, ...]
    mr_ids: Dict[LabelSeq, int]
    reach: np.ndarray  # (C, n, n) bool — reach[c, u, v] = u ~~mr_c^+~~> v

    @staticmethod
    def build(graph: LabeledGraph, k: int,
              matmul: MatMul = bool_matmul) -> "DenseEngine":
        mrs = enumerate_mrs(graph.num_labels, k)
        A = jnp.asarray(graph.label_adjacency(np.float32))
        R = _all_mr_reach(A, mrs, matmul)
        return DenseEngine(graph, k, mrs, mr_id_space(graph.num_labels, k),
                           np.asarray(R) > 0)

    def query(self, s: int, t: int, L: Sequence[int]) -> bool:
        c = self.mr_ids.get(tuple(L))
        if c is None:
            return False
        return bool(self.reach[c, s, t])

    def s_k(self, u: int, v: int) -> set:
        return {self.mrs[c] for c in range(len(self.mrs))
                if self.reach[c, u, v]}

    def num_true_pairs(self) -> int:
        return int(self.reach.sum())


# ------------------------------------------------------------------ #
# Hub-batched condensed 2-hop build (device Algorithm 2)
# ------------------------------------------------------------------ #
@partial(jax.jit, donate_argnums=(0, 1))
def _hub_batch_step(OUT: jax.Array, IN: jax.Array, R: jax.Array,
                    aid: jax.Array, hubs: jax.Array) -> Tuple[jax.Array,
                                                              jax.Array]:
    """Add entries for one batch of hubs with PR1/PR2 masks.

    OUT[c, y, x] = 1 iff (x, mr_c) in L_out(y);  IN[c, y, x] similarly.
    For hub h (column/row slices of R):
      backward (L_out additions at every y reaching h):
        cand = R[c, :, h] & aid(h) <= aid(y) & ~Query(y, h, mr_c)
      forward (L_in additions at every y reached from h): symmetric.
    Query(s, t, c) = OUT[c,s,t] | IN[c,t,s] | OR_x OUT[c,s,x] & IN[c,t,x].
    """
    dtypef = OUT.dtype
    aid_h = aid[hubs]                                    # (B,)
    pr2 = (aid_h[None, :] <= aid[:, None]).astype(dtypef)  # (n, B) keep-mask

    # ---- backward: entries (h, c) at L_out(y) ----
    reach_to_h = R[:, :, hubs]                           # (C, n, B)
    IN_h = IN[:, hubs, :]                                # (C, B, n)
    # Case-1 coverage: OR_x OUT[c,y,x] & IN[c,h,x]
    cov1 = (jnp.einsum("cyx,cbx->cyb", OUT, IN_h,
                       preferred_element_type=jnp.float32) > 0)
    cov2 = OUT[:, :, hubs] > 0                           # direct (h,c) there
    cov3 = jnp.swapaxes(IN_h, 1, 2)[:, :, :] > 0         # (y, c) in L_in(h)?
    # cov3[c, y, b] = IN[c, h_b, y]: (y, mr) in L_in(h) — Case 2 mirror.
    covered = cov1 | cov2 | cov3
    cand_out = reach_to_h * pr2[None] * (1.0 - covered.astype(dtypef))
    OUT = OUT.at[:, :, hubs].max(cand_out)

    # ---- forward: entries (h, c) at L_in(y) ----
    reach_from_h = jnp.swapaxes(R[:, hubs, :], 1, 2)     # (C, n, B)
    OUT_h = OUT[:, hubs, :]                              # (C, B, n) updated!
    cov1f = (jnp.einsum("cyx,cbx->cyb", IN, OUT_h,
                        preferred_element_type=jnp.float32) > 0)
    cov2f = IN[:, :, hubs] > 0
    cov3f = jnp.swapaxes(OUT_h, 1, 2) > 0                # (t, c) in L_out(h)
    coveredf = cov1f | cov2f | cov3f
    cand_in = reach_from_h * pr2[None] * (1.0 - coveredf.astype(dtypef))
    IN = IN.at[:, :, hubs].max(cand_in)
    return OUT, IN


def build_condensed_device(graph: LabeledGraph, k: int,
                           hub_batch: int = 1,
                           matmul: MatMul = bool_matmul,
                           reach: Optional[np.ndarray] = None
                           ) -> Tuple[RLCIndex, DenseEngine]:
    """Device-side condensed RLC index build (see module docstring)."""
    eng = (DenseEngine(graph, k, enumerate_mrs(graph.num_labels, k),
                       mr_id_space(graph.num_labels, k), reach)
           if reach is not None else DenseEngine.build(graph, k, matmul))
    n, C = graph.num_vertices, len(eng.mrs)
    aid = graph.access_ids()
    order = graph.access_order()
    R = jnp.asarray(eng.reach.astype(np.float32))
    OUT = jnp.zeros((C, n, n), jnp.float32)
    IN = jnp.zeros((C, n, n), jnp.float32)
    aid_j = jnp.asarray(aid, jnp.int32)
    for i in range(0, n, hub_batch):
        hubs = jnp.asarray(order[i:i + hub_batch], jnp.int32)
        OUT, IN = _hub_batch_step(OUT, IN, R, aid_j, hubs)
    OUT_np = np.asarray(OUT) > 0
    IN_np = np.asarray(IN) > 0
    idx = RLCIndex(n, k, aid)
    cs, ys, xs = np.nonzero(OUT_np)
    for c, y, x in zip(cs.tolist(), ys.tolist(), xs.tolist()):
        idx.add_out(y, x, eng.mrs[c])
    cs, ys, xs = np.nonzero(IN_np)
    for c, y, x in zip(cs.tolist(), ys.tolist(), xs.tolist()):
        idx.add_in(y, x, eng.mrs[c])
    return idx, eng
