"""Query-workload generation (paper §VI-c).

Uniformly sample (s, t, L^+) triples, classify each with a bidirectional
product-automaton BFS, and collect 1000 true- and 1000 false-queries.
Constraints L are drawn from the realizable minimum repeats of the graph
(uniform over MR space, as in the paper), biased to length <= k.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .baselines import bibfs_rlc
from .graph import LabeledGraph
from .minimum_repeat import LabelSeq, enumerate_mrs, minimum_repeat


@dataclass
class QuerySet:
    true_queries: List[Tuple[int, int, LabelSeq]]
    false_queries: List[Tuple[int, int, LabelSeq]]

    def all(self) -> List[Tuple[int, int, LabelSeq, bool]]:
        return ([(s, t, L, True) for s, t, L in self.true_queries]
                + [(s, t, L, False) for s, t, L in self.false_queries])


def generate_queries(g: LabeledGraph, k: int, n_true: int = 1000,
                     n_false: int = 1000, seed: int = 0,
                     max_attempts: Optional[int] = None) -> QuerySet:
    rng = np.random.default_rng(seed)
    mrs = enumerate_mrs(g.num_labels, k)
    # restrict to labels that actually occur (otherwise false-queries are
    # trivially false and true-queries unreachable)
    present = np.unique(g.edges[:, 1]) if g.num_edges else np.array([0])
    mrs = [m for m in mrs if all(l in present for l in m)] or list(mrs)
    tq: List[Tuple[int, int, LabelSeq]] = []
    fq: List[Tuple[int, int, LabelSeq]] = []
    attempts = 0
    cap = max_attempts or (n_true + n_false) * 200
    while (len(tq) < n_true or len(fq) < n_false) and attempts < cap:
        attempts += 1
        s = int(rng.integers(g.num_vertices))
        t = int(rng.integers(g.num_vertices))
        L = mrs[int(rng.integers(len(mrs)))]
        ans = bibfs_rlc(g, s, t, L)
        if ans and len(tq) < n_true:
            tq.append((s, t, L))
        elif not ans and len(fq) < n_false:
            fq.append((s, t, L))
    return QuerySet(tq, fq)


def sample_index_queries(frozen, id_to_mr, n: int = 64, seed: int = 0
                         ) -> List[Tuple[int, int, LabelSeq]]:
    """Sample ``(s, t, L)`` queries straight from a frozen index's entries.

    Every entry is a reachability fact — ``(h, c)`` at out-row ``v``
    witnesses ``v ~~mr_c^+~~> h``, and symmetrically on the in side — so
    each sampled entry yields a query the index *must* answer ``True``.
    The index-health auditor (:mod:`repro.obs.audit`) replays these
    against the BiBFS oracle as soundness probes, and they double as a
    hot-row-biased warm set (entry-dense rows are sampled more often),
    the shape the ROADMAP item-5 cache warmers want.
    """
    rng = np.random.default_rng(seed)
    out_n, in_n = len(frozen.out_hub), len(frozen.in_hub)
    total = out_n + in_n
    if total == 0:
        return []
    out: List[Tuple[int, int, LabelSeq]] = []
    for e in rng.integers(total, size=n).tolist():
        if e < out_n:
            v = int(np.searchsorted(frozen.out_indptr, e, "right")) - 1
            hub = int(frozen.out_hub[e])
            L = tuple(id_to_mr[int(frozen.out_mr[e])])
            out.append((v, hub, L))
        else:
            e -= out_n
            v = int(np.searchsorted(frozen.in_indptr, e, "right")) - 1
            hub = int(frozen.in_hub[e])
            L = tuple(id_to_mr[int(frozen.in_mr[e])])
            out.append((hub, v, L))
    return out


def biased_true_queries(g: LabeledGraph, k: int, n: int, seed: int = 0,
                        n_false: Optional[int] = None) -> QuerySet:
    """Seed true queries from short random walks so dense true sets exist
    even on very sparse graphs (used by benchmarks to hit the n_true quota
    quickly without the oracle).

    A walk ``s -> ... -> t`` of length ``<= k`` spelling ``seq`` witnesses
    ``s ~~MR(seq)^+~~> t`` (``seq`` is always a power of its own minimum
    repeat), so every sampled walk yields a true query with an MR of length
    up to ``k`` — not just single-label constraints. False queries are
    uniform ``(s, t, L)`` triples over the walk-observed MR pool, verified
    negative with the BiBFS oracle.
    """
    rng = np.random.default_rng(seed)
    n_false = n if n_false is None else n_false
    tq: List[Tuple[int, int, LabelSeq]] = []
    fq: List[Tuple[int, int, LabelSeq]] = []
    m = g.num_edges
    if m == 0:
        return QuerySet(tq, fq)
    seen_mrs: List[LabelSeq] = []
    attempts = 0
    while len(tq) < n and attempts < n * 100:
        attempts += 1
        # random walk of target length 1..k from a random edge's source
        e = g.edges[int(rng.integers(m))]
        s = int(e[0])
        length = int(rng.integers(1, k + 1))
        x, labels = s, []
        for _ in range(length):
            nbrs, labs = g.out_edges(x)
            if len(nbrs) == 0:
                break
            j = int(rng.integers(len(nbrs)))
            labels.append(int(labs[j]))
            x = int(nbrs[j])
        if not labels:
            continue
        L = minimum_repeat(tuple(labels))
        if len(L) > k:          # unreachable (|walk| <= k) — belt and braces
            continue
        tq.append((s, x, L))
        if L not in seen_mrs:
            seen_mrs.append(L)
    attempts = 0
    while len(fq) < n_false and attempts < n_false * 200 and seen_mrs:
        attempts += 1
        s = int(rng.integers(g.num_vertices))
        t = int(rng.integers(g.num_vertices))
        L = seen_mrs[int(rng.integers(len(seen_mrs)))]
        if not bibfs_rlc(g, s, t, L):
            fq.append((s, t, L))
    return QuerySet(tq, fq)
