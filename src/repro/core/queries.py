"""Query-workload generation (paper §VI-c).

Uniformly sample (s, t, L^+) triples, classify each with a bidirectional
product-automaton BFS, and collect 1000 true- and 1000 false-queries.
Constraints L are drawn from the realizable minimum repeats of the graph
(uniform over MR space, as in the paper), biased to length <= k.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .baselines import bibfs_rlc
from .graph import LabeledGraph
from .minimum_repeat import LabelSeq, enumerate_mrs


@dataclass
class QuerySet:
    true_queries: List[Tuple[int, int, LabelSeq]]
    false_queries: List[Tuple[int, int, LabelSeq]]

    def all(self) -> List[Tuple[int, int, LabelSeq, bool]]:
        return ([(s, t, L, True) for s, t, L in self.true_queries]
                + [(s, t, L, False) for s, t, L in self.false_queries])


def generate_queries(g: LabeledGraph, k: int, n_true: int = 1000,
                     n_false: int = 1000, seed: int = 0,
                     max_attempts: Optional[int] = None) -> QuerySet:
    rng = np.random.default_rng(seed)
    mrs = enumerate_mrs(g.num_labels, k)
    # restrict to labels that actually occur (otherwise false-queries are
    # trivially false and true-queries unreachable)
    present = np.unique(g.edges[:, 1]) if g.num_edges else np.array([0])
    mrs = [m for m in mrs if all(l in present for l in m)] or list(mrs)
    tq: List[Tuple[int, int, LabelSeq]] = []
    fq: List[Tuple[int, int, LabelSeq]] = []
    attempts = 0
    cap = max_attempts or (n_true + n_false) * 200
    while (len(tq) < n_true or len(fq) < n_false) and attempts < cap:
        attempts += 1
        s = int(rng.integers(g.num_vertices))
        t = int(rng.integers(g.num_vertices))
        L = mrs[int(rng.integers(len(mrs)))]
        ans = bibfs_rlc(g, s, t, L)
        if ans and len(tq) < n_true:
            tq.append((s, t, L))
        elif not ans and len(fq) < n_false:
            fq.append((s, t, L))
    return QuerySet(tq, fq)


def biased_true_queries(g: LabeledGraph, k: int, n: int, seed: int = 0
                        ) -> QuerySet:
    """Seed sources from actual edges so dense true sets exist even on very
    sparse graphs (used by benchmarks to hit the n_true quota quickly)."""
    rng = np.random.default_rng(seed)
    mrs = enumerate_mrs(g.num_labels, k)
    tq: List[Tuple[int, int, LabelSeq]] = []
    fq: List[Tuple[int, int, LabelSeq]] = []
    m = g.num_edges
    attempts = 0
    while len(tq) < n and attempts < n * 100:
        attempts += 1
        e = g.edges[int(rng.integers(m))]
        s, lab, t = int(e[0]), int(e[1]), int(e[2])
        L = (lab,)
        if len(L) <= k:
            tq.append((s, t, L))
    return QuerySet(tq, fq)
