"""Distributed RLC index build + query serving on a device mesh (DESIGN §3/§5).

Layout
------
* adjacency / reachability matrices: rows (source vertices) sharded over the
  ``data`` mesh axis; columns replicated (or sharded over ``model`` for the
  widest graphs).
* semiring matmuls: row-parallel ``shard_map`` — each shard holds a row
  block of the left operand, all-gathers the right operand once per step
  (ring all-gather on the ICI), and emits its row block of the product.
  This is the *manual-collective* path; a GSPMD path (`jit` +
  ``with_sharding_constraint``) is provided for comparison and used by the
  dry-run lowering.
* queries: embarrassingly parallel — sharded over ``("pod", "data")``; the
  frozen index is replicated per pod (paper's serving story).

Fault tolerance: the hub-batched build checkpoints ``(OUT, IN, next_hub)``
between batches (see :mod:`repro.ft.elastic`), so a failed build resumes
from the last completed batch, and a shrunk mesh re-shards the same arrays.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .dense import DenseEngine, build_condensed_device
from .graph import LabeledGraph
from .minimum_repeat import enumerate_mrs
from .rlc_index import RLCIndex

# jax promoted shard_map out of jax.experimental across versions.
try:
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map


def make_rlc_mesh(data: Optional[int] = None, pod: int = 1) -> Mesh:
    """1-pod mesh over available devices: axes ("pod", "data")."""
    nd = len(jax.devices())
    data = data or (nd // pod)
    devs = np.asarray(jax.devices()[:pod * data]).reshape(pod, data)
    return Mesh(devs, ("pod", "data"))


# ------------------------------------------------------------------ #
# Row-parallel semiring matmul (manual collectives)
# ------------------------------------------------------------------ #
def shmap_bool_matmul(mesh: Mesh, axis: str = "data"):
    """Returns an OR-AND matmul: left rows sharded over ``axis``; right
    operand all-gathered (tiled ring) inside the shard."""

    @partial(_shard_map, mesh=mesh,
             in_specs=(P(axis, None), P(axis, None)),
             out_specs=P(axis, None))
    def matmul(a_blk, b_blk):
        b_full = jax.lax.all_gather(b_blk, axis, axis=0, tiled=True)
        acc = jnp.matmul(a_blk, b_full,
                         preferred_element_type=jnp.float32)
        return (acc > 0).astype(a_blk.dtype)

    return matmul


def distributed_plus_closure(M: jax.Array, mesh: Mesh,
                             axis: str = "data") -> jax.Array:
    """Log-doubling closure with the row-parallel semiring matmul."""
    mm = shmap_bool_matmul(mesh, axis)
    n = M.shape[-1]
    R = M
    for _ in range(max(1, math.ceil(math.log2(max(n, 2))))):
        R = jnp.maximum(R, mm(R, R))
    return R


def distributed_all_mr_reach(graph: LabeledGraph, k: int, mesh: Mesh,
                             axis: str = "data") -> np.ndarray:
    """(C, n, n) R_L stack computed with row-sharded semiring matmuls.
    Rows are padded to a multiple of the axis size."""
    mrs = enumerate_mrs(graph.num_labels, k)
    n = graph.num_vertices
    p = mesh.shape[axis]
    n_pad = ((n + p - 1) // p) * p
    A_np = np.zeros((graph.num_labels, n_pad, n_pad), np.float32)
    A_np[:, :n, :n] = graph.label_adjacency(np.float32)
    shard = NamedSharding(mesh, P(None, axis, None))
    A = jax.device_put(jnp.asarray(A_np), shard)
    mm = shmap_bool_matmul(mesh, axis)
    outs = []
    for mr in mrs:
        M = A[mr[0]]
        for lab in mr[1:]:
            M = mm(M, A[lab])
        outs.append(distributed_plus_closure(M, mesh, axis))
    R = np.asarray(jnp.stack(outs))[:, :n, :n]
    return R > 0


def distributed_build(graph: LabeledGraph, k: int, mesh: Mesh,
                      hub_batch: int = 8) -> Tuple[RLCIndex, DenseEngine]:
    """Distributed condensed build: R_L on the mesh, then the hub-batched
    pruned labeling (dense.py) with row-sharded coverage matmuls."""
    R = distributed_all_mr_reach(graph, k, mesh)
    return build_condensed_device(graph, k, hub_batch=hub_batch, reach=R)


# ------------------------------------------------------------------ #
# Distributed query serving
# ------------------------------------------------------------------ #
def distributed_query_batch(dev_index, s: np.ndarray, t: np.ndarray,
                            mr: np.ndarray, mesh: Mesh) -> np.ndarray:
    """Shard the query batch over every mesh axis; index replicated.
    Pads the batch up to a multiple of the mesh size."""
    from .device_index import _query_batch_ref

    axes = tuple(mesh.axis_names)
    nshard = math.prod(mesh.shape[a] for a in axes)
    Q = len(s)
    Qp = ((Q + nshard - 1) // nshard) * nshard
    pad = Qp - Q

    def pad1(x):
        return np.concatenate([x, np.zeros(pad, x.dtype)]) if pad else x

    qshard = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    args = [jax.device_put(jnp.asarray(x), rep)
            for x in (dev_index.out_hub, dev_index.out_mr,
                      dev_index.in_hub, dev_index.in_mr)]
    qargs = [jax.device_put(jnp.asarray(pad1(np.asarray(x, np.int32))),
                            qshard) for x in (s, t, mr)]
    fn = jax.jit(_query_batch_ref,
                 in_shardings=(rep,) * 4 + (qshard,) * 3,
                 out_shardings=qshard)
    out = np.asarray(fn(*args, *qargs))
    return out[:Q]
