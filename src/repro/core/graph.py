"""Edge-labeled directed graph G = (V, E, L) (paper §III).

Storage: an int32 edge table plus CSR adjacency in both directions, grouped
so that per-(vertex, label) neighbor slices are O(1) to locate. A dense
per-label boolean adjacency view is available for the TPU dense-semiring
engine (``core/dense.py``) on graphs where |V|^2 * |L| is affordable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


def _edge_keys(edges: np.ndarray, num_vertices: int, num_labels: int
               ) -> np.ndarray:
    """Collision-free int64 key per (src, label, dst) row."""
    e = edges.astype(np.int64)
    return (e[:, 0] * num_labels + e[:, 1]) * num_vertices + e[:, 2]


@dataclass(frozen=True)
class GraphDelta:
    """A batch of edge mutations: ``inserts``/``deletes`` are (m, 3) int32
    rows of ``(src, label, dst)``, deduplicated and disjoint.

    The unit the incremental build engine (:mod:`repro.build.delta`)
    consumes: :meth:`LabeledGraph.apply_delta` turns ``graph + delta``
    into the mutated graph, and the delta builder re-derives only the
    ``(hub, direction)`` phases the delta can touch.
    """

    inserts: np.ndarray
    deletes: np.ndarray

    @staticmethod
    def of(inserts: Sequence = (), deletes: Sequence = ()) -> "GraphDelta":
        def norm(rows):
            a = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
            return np.unique(a, axis=0) if a.size else a
        return GraphDelta(norm(inserts), norm(deletes))

    @property
    def num_changes(self) -> int:
        return int(self.inserts.shape[0] + self.deletes.shape[0])

    def endpoints(self) -> np.ndarray:
        """Sorted unique vertex ids whose degree the delta changes."""
        cols = [self.inserts[:, 0], self.inserts[:, 2],
                self.deletes[:, 0], self.deletes[:, 2]]
        return np.unique(np.concatenate(cols)).astype(np.int64)

    def validate(self, graph: "LabeledGraph") -> None:
        """Raise ``ValueError`` unless the delta is applicable to
        ``graph``: ids in range, deletes present, inserts absent, and no
        row both inserted and deleted."""
        for name, rows in (("inserts", self.inserts),
                           ("deletes", self.deletes)):
            if not rows.size:
                continue
            if (rows[:, [0, 2]].min() < 0
                    or rows[:, [0, 2]].max() >= graph.num_vertices):
                raise ValueError(f"{name}: vertex id out of range "
                                 f"[0, {graph.num_vertices})")
            if rows[:, 1].min() < 0 or rows[:, 1].max() >= graph.num_labels:
                raise ValueError(f"{name}: label id out of range "
                                 f"[0, {graph.num_labels})")
        V, L = graph.num_vertices, graph.num_labels
        have = _edge_keys(graph.edges, V, L)
        ins = _edge_keys(self.inserts, V, L)
        dels = _edge_keys(self.deletes, V, L)
        if np.isin(ins, have).any():
            raise ValueError("inserts contain edges already in the graph")
        if not np.isin(dels, have).all():
            raise ValueError("deletes contain edges not in the graph")
        if np.isin(ins, dels).any():
            raise ValueError("an edge appears in both inserts and deletes")


@dataclass
class LabeledGraph:
    num_vertices: int
    num_labels: int
    # (m, 3) int32 rows of (src, label, dst), deduplicated.
    edges: np.ndarray

    # --- derived CSR structures (built lazily) ---
    _fwd: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = field(
        default=None, repr=False)
    _bwd: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = field(
        default=None, repr=False)
    _label_adj: Optional[np.ndarray] = field(default=None, repr=False)
    _fwd_label_csr: Optional[Tuple[np.ndarray, np.ndarray]] = field(
        default=None, repr=False)
    _bwd_label_csr: Optional[Tuple[np.ndarray, np.ndarray]] = field(
        default=None, repr=False)

    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(num_vertices: int, num_labels: int,
                   edges: np.ndarray) -> "LabeledGraph":
        edges = np.asarray(edges, dtype=np.int32).reshape(-1, 3)
        if edges.size:
            edges = np.unique(edges, axis=0)
        return LabeledGraph(num_vertices, num_labels, edges)

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    def apply_delta(self, delta: GraphDelta,
                    validate: bool = True) -> "LabeledGraph":
        """The mutated graph ``(E \\ deletes) ∪ inserts`` as a fresh
        :class:`LabeledGraph` (same vertex/label space; derived CSR caches
        are rebuilt lazily on the new object — the receiver is untouched,
        so index builds against the old snapshot stay valid)."""
        if validate:
            delta.validate(self)
        keys = _edge_keys(self.edges, self.num_vertices, self.num_labels)
        dels = _edge_keys(delta.deletes, self.num_vertices, self.num_labels)
        kept = self.edges[~np.isin(keys, dels)]
        edges = (np.concatenate([kept, delta.inserts.astype(np.int32)])
                 if delta.inserts.size else kept)
        return LabeledGraph.from_edges(self.num_vertices, self.num_labels,
                                       edges)

    # ------------------------------------------------------------------ #
    def _build_csr(self, key_col: int, val_col: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR keyed on ``key_col`` vertex; values are (other_vertex, label)
        sorted by (key, label) so per-label slices are contiguous."""
        e = self.edges
        order = np.lexsort((e[:, val_col], e[:, 1], e[:, key_col]))
        e = e[order]
        indptr = np.zeros(self.num_vertices + 1, dtype=np.int64)
        np.add.at(indptr, e[:, key_col] + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, e[:, val_col].copy(), e[:, 1].copy()

    @property
    def fwd(self):
        """(indptr, dst, label): out-edges of each vertex, label-sorted."""
        if self._fwd is None:
            self._fwd = self._build_csr(key_col=0, val_col=2)
        return self._fwd

    @property
    def bwd(self):
        """(indptr, src, label): in-edges of each vertex, label-sorted."""
        if self._bwd is None:
            self._bwd = self._build_csr(key_col=2, val_col=0)
        return self._bwd

    # -- neighbor iteration -------------------------------------------- #
    def out_edges(self, v: int) -> Tuple[np.ndarray, np.ndarray]:
        indptr, other, lab = self.fwd
        s, t = indptr[v], indptr[v + 1]
        return other[s:t], lab[s:t]

    def in_edges(self, v: int) -> Tuple[np.ndarray, np.ndarray]:
        indptr, other, lab = self.bwd
        s, t = indptr[v], indptr[v + 1]
        return other[s:t], lab[s:t]

    # -- label-partitioned CSR (shared by batched builders, baselines,
    #    the dense engine, and per-label neighbor slicing) --------------- #
    def _build_label_csr(self, backward: bool
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """CSR keyed on the composite ``vertex * |L| + label``.

        The base CSRs are already (vertex, label)-sorted, so the neighbor
        array is shared (no copy); only the (V*|L| + 1) indptr is new.
        ``nbrs[indptr[v*L + l] : indptr[v*L + l + 1]]`` are v's neighbors
        via label ``l``, in the direction's base-CSR order.
        """
        indptr, other, lab = self.bwd if backward else self.fwd
        nl = self.num_labels
        keys = np.zeros(self.num_vertices * nl + 1, dtype=np.int64)
        # edge e sits at row (key_vertex[e], lab[e]); count per composite key
        vert = np.repeat(np.arange(self.num_vertices), np.diff(indptr))
        np.add.at(keys, vert * nl + lab + 1, 1)
        np.cumsum(keys, out=keys)
        return keys, other

    def label_csr(self, backward: bool = False
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Cached ``(indptr, nbrs)`` label-partitioned adjacency; see
        :meth:`_build_label_csr` for the layout contract."""
        if backward:
            if self._bwd_label_csr is None:
                self._bwd_label_csr = self._build_label_csr(True)
            return self._bwd_label_csr
        if self._fwd_label_csr is None:
            self._fwd_label_csr = self._build_label_csr(False)
        return self._fwd_label_csr

    def out_neighbors_with_label(self, v: int, label: int) -> np.ndarray:
        indptr, nbrs = self.label_csr(backward=False)
        key = v * self.num_labels + label
        return nbrs[indptr[key]:indptr[key + 1]]

    def in_neighbors_with_label(self, v: int, label: int) -> np.ndarray:
        indptr, nbrs = self.label_csr(backward=True)
        key = v * self.num_labels + label
        return nbrs[indptr[key]:indptr[key + 1]]

    # -- degrees & the IN-OUT vertex ordering (paper §V-B) -------------- #
    def out_degree(self) -> np.ndarray:
        indptr, _, _ = self.fwd
        return np.diff(indptr)

    def in_degree(self) -> np.ndarray:
        indptr, _, _ = self.bwd
        return np.diff(indptr)

    def access_order(self) -> np.ndarray:
        """Vertices sorted by (|out(v)|+1)*(|in(v)|+1) descending; ties by
        vertex id for determinism. ``order[aid-1] = vertex``."""
        score = (self.out_degree() + 1).astype(np.int64) * \
                (self.in_degree() + 1).astype(np.int64)
        return np.lexsort((np.arange(self.num_vertices), -score))

    def access_ids(self) -> np.ndarray:
        """``aid[v]`` = 1-based access id of vertex v."""
        order = self.access_order()
        aid = np.empty(self.num_vertices, dtype=np.int64)
        aid[order] = np.arange(1, self.num_vertices + 1)
        return aid

    # -- dense per-label adjacency for the semiring engine -------------- #
    def label_adjacency(self, dtype=np.float32) -> np.ndarray:
        """Dense (|L|, n, n) boolean-as-``dtype`` adjacency stack.
        ``A[l, u, v] = 1`` iff edge (u, l, v).

        Derived from :meth:`label_csr` so the dense engine, the baselines,
        and the batched builders all share one adjacency source.
        """
        if self._label_adj is None or self._label_adj.dtype != dtype:
            n, nl = self.num_vertices, self.num_labels
            indptr, nbrs = self.label_csr(backward=False)
            keys = np.repeat(np.arange(n * nl), np.diff(indptr))
            A = np.zeros((nl, n, n), dtype=dtype)
            A[keys % nl, keys // nl, nbrs] = 1
            self._label_adj = A
        return self._label_adj

    # -- stats used in benchmarks (paper Table III) ---------------------- #
    def loop_count(self) -> int:
        return int(np.sum(self.edges[:, 0] == self.edges[:, 2]))

    def summary(self) -> Dict[str, int]:
        return dict(V=self.num_vertices, E=self.num_edges,
                    L=self.num_labels, loops=self.loop_count())
