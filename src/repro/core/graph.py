"""Edge-labeled directed graph G = (V, E, L) (paper §III).

Storage: an int32 edge table plus CSR adjacency in both directions, grouped
so that per-(vertex, label) neighbor slices are O(1) to locate. A dense
per-label boolean adjacency view is available for the TPU dense-semiring
engine (``core/dense.py``) on graphs where |V|^2 * |L| is affordable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class LabeledGraph:
    num_vertices: int
    num_labels: int
    # (m, 3) int32 rows of (src, label, dst), deduplicated.
    edges: np.ndarray

    # --- derived CSR structures (built lazily) ---
    _fwd: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = field(
        default=None, repr=False)
    _bwd: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = field(
        default=None, repr=False)
    _label_adj: Optional[np.ndarray] = field(default=None, repr=False)

    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(num_vertices: int, num_labels: int,
                   edges: np.ndarray) -> "LabeledGraph":
        edges = np.asarray(edges, dtype=np.int32).reshape(-1, 3)
        if edges.size:
            edges = np.unique(edges, axis=0)
        return LabeledGraph(num_vertices, num_labels, edges)

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    # ------------------------------------------------------------------ #
    def _build_csr(self, key_col: int, val_col: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR keyed on ``key_col`` vertex; values are (other_vertex, label)
        sorted by (key, label) so per-label slices are contiguous."""
        e = self.edges
        order = np.lexsort((e[:, val_col], e[:, 1], e[:, key_col]))
        e = e[order]
        indptr = np.zeros(self.num_vertices + 1, dtype=np.int64)
        np.add.at(indptr, e[:, key_col] + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, e[:, val_col].copy(), e[:, 1].copy()

    @property
    def fwd(self):
        """(indptr, dst, label): out-edges of each vertex, label-sorted."""
        if self._fwd is None:
            self._fwd = self._build_csr(key_col=0, val_col=2)
        return self._fwd

    @property
    def bwd(self):
        """(indptr, src, label): in-edges of each vertex, label-sorted."""
        if self._bwd is None:
            self._bwd = self._build_csr(key_col=2, val_col=0)
        return self._bwd

    # -- neighbor iteration -------------------------------------------- #
    def out_edges(self, v: int) -> Tuple[np.ndarray, np.ndarray]:
        indptr, other, lab = self.fwd
        s, t = indptr[v], indptr[v + 1]
        return other[s:t], lab[s:t]

    def in_edges(self, v: int) -> Tuple[np.ndarray, np.ndarray]:
        indptr, other, lab = self.bwd
        s, t = indptr[v], indptr[v + 1]
        return other[s:t], lab[s:t]

    def out_neighbors_with_label(self, v: int, label: int) -> np.ndarray:
        other, lab = self.out_edges(v)
        lo = np.searchsorted(lab, label, side="left")
        hi = np.searchsorted(lab, label, side="right")
        return other[lo:hi]

    def in_neighbors_with_label(self, v: int, label: int) -> np.ndarray:
        other, lab = self.in_edges(v)
        lo = np.searchsorted(lab, label, side="left")
        hi = np.searchsorted(lab, label, side="right")
        return other[lo:hi]

    # -- degrees & the IN-OUT vertex ordering (paper §V-B) -------------- #
    def out_degree(self) -> np.ndarray:
        indptr, _, _ = self.fwd
        return np.diff(indptr)

    def in_degree(self) -> np.ndarray:
        indptr, _, _ = self.bwd
        return np.diff(indptr)

    def access_order(self) -> np.ndarray:
        """Vertices sorted by (|out(v)|+1)*(|in(v)|+1) descending; ties by
        vertex id for determinism. ``order[aid-1] = vertex``."""
        score = (self.out_degree() + 1).astype(np.int64) * \
                (self.in_degree() + 1).astype(np.int64)
        return np.lexsort((np.arange(self.num_vertices), -score))

    def access_ids(self) -> np.ndarray:
        """``aid[v]`` = 1-based access id of vertex v."""
        order = self.access_order()
        aid = np.empty(self.num_vertices, dtype=np.int64)
        aid[order] = np.arange(1, self.num_vertices + 1)
        return aid

    # -- dense per-label adjacency for the semiring engine -------------- #
    def label_adjacency(self, dtype=np.float32) -> np.ndarray:
        """Dense (|L|, n, n) boolean-as-``dtype`` adjacency stack.
        ``A[l, u, v] = 1`` iff edge (u, l, v)."""
        if self._label_adj is None or self._label_adj.dtype != dtype:
            n = self.num_vertices
            A = np.zeros((self.num_labels, n, n), dtype=dtype)
            e = self.edges
            A[e[:, 1], e[:, 0], e[:, 2]] = 1
            self._label_adj = A
        return self._label_adj

    # -- stats used in benchmarks (paper Table III) ---------------------- #
    def loop_count(self) -> int:
        return int(np.sum(self.edges[:, 0] == self.edges[:, 2]))

    def summary(self) -> Dict[str, int]:
        return dict(V=self.num_vertices, E=self.num_edges,
                    L=self.num_labels, loops=self.loop_count())
