"""Shared constants for the padded index layouts.

``PAD`` fills unused slots in every padded per-vertex row (DeviceIndex
arrays, Pallas kernel inputs, scheduler batch padding). It is a vertex /
MR id that can never occur (ids are non-negative), so padded slots never
match a real hub, query vertex or constraint.
"""

PAD = -1
