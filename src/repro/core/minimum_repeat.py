"""Minimum repeats, kernels and tails of label sequences (paper §III-A, §IV).

A label sequence is represented as a tuple of non-negative ints (label ids).
All routines are O(n) via the KMP failure function [75].

Definitions (paper):
  * ``L'`` is a *repeat* of ``L`` if ``L = (L')^z`` for an integer ``z >= 1``.
  * ``MR(L)`` is the shortest repeat of ``L`` (unique, Lemma 1).
  * ``L`` has *kernel* ``L'`` and *tail* ``L''`` if ``L = (L')^h ∘ L''`` with
    ``h >= 2``, ``MR(L') = L'`` and ``L''`` a proper prefix of ``L'`` (or ε).
    The kernel, when it exists, is unique (Lemma 2).
  * ``L`` has a non-empty *k-MR* iff ``|MR(L)| <= k``; the k-MR is ``MR(L)``.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Iterator, Optional, Sequence, Tuple

Label = int
LabelSeq = Tuple[Label, ...]


def failure_function(seq: Sequence[Label]) -> list:
    """KMP failure function. ``fail[i]`` = length of the longest proper
    prefix of ``seq[:i+1]`` that is also a suffix of it."""
    n = len(seq)
    fail = [0] * n
    j = 0
    for i in range(1, n):
        while j > 0 and seq[i] != seq[j]:
            j = fail[j - 1]
        if seq[i] == seq[j]:
            j += 1
        fail[i] = j
    return fail


def minimum_repeat(seq: Sequence[Label]) -> LabelSeq:
    """``MR(L)``: the shortest ``L'`` such that ``L = (L')^z`` (Lemma 1).

    The shortest period of ``seq`` is ``p = n - fail[n-1]``; it yields a
    repeat iff ``p`` divides ``n``, otherwise ``seq`` is its own MR.
    """
    seq = tuple(seq)
    n = len(seq)
    if n == 0:
        return ()
    p = n - failure_function(seq)[-1]
    if n % p == 0:
        return seq[:p]
    return seq


def is_minimum_repeat(seq: Sequence[Label]) -> bool:
    seq = tuple(seq)
    return minimum_repeat(seq) == seq


def k_mr(seq: Sequence[Label], k: int) -> Optional[LabelSeq]:
    """The k-MR of ``seq``: ``MR(seq)`` if ``|MR(seq)| <= k`` else ``None``."""
    mr = minimum_repeat(seq)
    return mr if len(mr) <= k else None


def kernel_tail(seq: Sequence[Label]) -> Optional[Tuple[LabelSeq, LabelSeq]]:
    """Kernel/tail decomposition (Definition 3), or ``None`` if none exists.

    Returns the unique ``(kernel, tail)`` with ``seq = kernel^h ∘ tail``,
    ``h >= 2``, ``MR(kernel) = kernel`` and ``tail`` a proper prefix of the
    kernel (possibly ε). Uniqueness is Lemma 2; the shortest valid period is
    therefore the kernel.
    """
    seq = tuple(seq)
    n = len(seq)
    for p in range(1, n // 2 + 1):
        # seq must be periodic with period p over its whole length ...
        if all(seq[i] == seq[i - p] for i in range(p, n)):
            kern = seq[:p]
            # ... the kernel must be its own MR and repeat at least twice.
            if minimum_repeat(kern) == kern and n // p >= 2:
                return kern, seq[(n // p) * p:]
    return None


def has_k_mr_path(prefix_2k: Sequence[Label], rest: Sequence[Label], k: int
                  ) -> Optional[LabelSeq]:
    """Theorem 1, Case 3 helper: given a path split at ``|prefix| = 2k``,
    return its k-MR or None. Used by the lazy-KBS reference and in tests."""
    kt = kernel_tail(tuple(prefix_2k))
    if kt is None:
        return None
    kern, tail = kt
    if len(kern) > k:
        return None
    if minimum_repeat(tuple(tail) + tuple(rest)) == kern:
        return kern
    return None


@lru_cache(maxsize=64)
def enumerate_mrs(num_labels: int, k: int) -> Tuple[LabelSeq, ...]:
    """All sequences over ``{0..num_labels-1}`` of length <= k that are their
    own minimum repeat. ``len(enumerate_mrs(|L|, k))`` equals the paper's C
    (index-size analysis §V-C)."""
    out = []

    def rec(prefix: LabelSeq):
        if prefix and is_minimum_repeat(prefix):
            out.append(prefix)
        if len(prefix) < k:
            for lab in range(num_labels):
                rec(prefix + (lab,))

    rec(())
    return tuple(out)


def count_mrs(num_labels: int, k: int) -> int:
    """Closed-form C = Σ_{i<=k} F(i), F(i) = |L|^i - Σ_{j|i, j≠i} F(j)."""
    F = {}
    for i in range(1, k + 1):
        F[i] = num_labels ** i - sum(F[j] for j in range(1, i) if i % j == 0)
    return sum(F.values())


def mr_id_space(num_labels: int, k: int) -> dict:
    """Canonical MR -> dense id mapping (deterministic order)."""
    return {mr: i for i, mr in enumerate(enumerate_mrs(num_labels, k))}


def iter_rotations(seq: LabelSeq) -> Iterator[LabelSeq]:
    for i in range(len(seq)):
        yield seq[i:] + seq[:i]
