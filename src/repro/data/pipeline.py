"""Deterministic synthetic LM data pipeline (host-sharded, restart-safe).

Real deployments swap this for a tokenized corpus reader; the interface is
the contract: ``batch_at(step)`` is a pure function of (seed, step,
process_index) so (a) restarts resume bit-identically mid-epoch without
data state in checkpoints, (b) each host materializes only its shard
(B/num_processes), and (c) elastic re-meshes re-partition cleanly.

The token stream is a mixture of Zipfian unigrams + local n-gram structure
so smoke-training shows a real loss curve (not instantly-memorized noise).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    num_processes: int = 1
    process_index: int = 0


class SyntheticLMData:
    def __init__(self, cfg: ArchConfig, dc: DataConfig):
        assert dc.global_batch % dc.num_processes == 0
        self.cfg = cfg
        self.dc = dc
        self.local_batch = dc.global_batch // dc.num_processes
        # fixed Zipfian unigram table + a per-token-mixing matrix
        rng = np.random.default_rng(dc.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._p = ranks ** -1.1
        self._p /= self._p.sum()
        self._shift = rng.integers(1, max(V - 1, 2))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        rng = np.random.default_rng(
            (dc.seed * 1_000_003 + step) * 4096 + dc.process_index)
        B, S, V = self.local_batch, dc.seq_len, self.cfg.vocab_size
        base = rng.choice(V, size=(B, S + 1), p=self._p)
        # n-gram structure: half the positions copy-shift the predecessor
        copy = rng.random((B, S + 1)) < 0.5
        shifted = (np.roll(base, 1, axis=1) + self._shift) % V
        tokens = np.where(copy, shifted, base).astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.cfg.frontend != "none":
            out["frontend"] = rng.standard_normal(
                (B, self.cfg.frontend_len, self.cfg.frontend_dim)
            ).astype(np.float32)
        return out
