"""Pallas TPU kernels: label-guided product-automaton frontier steps.

One kernel step of the (batched) kernel-BFS: given the frontier matrix
``F`` (sources x vertices) at automaton position ``p`` and the stacked
per-label adjacency ``A`` (|L|, V, V), compute ``F @ A[label]`` over the
OR-AND semiring. The *label* selects the adjacency slice via a
scalar-prefetch indexed BlockSpec — the whole guided BFS runs without
materializing the selected slice in HBM.

Three granularities:

* :func:`frontier_step`       — one shared label for the whole batch;
* :func:`frontier_step_many`  — one label *per frontier row* (the
  batched index builder drives every kernel/phase of a hub's product
  automaton through a single call);
* :func:`frontier_steps`      — multi-step: a ``(T, R)`` label schedule
  scanned on device with a per-step row permutation (the phase shift of
  the product automaton), for advancing several waves without a host
  round-trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _frontier_kernel(lab_ref, f_ref, a_ref, o_ref, acc_ref, *,
                     k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(f_ref[...], a_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] > 0).astype(o_ref.dtype)


def frontier_step(frontier: jax.Array, A: jax.Array, label: jax.Array, *,
                  bb: int = 128, bk: int = 128, bn: int = 128,
                  interpret: bool = False) -> jax.Array:
    """next[b, v] = OR_u frontier[b, u] & A[label, u, v].

    frontier: (B, V) f32 0/1;  A: (|L|, V, V) f32;  label: () int32.
    """
    B, V = frontier.shape
    nl, V1, V2 = A.shape
    assert V == V1 == V2
    bb, bk, bn = min(bb, B), min(bk, V), min(bn, V)
    assert B % bb == 0 and V % bk == 0 and V % bn == 0
    grid = (B // bb, V // bn, V // bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, kk, lab: (i, kk)),
            pl.BlockSpec((1, bk, bn), lambda i, j, kk, lab: (lab[0], kk, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk, lab: (i, j)),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_frontier_kernel, k_steps=grid[2]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, V), frontier.dtype),
        interpret=interpret,
    )(label.reshape(1).astype(jnp.int32), frontier, A)


def frontier_step_many(frontier: jax.Array, A: jax.Array,
                       labels: jax.Array, *, bk: int = 128, bn: int = 128,
                       interpret: bool = False) -> jax.Array:
    """next[r, v] = OR_u frontier[r, u] & A[labels[r], u, v].

    Per-row labels: row ``r`` of the frontier advances along its own
    adjacency slice, selected by the scalar-prefetched ``labels`` vector
    in the BlockSpec index map — many kernels / automaton phases of
    Algorithm 2's kernel-BFS batch through one call.

    frontier: (R, V) f32 0/1;  A: (|L|, V, V) f32;  labels: (R,) int32.
    """
    R, V = frontier.shape
    nl, V1, V2 = A.shape
    assert V == V1 == V2 and labels.shape == (R,)
    bk, bn = min(bk, V), min(bn, V)
    assert V % bk == 0 and V % bn == 0
    grid = (R, V // bn, V // bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, kk, lab: (i, kk)),
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, kk, lab: (lab[i], kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j, kk, lab: (i, j)),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_frontier_kernel, k_steps=grid[2]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, V), frontier.dtype),
        interpret=interpret,
    )(labels.astype(jnp.int32), frontier, A)


def frontier_steps(frontier: jax.Array, A: jax.Array, labels: jax.Array,
                   dst: jax.Array, *, bk: int = 128, bn: int = 128,
                   interpret: bool = False) -> jax.Array:
    """``T`` chained :func:`frontier_step_many` waves on device.

    After wave ``t``, row ``r``'s expansion lands in row ``dst[t, r]``
    (the product automaton's phase shift; each ``dst[t]`` must be a
    permutation). No visited-set pruning happens between waves — callers
    interleave host-side pruning only at repeat boundaries and use this
    to advance the off-boundary phases in one shot.

    frontier: (R, V);  labels: (T, R) int32;  dst: (T, R) int32.
    """
    T, R = labels.shape
    assert dst.shape == (T, R) and frontier.shape[0] == R

    def body(F, step):
        labs, d = step
        G = frontier_step_many(F, A, labs, bk=bk, bn=bn,
                               interpret=interpret)
        return jnp.zeros_like(G).at[d].set(G), None

    out, _ = jax.lax.scan(body, frontier,
                          (labels.astype(jnp.int32),
                           dst.astype(jnp.int32)))
    return out
