"""Pallas TPU kernel: label-guided product-automaton frontier step.

One kernel step of the (batched) kernel-BFS: given the frontier matrix
``F`` (sources x vertices) at automaton position ``p`` and the stacked
per-label adjacency ``A`` (|L|, V, V), compute ``F @ A[label]`` over the
OR-AND semiring. The *label* selects the adjacency slice via a
scalar-prefetch indexed BlockSpec — the whole guided BFS runs without
materializing the selected slice in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _frontier_kernel(lab_ref, f_ref, a_ref, o_ref, acc_ref, *,
                     k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(f_ref[...], a_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] > 0).astype(o_ref.dtype)


def frontier_step(frontier: jax.Array, A: jax.Array, label: jax.Array, *,
                  bb: int = 128, bk: int = 128, bn: int = 128,
                  interpret: bool = False) -> jax.Array:
    """next[b, v] = OR_u frontier[b, u] & A[label, u, v].

    frontier: (B, V) f32 0/1;  A: (|L|, V, V) f32;  label: () int32.
    """
    B, V = frontier.shape
    nl, V1, V2 = A.shape
    assert V == V1 == V2
    bb, bk, bn = min(bb, B), min(bk, V), min(bn, V)
    assert B % bb == 0 and V % bk == 0 and V % bn == 0
    grid = (B // bb, V // bn, V // bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, kk, lab: (i, kk)),
            pl.BlockSpec((1, bk, bn), lambda i, j, kk, lab: (lab[0], kk, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk, lab: (i, j)),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_frontier_kernel, k_steps=grid[2]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, V), frontier.dtype),
        interpret=interpret,
    )(label.reshape(1).astype(jnp.int32), frontier, A)
