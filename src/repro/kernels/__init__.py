"""Pallas TPU kernels for the RLC engine's compute hot-spots.

Layout: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec kernel,
``ops.py`` the jit'd padded wrappers, ``ref.py`` the pure-jnp oracles.
Kernels target TPU (MXU-aligned 128-blocks, VMEM scratch accumulators) and
are validated on CPU via ``interpret=True``.
"""
from . import bitpack, bool_semiring, label_frontier, mergejoin, ops, ref

__all__ = ["bool_semiring", "mergejoin", "bitpack", "label_frontier",
           "ops", "ref"]
