"""Pallas TPU kernel: bit-packed OR-AND matmul (beyond-paper optimization).

Rationale (DESIGN §3): once the reachability frontier saturates, the
semiring matmul is *memory-bound* — its operands are 0/1 values occupying
a full f32 lane each. Packing the N dimension 32-to-a-uint32 cuts HBM
traffic of the right operand and the output by 32x, trading MXU dots for
VPU ``where``+``or`` ops. Profitable exactly when the memory roofline term
dominates (see EXPERIMENTS.md §Perf for the napkin math + measurement).

``out_packed[m, w] = OR_k a[m, k] ? b_packed[k, w] : 0``   (bitwise OR)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; take
# whichever this install provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def pack_bits(x: jax.Array) -> jax.Array:
    """(..., N) 0/1 -> (..., N//32) uint32 (bit j of word w = col 32w+j)."""
    n = x.shape[-1]
    assert n % 32 == 0, n
    xb = (x > 0).astype(jnp.uint32).reshape(*x.shape[:-1], n // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (xb << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(xp: jax.Array, dtype=jnp.float32) -> jax.Array:
    w = xp.shape[-1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (xp[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*xp.shape[:-1], w * 32).astype(dtype)


def _bitpack_kernel(a_ref, bp_ref, o_ref, acc_ref, *, k_steps: int,
                    bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]          # (bm, bk) f32 0/1
    bp = bp_ref[...]        # (bk, bw) uint32

    def body(kk, acc):
        mask = jax.lax.dynamic_slice_in_dim(a, kk, 1, axis=1) > 0  # (bm, 1)
        word = jax.lax.dynamic_slice_in_dim(bp, kk, 1, axis=0)     # (1, bw)
        return acc | jnp.where(mask, word, jnp.uint32(0))

    acc_ref[...] = jax.lax.fori_loop(0, bk, body, acc_ref[...])

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...]


def bitpack_matmul(a: jax.Array, b_packed: jax.Array, *, bm: int = 128,
                   bk: int = 128, bw: int = 128,
                   interpret: bool = False) -> jax.Array:
    """OR-AND product with bit-packed right operand / output.

    a: (M, K) f32 0/1;  b_packed: (K, W) uint32;  out: (M, W) uint32.
    """
    m, k = a.shape
    k2, w = b_packed.shape
    assert k == k2
    bm, bk, bw = min(bm, m), min(bk, k), min(bw, w)
    assert m % bm == 0 and k % bk == 0 and w % bw == 0
    grid = (m // bm, w // bw, k // bk)
    return pl.pallas_call(
        functools.partial(_bitpack_kernel, k_steps=grid[2], bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bw), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bw), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, w), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bm, bw), jnp.uint32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, b_packed)
