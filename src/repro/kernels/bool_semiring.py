"""Pallas TPU kernel: blocked OR-AND (boolean semiring) matmul.

The compute hot-spot of the TPU-native RLC engine (DESIGN §3): reachability
closures and MR step-matrix chains are chains of these products. 0/1 values
ride in f32/bf16 so the MXU does the AND-accumulate as a dot; OR is the
``> 0`` threshold applied once per output tile on the f32 accumulator.

Grid: ``(M/bm, N/bn, K/bk)`` with the K loop innermost; one VMEM f32
accumulator tile per (i, j). Block defaults are MXU-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; take
# whichever this install provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _bool_mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] > 0).astype(o_ref.dtype)


def _fused_closure_kernel(a_ref, b_ref, rij_ref, o_ref, acc_ref, *,
                          k_steps: int):
    """One fused doubling step: O = R | R @ R. The (i, j) tile of R rides
    in as a third operand so the OR happens in VMEM (saves one HBM
    round-trip of the output tile vs. matmul-then-max)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        o_ref[...] = jnp.maximum((acc_ref[...] > 0).astype(o_ref.dtype),
                                 rij_ref[...])


def bool_matmul(a: jax.Array, b: jax.Array, *, bm: int = 128,
                bk: int = 128, bn: int = 128,
                interpret: bool = False) -> jax.Array:
    """``(a @ b) > 0`` over the OR-AND semiring. Shapes must tile evenly
    (use :mod:`repro.kernels.ops` for padded dispatch)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_bool_mm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, b)


def closure_step(r: jax.Array, *, bm: int = 128, bk: int = 128,
                 bn: int = 128, interpret: bool = False) -> jax.Array:
    """Fused ``R | R @ R`` (log-doubling step). ``r`` must be square and
    tile evenly."""
    n = r.shape[0]
    assert r.shape == (n, n)
    bm, bk, bn = min(bm, n), min(bk, n), min(bn, n)
    assert n % bm == 0 and n % bk == 0 and n % bn == 0
    grid = (n // bm, n // bn, n // bk)
    return pl.pallas_call(
        functools.partial(_fused_closure_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(r, r, r)
