"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth; kernel tests sweep shapes and
dtypes asserting exact agreement (boolean semirings are exact in f32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

PAD = -1


def bool_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """OR-AND semiring product of 0/1 matrices."""
    return (jnp.matmul(a, b, preferred_element_type=jnp.float32) > 0
            ).astype(a.dtype)


def fused_closure_step_ref(r: jax.Array) -> jax.Array:
    """One log-doubling step R | R@R (fused in the Pallas variant)."""
    return jnp.maximum(r, bool_matmul_ref(r, r))


def mergejoin_ref(out_hub, out_mr, in_hub, in_mr, s, t, mr):
    """Batched Algorithm 1 (Case 2 + Case 1 join) — see device_index."""
    oh = out_hub[s]
    om = out_mr[s]
    ih = in_hub[t]
    im = in_mr[t]
    q_mr = mr[:, None]
    case2 = jnp.any((oh == t[:, None]) & (om == q_mr), axis=1) | \
        jnp.any((ih == s[:, None]) & (im == q_mr), axis=1)
    o_ok = (om == q_mr) & (oh != PAD)
    i_ok = (im == q_mr) & (ih != PAD)
    join = (oh[:, :, None] == ih[:, None, :]) & \
        o_ok[:, :, None] & i_ok[:, None, :]
    return case2 | jnp.any(join, axis=(1, 2))


def pack_bits_ref(x: jax.Array) -> jax.Array:
    """(..., N) 0/1 float -> (..., N//32) uint32, bit j of word w =
    column ``32*w + j``."""
    n = x.shape[-1]
    assert n % 32 == 0
    xb = (x > 0).astype(jnp.uint32).reshape(*x.shape[:-1], n // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (xb << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits_ref(xp: jax.Array, dtype=jnp.float32) -> jax.Array:
    w = xp.shape[-1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (xp[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*xp.shape[:-1], w * 32).astype(dtype)


def bitpack_matmul_ref(a: jax.Array, b_packed: jax.Array) -> jax.Array:
    """OR-AND product with a bit-packed right operand:
    out_packed[m, w] = OR_k a[m, k] & b_packed[k, w] (bitwise)."""
    mask = (a > 0)
    # big-OR via max over K of masked words
    masked = jnp.where(mask[:, :, None], b_packed[None, :, :],
                       jnp.uint32(0))
    out = masked[:, 0, :]
    out = jax.lax.reduce(masked, jnp.uint32(0),
                         jax.lax.bitwise_or, dimensions=(1,))
    return out


def frontier_step_ref(frontier: jax.Array, A: jax.Array,
                      label: jax.Array) -> jax.Array:
    """Product-automaton step: next[b, v] = OR_u frontier[b, u] & A[label, u, v]."""
    return bool_matmul_ref(frontier, A[label])
