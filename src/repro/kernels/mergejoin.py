"""Pallas TPU kernel: batched RLC query join (Algorithm 1 on device).

One grid step evaluates one query ``(s, t, mr)``: the ``L_out(s)`` and
``L_in(t)`` rows are streamed into VMEM by scalar-prefetch indexed
BlockSpecs (the TPU answer to the pointer-chase gather), Case 2 is a pair
of vector compares and Case 1 an ``(E, E)`` broadcast join on the VPU —
the dense equivalent of the paper's aid-ordered merge join.

Inputs are the padded DeviceIndex arrays (PAD = -1 never matches).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.constants import PAD


def _mergejoin_kernel(s_ref, t_ref, mr_ref,       # scalar prefetch
                      oh_ref, om_ref, ih_ref, im_ref,  # (1, E) rows
                      o_ref):                      # (1, 1) int32 out
    q = pl.program_id(0)
    t = t_ref[q]
    s = s_ref[q]
    mr = mr_ref[q]
    oh = oh_ref[0, :]
    om = om_ref[0, :]
    ih = ih_ref[0, :]
    im = im_ref[0, :]
    case2 = jnp.any((oh == t) & (om == mr)) | jnp.any((ih == s) & (im == mr))
    o_ok = (om == mr) & (oh != PAD)
    i_ok = (im == mr) & (ih != PAD)
    join = (oh[:, None] == ih[None, :]) & o_ok[:, None] & i_ok[None, :]
    o_ref[0, 0] = (case2 | jnp.any(join)).astype(jnp.int32)


def query_batch(out_hub: jax.Array, out_mr: jax.Array, in_hub: jax.Array,
                in_mr: jax.Array, s: jax.Array, t: jax.Array,
                mr: jax.Array, *, interpret: bool = False,
                row_base_out: int = 0, row_base_in: int = 0) -> jax.Array:
    """Returns (Q,) bool answers. E (row length) rides fully in VMEM.

    ``row_base_*`` offset the scalar-prefetch row lookups for
    row-windowed shard layouts (storage row = vertex id - base); the
    kernel body still compares the global ids in ``s``/``t``.
    """
    n, E = out_hub.shape
    Q = s.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(Q,),
        in_specs=[
            pl.BlockSpec((1, E),
                         lambda q, s_r, t_r, m_r: (s_r[q] - row_base_out, 0)),
            pl.BlockSpec((1, E),
                         lambda q, s_r, t_r, m_r: (s_r[q] - row_base_out, 0)),
            pl.BlockSpec((1, E),
                         lambda q, s_r, t_r, m_r: (t_r[q] - row_base_in, 0)),
            pl.BlockSpec((1, E),
                         lambda q, s_r, t_r, m_r: (t_r[q] - row_base_in, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda q, s_r, t_r, m_r: (q, 0)),
    )
    out = pl.pallas_call(
        _mergejoin_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        interpret=interpret,
    )(s.astype(jnp.int32), t.astype(jnp.int32), mr.astype(jnp.int32),
      out_hub, out_mr, in_hub, in_mr)
    return out[:, 0] > 0
