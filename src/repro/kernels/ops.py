"""Jit'd public wrappers for the Pallas kernels: padding to block multiples,
interpret-mode dispatch on CPU (the container has no TPU — kernels are
authored for TPU and validated via the interpreter), and a uniform
``matmul``-shaped interface the dense engine can plug in.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import bitpack as _bitpack
from . import bool_semiring as _bs
from . import label_frontier as _lf
from . import mergejoin as _mj

_ON_CPU = jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, mults):
    pads = []
    needs = False
    for dim, mult in zip(x.shape, mults):
        target = ((dim + mult - 1) // mult) * mult
        pads.append((0, target - dim))
        needs |= target != dim
    return jnp.pad(x, pads) if needs else x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def bool_matmul(a: jax.Array, b: jax.Array, bm: int = 128, bk: int = 128,
                bn: int = 128, interpret: Optional[bool] = None
                ) -> jax.Array:
    """Padded OR-AND semiring matmul via the Pallas kernel."""
    interpret = _ON_CPU if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    ap = _pad_to(a, (bm_, bk_))
    bp = _pad_to(b, (bk_, bn_))
    out = _bs.bool_matmul(ap, bp, bm=bm_, bk=bk_, bn=bn_,
                          interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def closure_step(r: jax.Array, bm: int = 128, bk: int = 128, bn: int = 128,
                 interpret: Optional[bool] = None) -> jax.Array:
    interpret = _ON_CPU if interpret is None else interpret
    n = r.shape[0]
    b = min(bm, n)
    rp = _pad_to(r, (b, b))
    out = _bs.closure_step(rp, bm=min(bm, rp.shape[0]),
                           bk=min(bk, rp.shape[0]),
                           bn=min(bn, rp.shape[0]), interpret=interpret)
    return out[:n, :n]


@functools.partial(jax.jit, static_argnames=("interpret", "row_base_out",
                                             "row_base_in"))
def mergejoin_query(out_hub, out_mr, in_hub, in_mr, s, t, mr,
                    interpret: Optional[bool] = None,
                    row_base_out: int = 0,
                    row_base_in: int = 0) -> jax.Array:
    interpret = _ON_CPU if interpret is None else interpret
    return _mj.query_batch(out_hub, out_mr, in_hub, in_mr, s, t, mr,
                           interpret=interpret, row_base_out=row_base_out,
                           row_base_in=row_base_in)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitpack_matmul(a, b_packed, interpret: Optional[bool] = None):
    interpret = _ON_CPU if interpret is None else interpret
    m, k = a.shape
    _, w = b_packed.shape
    bm, bk, bw = min(128, m), min(128, k), min(128, w)
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b_packed, (bk, bw))
    out = _bitpack.bitpack_matmul(ap, bp, bm=bm, bk=bk, bw=bw,
                                  interpret=interpret)
    return out[:m, :w]


@functools.partial(jax.jit, static_argnames=("interpret",))
def frontier_step(frontier, A, label, interpret: Optional[bool] = None):
    interpret = _ON_CPU if interpret is None else interpret
    B, V = frontier.shape
    bb, bk = min(128, B), min(128, V)
    fp = _pad_to(frontier, (bb, bk))
    Ap = _pad_to(A, (A.shape[0], bk, bk))
    out = _lf.frontier_step(fp, Ap, label, bb=min(128, fp.shape[0]),
                            bk=min(128, Ap.shape[1]),
                            bn=min(128, Ap.shape[2]), interpret=interpret)
    return out[:B, :V]


pack_bits = _bitpack.pack_bits
unpack_bits = _bitpack.unpack_bits
