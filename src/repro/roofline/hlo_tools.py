"""HLO-text profiling tools for the dry-run perf loop (no real hardware:
the optimized per-device HLO *is* the profile).

``dot_flops_histogram`` attributes every dot/convolution's flops to its
jax op_name (metadata), so a 3x-over-model-flops cell can be traced to
the offending einsum. ``buffer_histogram`` ranks the largest tensors.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\(?.*?\)?)\s*"
    r"(?P<op>[\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")
_META_RE = re.compile(r'op_name="([^"]+)"')
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _shorten(op_name: str) -> str:
    """Collapse a jax op_name path to its meaningful tail."""
    parts = [p for p in op_name.split("/") if p and not p.startswith("jit(")]
    tail = parts[-3:] if len(parts) >= 3 else parts
    return "/".join(tail)


def parse_symbol_shapes(hlo_text: str) -> Dict[str, Tuple[str, Tuple]]:
    """%name -> (dtype, shape) for every defined value."""
    table: Dict[str, Tuple[str, Tuple]] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        shapes = _parse_shapes(m.group("type"))
        if shapes:
            table[m.group("name")] = shapes[0]
    return table


def dot_flops_histogram(hlo_text: str, top: int = 25
                        ) -> List[Tuple[str, float, int]]:
    """[(op_name tail, flops, count)] for dot ops, descending.

    flops = 2 * numel(output) * prod(contracting dims of lhs). Operand
    shapes come from the symbol table (HLO text annotates only outputs).
    """
    table = parse_symbol_shapes(hlo_text)
    hist: Dict[str, List[float]] = defaultdict(lambda: [0.0, 0])
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m or m.group("op") != "dot":
            continue
        out_shapes = _parse_shapes(m.group("type"))
        if not out_shapes:
            continue
        args_m = _OPERANDS_RE.search(line[m.end() - 1:])
        cdims_m = _DOT_DIMS_RE.search(line)
        if not args_m or not cdims_m:
            continue
        operands = [a.strip().lstrip("%")
                    for a in args_m.group(1).split(",")]
        lhs = table.get(operands[0])
        if lhs is None:
            continue
        cdims = [int(x) for x in cdims_m.group(1).split(",") if x]
        csize = 1
        for cd in cdims:
            if cd < len(lhs[1]):
                csize *= lhs[1][cd]
        flops = 2.0 * _numel(out_shapes[0][1]) * csize
        meta = _META_RE.search(line)
        key = _shorten(meta.group(1)) if meta else "<no-meta>"
        hist[key][0] += flops
        hist[key][1] += 1
    rows = [(k, v[0], int(v[1])) for k, v in hist.items()]
    rows.sort(key=lambda r: -r[1])
    return rows[:top]


def buffer_histogram(hlo_text: str, top: int = 25,
                     min_bytes: int = 1 << 20
                     ) -> List[Tuple[str, int, str]]:
    """Largest tensors defined in the module: [(op_name tail, bytes,
    'dtype[shape]')]."""
    rows = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        shapes = _parse_shapes(m.group("type"))
        if not shapes:
            continue
        total = sum(_numel(s) * _DTYPE_BYTES[d] for d, s in shapes)
        if total < min_bytes:
            continue
        meta = _META_RE.search(line)
        key = _shorten(meta.group(1)) if meta else m.group("op")
        desc = ", ".join(f"{d}[{','.join(map(str, s))}]"
                         for d, s in shapes[:2])
        rows.append((key, total, desc))
    rows.sort(key=lambda r: -r[1])
    # dedupe identical (key, desc) keeping counts
    agg: Dict[Tuple[str, str], List[int]] = defaultdict(lambda: [0, 0])
    for k, b, d in rows:
        agg[(k, d)][0] += b
        agg[(k, d)][1] += 1
    out = [(f"{k} x{c[1]}", c[0], d) for (k, d), c in agg.items()]
    out.sort(key=lambda r: -r[1])
    return out[:top]


# ------------------------------------------------------------------ #
# Computation-tree walk: exact totals under lax.scan (while loops)
# ------------------------------------------------------------------ #
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_KIND_RE = re.compile(
    r"=\s+(?P<type>\(?[a-z0-9\[\],{}\s]*?\)?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<phase>-start|-done)?\(")

_NO_TRAFFIC_OPS = {"parameter", "bitcast", "get-tuple-element", "tuple",
                   "constant", "while", "conditional", "call"}


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its body lines (text between braces).

    Header lines end with ``{`` and contain ``->``; params may be nested
    tuple types with ``/*index=N*/`` comments, so the name is taken as
    the first (non-ENTRY) whitespace token."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s and not s.startswith("//"):
            toks = s.split()
            name = (toks[1] if toks[0] == "ENTRY" else toks[0])
            name = name.lstrip("%")
            i = name.find("(")
            if i > 0:
                name = name[:i]
            cur = name
            if toks[0] == "ENTRY":
                entry = cur
            comps[cur] = []
            continue
        if cur is not None:
            if s == "}":
                cur = None
                continue
            comps[cur].append(line)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _line_dot_flops(line: str, table) -> float:
    m = _DEF_RE.match(line)
    if not m or m.group("op") != "dot":
        return 0.0
    out_shapes = _parse_shapes(m.group("type"))
    args_m = _OPERANDS_RE.search(line[m.end() - 1:])
    cdims_m = _DOT_DIMS_RE.search(line)
    if not out_shapes or not args_m or not cdims_m:
        return 0.0
    # Optimized HLO spells operands with their types —
    # ``dot(f32[256,256]{1,0} %lhs, ...)`` — so a naive comma split lands
    # inside the shape; take the first %-name (or bare name) token instead.
    first_ref = re.search(r"%([\w.\-]+)", args_m.group(1))
    lhs_name = (first_ref.group(1) if first_ref
                else args_m.group(1).split(",")[0].strip())
    lhs = table.get(lhs_name)
    if lhs is None:
        return 0.0
    csize = 1
    for cd in (int(x) for x in cdims_m.group(1).split(",") if x):
        if cd < len(lhs[1]):
            csize *= lhs[1][cd]
    return 2.0 * _numel(out_shapes[0][1]) * csize


def _line_coll_wire(line: str) -> Tuple[Optional[str], int]:
    m = _COLL_KIND_RE.search(line)
    if not m or m.group("phase") == "-done":
        return None, 0
    obytes = sum(_numel(s) * _DTYPE_BYTES[d]
                 for d, s in _parse_shapes(m.group("type")))
    g_m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    g = int(g_m.group(2)) if g_m else 2
    kind = m.group("kind")
    if kind == "all-gather":
        return kind, obytes * (g - 1) // g
    if kind == "reduce-scatter":
        return kind, obytes * (g - 1)
    if kind == "all-reduce":
        return kind, 2 * obytes * (g - 1) // g
    if kind == "all-to-all":
        return kind, obytes * (g - 1) // g
    return kind, obytes


def _line_out_bytes(line: str) -> int:
    m = _DEF_RE.match(line)
    if not m or m.group("op") in _NO_TRAFFIC_OPS:
        return 0
    return sum(_numel(s) * _DTYPE_BYTES[d]
               for d, s in _parse_shapes(m.group("type")))


# ops that remain HBM-traffic after TPU-grade fusion: everything else
# (elementwise chains, converts, broadcasts) fuses into these.
_MEM_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "copy",
    "transpose", "sort", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute", "pad", "concatenate", "slice",
    "iota", "rng-bit-generator", "select-and-scatter", "cholesky",
    "triangular-solve",
}


def _line_fused_traffic(line: str, table) -> int:
    """Fusion-aware HBM bytes: operands + outputs of memory-touching ops
    (the TPU-optimistic floor; elementwise chains assumed fused away).

    Sparse-access ops only touch the addressed region, not the whole
    operand: gather/dynamic-slice read ~output bytes; dynamic-update-
    slice/scatter read+write ~update bytes (operand 0 is aliased)."""
    m = _DEF_RE.match(line)
    if not m or m.group("op") not in _MEM_OPS:
        return 0
    op = m.group("op")
    out = sum(_numel(s) * _DTYPE_BYTES[d]
              for d, s in _parse_shapes(m.group("type")))
    if op in ("gather", "dynamic-slice", "slice"):
        return 2 * out                      # read region + write output
    if op in ("dynamic-update-slice", "scatter"):
        args_m = _OPERANDS_RE.search(line[m.end() - 1:])
        upd = 0
        if args_m:
            ops_ = args_m.group(1).split(",")
            if len(ops_) >= 2:
                ent = table.get(ops_[1].strip().lstrip("%"))
                if ent:
                    upd = _numel(ent[1]) * _DTYPE_BYTES.get(ent[0], 0)
        return 2 * upd                      # read-modify-write the region
    args_m = _OPERANDS_RE.search(line[m.end() - 1:])
    if args_m:
        for a in args_m.group(1).split(","):
            ent = table.get(a.strip().lstrip("%"))
            if ent:
                out += _numel(ent[1]) * _DTYPE_BYTES.get(ent[0], 0)
    return out


def scan_aware_totals(hlo_text: str) -> Dict[str, float]:
    """Walk ENTRY -> fusions/calls/while-bodies, multiplying while bodies
    by their trip count (parsed from the loop condition's constant).

    Returns {"flops", "coll_<kind>", "coll_total", "hbm_bytes_est"}.
    flops counts dots everywhere (fusion internals are real MXU work);
    hbm_bytes_est counts top-level op outputs x2 (read+write approx),
    skipping fusion internals (they stay in registers/VMEM).
    """
    comps = split_computations(hlo_text)
    table = parse_symbol_shapes(hlo_text)

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for line in comps.get(cond_name, [])
                  for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1


    def walk(name: str, count_bytes: bool):
        flops = 0.0
        coll: Dict[str, float] = defaultdict(float)
        bts = 0.0
        fused = 0.0
        for line in comps.get(name, []):
            flops += _line_dot_flops(line, table)
            kind, wire = _line_coll_wire(line)
            if kind:
                coll[kind] += wire
            if count_bytes:
                bts += _line_out_bytes(line)
                fused += _line_fused_traffic(line, table)
            if " while(" in line:
                bm = _WHILE_BODY_RE.search(line)
                if bm:
                    tm = _TRIP_RE.search(line)
                    if tm:
                        t = int(tm.group(1))
                    else:
                        cm_ = _WHILE_COND_RE.search(line)
                        t = trip_count(cm_.group(1)) if cm_ else 1
                    f2, c2, b2, fu2 = walk(bm.group(1), count_bytes)
                    flops += t * f2
                    bts += t * b2
                    fused += t * fu2
                    for k, v in c2.items():
                        coll[k] += t * v
                continue
            cm = _CALLS_RE.search(line)
            if cm and " fusion(" in line:
                # fusion internals: flops yes, hbm traffic no
                f2, c2, _, _ = walk(cm.group(1), False)
                flops += f2
                for k, v in c2.items():
                    coll[k] += v
            elif cm and (" call(" in line or " conditional(" in line):
                f2, c2, b2, fu2 = walk(cm.group(1), count_bytes)
                flops += f2
                bts += b2
                fused += fu2
                for k, v in c2.items():
                    coll[k] += v
        return flops, coll, bts, fused

    entry = "__entry__" if "__entry__" in comps else next(iter(comps))
    flops, coll, bts, fused = walk(entry, True)
    out = {"flops": flops, "hbm_bytes_est": fused,
           "hbm_bytes_upper": 2.0 * bts}
    for k, v in coll.items():
        out[f"coll_{k}"] = v
    out["coll_total"] = sum(coll.values())
    return out


def op_bytes_by_kind(hlo_text: str) -> Dict[str, int]:
    """Total output bytes per HLO op kind (coarse memory-traffic view)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        shapes = _parse_shapes(m.group("type"))
        total = sum(_numel(s) * _DTYPE_BYTES[d] for d, s in shapes)
        out[m.group("op")] += total
    return dict(sorted(out.items(), key=lambda kv: -kv[1]))
