"""Roofline analysis from compiled dry-run artifacts (assignment §Roofline).

All compiled-module numbers (cost_analysis flops/bytes, HLO collective
operand bytes) are PER-DEVICE — XLA compiles the SPMD-partitioned program
(verified empirically: a (512,512,512) matmul on 8 devices reports
2*512^3/8 flops). Therefore:

    compute    = flops_per_dev / peak_flops_per_chip
    memory     = bytes_per_dev / hbm_bw_per_chip
    collective = collective_bytes_per_dev / ici_bw_per_chip

ici_bw accounts for link count per chip on the 2D torus mesh axes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

# TPU v5e-class hardware constants (assignment-specified)
@dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # B/s / chip
    ici_bw_per_link: float = 50e9     # B/s / link (~)
    ici_links: int = 4                # 2D torus: 4 links/chip


HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_LINE_RE = re.compile(
    r"=\s+(?P<type>\(?[a-z0-9\[\],{}\s]*?\)?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<phase>-start|-done)?\(")
_TYPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))        # [num_groups, group_size]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes per collective kind, from the per-device
    SPMD module.

    Compiled HLO prints only the OUTPUT type inline
    (``%ag = f32[4,48] all-gather(%x), replica_groups=[16,16]<=[256]``),
    so bytes-on-wire per device derive from output size O and group size
    g via ring algorithms:
      all-gather          O*(g-1)/g      (receives all but its own shard)
      reduce-scatter      O*(g-1)        (input = O*g streams through)
      all-reduce          2*O*(g-1)/g    (RS + AG phases)
      all-to-all          O*(g-1)/g
      collective-permute  O
    ``-start`` counted, ``-done`` skipped (same transfer).
    """
    out: Dict[str, int] = {}
    raw: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m or m.group("phase") == "-done":
            continue
        kind = m.group("kind")
        obytes = sum(_shape_bytes(d, s)
                     for d, s in _TYPE_RE.findall(m.group("type")))
        g = _group_size(line)
        if kind == "all-gather":
            wire = obytes * (g - 1) // g
        elif kind == "reduce-scatter":
            wire = obytes * (g - 1)
        elif kind == "all-reduce":
            wire = 2 * obytes * (g - 1) // g
        elif kind == "all-to-all":
            wire = obytes * (g - 1) // g
        else:  # collective-permute
            wire = obytes
        out[kind] = out.get(kind, 0) + wire
        raw[kind] = raw.get(kind, 0) + obytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    for k, v in raw.items():
        out[f"raw_output_{k}"] = v
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: _HW = HW
                   ) -> Dict[str, float]:
    compute = flops_per_dev / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = coll_bytes_per_dev / (hw.ici_bw_per_link * hw.ici_links)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["roofline_fraction"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int,
                n_params_active: int, n_params_embed: int = 0) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference),
    D = processed tokens. Embedding params excluded from N by convention."""
    n = n_params_active - n_params_embed
    if shape_kind == "train":
        per_tok = 6 * n
        tokens = seq_len * global_batch
    elif shape_kind == "prefill":
        per_tok = 2 * n
        tokens = seq_len * global_batch
    else:  # decode: one token per sequence
        per_tok = 2 * n
        tokens = global_batch
    return float(per_tok) * float(tokens)


def active_params(cfg, params_total: int) -> int:
    """MoE: count routed experts once per top_k instead of num_experts."""
    if cfg.num_experts and cfg.top_k:
        expert_p = (3 * cfg.d_model * cfg.moe_d_ff) * cfg.num_experts
        n_moe_layers = sum(1 for k in cfg.block_pattern if k == "moe")
        all_experts = expert_p * n_moe_layers
        active_experts = all_experts * cfg.top_k // cfg.num_experts
        return params_total - all_experts + active_experts
    return params_total
