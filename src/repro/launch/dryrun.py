import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell with ShapeDtypeStruct inputs —
no allocation — and record memory/cost/collective analyses for §Roofline.

The two lines above MUST precede any other import (jax locks the device
count on first init). Run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh pod            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/

Cells also cover the paper's own workloads (--arch rlc-build-64k /
rlc-query-1m): the RLC index build step and the batched query join are
lowered on the same production meshes.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, cell_supported, get_config
from repro.configs.rlc_paper import RLC_CELLS
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import decode_step, init_cache, init_model, prefill
from repro.models.builder import count_params
from repro.roofline.analysis import (active_params,
                                     collective_bytes_from_hlo,
                                     model_flops, roofline_terms)
from repro.sharding.partition import (ACT_RULES, PARAM_RULES,
                                      logical_to_sharding, tree_shardings)
from repro.train import OptConfig, make_train_step
from repro.train.train_loop import init_train_state


# ------------------------------------------------------------------ #
# Input specs (ShapeDtypeStruct stand-ins; shardable, no allocation)
# ------------------------------------------------------------------ #
def input_specs(cfg, shape, mesh) -> Dict:
    """Abstract inputs + shardings for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch_sharding = logical_to_sharding(
        (B, S), ("act_batch", None), mesh, ACT_RULES)
    out = {"kind": shape.kind}
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        shards = {"tokens": batch_sharding, "labels": batch_sharding}
        if cfg.frontend != "none":
            fe = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
            batch["frontend"] = fe
            shards["frontend"] = logical_to_sharding(
                fe.shape, ("act_batch", None, None), mesh, ACT_RULES)
        out.update(batch=batch, batch_shardings=shards)
    elif shape.kind == "prefill":
        out.update(tokens=tok, tokens_sharding=batch_sharding)
        if cfg.frontend != "none":
            fe = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
            out.update(frontend=fe, frontend_sharding=logical_to_sharding(
                fe.shape, ("act_batch", None, None), mesh, ACT_RULES))
    else:  # decode: one new token against a seq_len cache
        t1 = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        out.update(token=t1, token_sharding=logical_to_sharding(
            (B, 1), ("act_batch", None), mesh, ACT_RULES))
    return out


def _decode_cache_specs(cfg, shape, mesh):
    # VLM prefix tokens extend the cached sequence (early fusion)
    max_len = shape.seq_len + (cfg.frontend_len
                               if cfg.frontend == "patch_stub" else 0)
    cache, cache_axes = init_cache(cfg, shape.global_batch, max_len,
                                   abstract=True)
    if cfg.encoder_layers:
        # enc_kv rides in the cache for enc-dec archs
        from repro.models.lm import _enc_kv_tree  # shapes via abstract eval
        params, _ = init_model(cfg, abstract=True)
        enc_out = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.frontend_len, cfg.d_model),
            cfg.dtype("compute"))
        kv = jax.eval_shape(lambda p, e: _enc_kv_tree(p, cfg, e),
                            params, enc_out)
        cache["enc_kv"] = kv
        cache_axes["enc_kv"] = jax.tree.map(
            lambda l: ("layers",) * (l.ndim - 4) +
            ("act_batch", None, "kv", None), kv,
            is_leaf=lambda l: hasattr(l, "shape"))
    shardings = tree_shardings(cache, cache_axes, mesh, ACT_RULES)
    return cache, shardings


# ------------------------------------------------------------------ #
# Cell lowering
# ------------------------------------------------------------------ #
def lower_cell(arch: str, shape_name: str, mesh, microbatches: int = 1,
               remat: Optional[str] = None, ssm_chunk: int = 0,
               moe_combine: Optional[str] = None,
               attn_chunk: int = 0) -> Dict:
    """Lower + compile one cell; returns the §Roofline record."""
    if arch.startswith("rlc-"):
        return lower_rlc_cell(arch, mesh)
    cfg = get_config(arch)
    if ssm_chunk:
        cfg = cfg.replace(ssm_chunk=ssm_chunk)
    if moe_combine:
        cfg = cfg.replace(moe_combine=moe_combine)
    if attn_chunk:
        cfg = cfg.replace(attn_chunk=attn_chunk)
    # Layers stay SCANNED (compile cost ~layer-count-independent);
    # roofline totals come from the scan-aware HLO walk, which multiplies
    # while-loop bodies by their trip counts (XLA's cost_analysis visits
    # them once and under-counts by ~num_layers x microbatches).
    if remat:
        cfg = cfg.replace(remat=remat)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": reason}

    specs = input_specs(cfg, shape, mesh)
    t0 = time.time()
    with mesh_context(mesh):
        if shape.kind == "train":
            oc = OptConfig()
            state, state_axes = init_train_state(cfg, oc, abstract=True)
            state_sh = tree_shardings(state, state_axes, mesh, PARAM_RULES)
            step_fn = make_train_step(cfg, oc, microbatches=microbatches)
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, specs["batch_shardings"]),
                out_shardings=(state_sh, None),
            ).lower(state, specs["batch"])
        elif shape.kind == "prefill":
            params, axes = init_model(cfg, abstract=True)
            p_sh = tree_shardings(params, axes, mesh, PARAM_RULES)
            cache, cache_sh = _decode_cache_specs(cfg, shape, mesh)
            if cfg.encoder_layers:
                cache.pop("enc_kv", None)
                cache_sh.pop("enc_kv", None)

            def prefill_fn(p, tokens, cache, frontend=None):
                return prefill(p, cfg, tokens, cache, frontend)

            args = [params, specs["tokens"], cache]
            in_sh = [p_sh, specs["tokens_sharding"], cache_sh]
            if cfg.frontend != "none":
                args.append(specs["frontend"])
                in_sh.append(specs["frontend_sharding"])
            lowered = jax.jit(prefill_fn,
                              in_shardings=tuple(in_sh)).lower(*args)
        else:  # decode
            params, axes = init_model(cfg, abstract=True)
            p_sh = tree_shardings(params, axes, mesh, PARAM_RULES)
            cache, cache_sh = _decode_cache_specs(cfg, shape, mesh)
            pos = jax.ShapeDtypeStruct((), jnp.int32)

            def decode_fn(p, cache, token, pos):
                return decode_step(p, cfg, cache, token, pos)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(p_sh, cache_sh, specs["token_sharding"],
                              None),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params, cache, specs["token"], pos)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.roofline.hlo_tools import scan_aware_totals
    totals = scan_aware_totals(hlo)
    coll = {k[5:]: int(v) for k, v in totals.items()
            if k.startswith("coll_")}
    n_chips = int(np.prod(list(mesh.shape.values())))
    flops_dev = float(totals["flops"])
    bytes_dev = float(totals["hbm_bytes_est"])
    terms = roofline_terms(flops_dev, bytes_dev, float(coll["total"]))

    params_abs, _ = init_model(cfg, abstract=True)
    n_params = count_params(params_abs)
    n_active = active_params(cfg, n_params)
    embed_params = cfg.padded_vocab * cfg.d_model * (
        1 if cfg.tie_embeddings else 2)
    mf = model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch,
                     n_active, embed_params)
    if shape.kind == "train":
        pass  # 6ND already
    hlo_flops_total = flops_dev * n_chips
    record = {
        "arch": arch, "shape": shape_name, "skipped": False,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "compile_seconds": round(t1 - t0, 1),
        "params": n_params, "params_active": n_active,
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
            "peak_bytes_per_dev": (mem.argument_size_in_bytes
                                   + mem.output_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   - mem.alias_size_in_bytes),
        },
        "cost": {"flops_per_dev": flops_dev,
                 "bytes_per_dev": bytes_dev,
                 "hlo_flops_total": hlo_flops_total,
                 "xla_flops_per_dev": float(cost.get("flops", 0.0)),
                 "xla_bytes_per_dev": float(cost.get("bytes accessed",
                                                     0.0))},
        "collectives": coll,
        "roofline": terms,
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_flops_total
                               if hlo_flops_total else 0.0),
    }
    return record


# ------------------------------------------------------------------ #
# The paper's own cells
# ------------------------------------------------------------------ #
def lower_rlc_cell(name: str, mesh) -> Dict:
    """Lower the RLC engine's two hot steps on the production mesh."""
    cell = RLC_CELLS[name]
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with mesh_context(mesh):
        if cell.hub_batch:
            # one log-doubling closure step over the reachability matrix:
            # R | R @ R with R (C_mr batch folded into rows) row-sharded
            n = cell.num_vertices
            R = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
            row_sh = logical_to_sharding(
                (n, n), ("act_batch", "act_heads"), mesh,
                {"act_batch": ("pod", "data"), "act_heads": "model"})

            def closure_step(r):
                rr = (jnp.matmul(r, r, preferred_element_type=jnp.float32)
                      > 0).astype(r.dtype)
                return jnp.maximum(r, rr)

            lowered = jax.jit(closure_step, in_shardings=(row_sh,),
                              out_shardings=row_sh).lower(R)
        else:
            # batched query join: Q queries against padded (n, E) rows
            Q, E = cell.query_batch, cell.row_len
            n = cell.num_vertices
            rep = NamedSharding(mesh, P())
            qsh = logical_to_sharding(
                (Q,), ("act_batch",), mesh, ACT_RULES)
            rows = jax.ShapeDtypeStruct((n, E), jnp.int32)
            qv = jax.ShapeDtypeStruct((Q,), jnp.int32)
            if name.endswith("-sorted"):
                # §Perf iteration: sorted-key searchsorted intersection
                from repro.core.device_index import _query_batch_sorted

                def qfn(ok, ik, s, t, m):
                    return _query_batch_sorted(ok, ik, s, t, m,
                                               num_mrs=72)

                lowered = jax.jit(
                    qfn, in_shardings=(rep,) * 2 + (qsh,) * 3,
                    out_shardings=qsh,
                ).lower(rows, rows, qv, qv, qv)
            else:
                from repro.core.device_index import _query_batch_ref
                lowered = jax.jit(
                    _query_batch_ref,
                    in_shardings=(rep,) * 4 + (qsh,) * 3,
                    out_shardings=qsh,
                ).lower(rows, rows, rows, rows, qv, qv, qv)
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops_dev, bytes_dev, float(coll["total"]))
    return {
        "arch": name, "shape": "paper", "skipped": False,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "compile_seconds": round(t1 - t0, 1),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "peak_bytes_per_dev": (mem.argument_size_in_bytes
                                   + mem.output_size_in_bytes
                                   + mem.temp_size_in_bytes),
        },
        "cost": {"flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
                 "hlo_flops_total": flops_dev * n_chips},
        "collectives": coll,
        "roofline": terms,
    }


# ------------------------------------------------------------------ #
def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default="train_4k",
                    choices=list(SHAPES) + ["paper"])
    ap.add_argument("--mesh", type=str, default="pod",
                    choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) cell on this mesh")
    ap.add_argument("--out", type=str, default="benchmarks/artifacts/dryrun")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="grad-accum microbatches for train cells (8 keeps "
                    "the 256x4k global batch within 16G HBM)")
    ap.add_argument("--ssm-chunk", type=int, default=0,
                    help="override SSD chunk length (perf iteration)")
    ap.add_argument("--moe-combine", type=str, default=None,
                    choices=[None, "gather", "scatter"],
                    help="override MoE combine formulation")
    ap.add_argument("--attn-chunk", type=int, default=0,
                    help="chunked online-softmax attention block size")
    ap.add_argument("--remat", type=str, default=None)
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, \
        f"dry-run needs 512 placeholder devices, got {len(jax.devices())}"
    mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    os.makedirs(args.out, exist_ok=True)

    def run_one(arch, shape_name):
        tag = f"{arch}__{shape_name}__{args.mesh}"
        if args.remat:
            tag += f"__remat-{args.remat}"
        if args.microbatches != 1:
            tag += f"__mb{args.microbatches}"
        if args.ssm_chunk:
            tag += f"__chunk{args.ssm_chunk}"
        if args.moe_combine:
            tag += f"__{args.moe_combine}"
        if args.attn_chunk:
            tag += f"__attnchunk{args.attn_chunk}"
        path = os.path.join(args.out, tag + ".json")
        try:
            rec = lower_cell(arch, shape_name, mesh,
                             microbatches=args.microbatches,
                             remat=args.remat, ssm_chunk=args.ssm_chunk,
                             moe_combine=args.moe_combine,
                             attn_chunk=args.attn_chunk)
            rec["status"] = "ok" if not rec.get("skipped") else "skipped"
        except Exception as e:
            rec = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec.get("status")
        extra = ""
        if status == "ok":
            r = rec.get("roofline", {})
            extra = (f" dom={r.get('dominant')} "
                     f"frac={r.get('roofline_fraction', 0):.3f} "
                     f"compile={rec.get('compile_seconds')}s")
        elif status == "skipped":
            extra = f" ({rec.get('reason', '')[:60]})"
        else:
            extra = f" !! {rec.get('error', '')[:160]}"
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
        return rec

    if args.all:
        from repro.configs import ASSIGNED
        ok = True
        for arch in ASSIGNED:
            for shape_name in SHAPES:
                rec = run_one(arch, shape_name)
                ok &= rec.get("status") in ("ok", "skipped")
        for rlc in RLC_CELLS:
            rec = run_one(rlc, "paper")
            ok &= rec.get("status") in ("ok", "skipped")
        sys.exit(0 if ok else 1)
    else:
        rec = run_one(args.arch, args.shape)
        if rec.get("status") == "ok":
            print(json.dumps(
                {k: rec[k] for k in ("memory", "cost", "collectives",
                                     "roofline") if k in rec}, indent=1))
        sys.exit(0 if rec.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
