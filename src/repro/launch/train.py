"""End-to-end training driver (deliverable b): data -> train_step ->
checkpoint/restart, on whatever mesh the host offers.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b-smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Restart-safe: re-running the same command resumes from the latest
checkpoint (the data pipeline is a pure function of step). The ~100M-param
example run lives in examples/train_lm.py.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.ft import StragglerMonitor, resilient_loop
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.sharding.partition import PARAM_RULES, tree_shardings
from repro.train import OptConfig, make_train_step
from repro.train.train_loop import init_train_state


def run(arch: str, steps: int, batch: int, seq: int,
        ckpt_dir: Optional[str] = None, lr: float = 3e-4,
        microbatches: int = 1, ckpt_every: int = 25,
        model_parallel: int = 1, log_every: int = 10,
        seed: int = 0, fail_at=None):
    cfg = get_config(arch)
    oc = OptConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                   total_steps=steps,
                   m_dtype="float32" if cfg.param_dtype == "float32"
                   else "bfloat16",
                   v_dtype="float32" if cfg.param_dtype == "float32"
                   else "bfloat16",
                   grad_dtype="float32" if cfg.param_dtype == "float32"
                   else "bfloat16")
    mesh = make_host_mesh(model=model_parallel)
    dc = DataConfig(seq_len=seq, global_batch=batch, seed=seed)
    data = SyntheticLMData(cfg, dc)

    state, state_axes = init_train_state(cfg, oc, jax.random.PRNGKey(seed))
    state_sh = tree_shardings(state, state_axes, mesh, PARAM_RULES)
    state = jax.tree.map(jax.device_put, state, state_sh)
    step_fn = make_train_step(cfg, oc, microbatches=microbatches)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    monitor = StragglerMonitor()
    history = []

    def batch_at(step):
        return {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}

    if ckpt_dir:
        def wrapped(state, b):
            with mesh_context(mesh):
                s, m = jit_step(state, b)
            history.append(float(m["loss"]))
            if len(history) % log_every == 0:
                print(f"[train {arch}] step={len(history)} "
                      f"loss={history[-1]:.4f} "
                      f"lr={float(m['lr']):.2e} "
                      f"gnorm={float(m['grad_norm']):.3f}", flush=True)
            return s, m

        state, report = resilient_loop(
            wrapped, state, batch_at, steps, ckpt_dir,
            ckpt_every=ckpt_every, monitor=monitor, fail_at=fail_at)
        return state, history, report

    with mesh_context(mesh):
        for step in range(steps):
            t0 = time.perf_counter()
            state, metrics = jit_step(state, batch_at(step))
            jax.block_until_ready(metrics["loss"])
            monitor.record(step, time.perf_counter() - t0)
            history.append(float(metrics["loss"]))
            if (step + 1) % log_every == 0:
                print(f"[train {arch}] step={step+1} "
                      f"loss={history[-1]:.4f} "
                      f"lr={float(metrics['lr']):.2e}", flush=True)
    return state, history, None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    _, history, report = run(
        args.arch, args.steps, args.batch, args.seq, args.ckpt_dir,
        args.lr, args.microbatches, args.ckpt_every, args.model_parallel,
        seed=args.seed)
    print(f"[train {args.arch}] done: loss {history[0]:.4f} -> "
          f"{history[-1]:.4f} over {len(history)} steps")
    if report:
        print(f"[train {args.arch}] restarts={report.restarts} "
              f"stragglers={len(report.straggler_steps)}")


if __name__ == "__main__":
    main()
