"""Production mesh construction (DESIGN §5, assignment spec).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets the 512-device XLA flag before
any jax import; everything else sees the real topology).
"""
from __future__ import annotations


import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist in
    # newer jax; older installs default every axis to Auto anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = 256 chips/pod ("data", "model"); multi-pod adds the
    leading ("pod",) axis: (2, 16, 16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_elastic_mesh(data: int, model: int = 16, pod: int = 1):
    """Degraded-operation meshes after failures: whole TP groups only
    (shrink 'data'; 'model' stays intact — see ft/elastic.py)."""
    shape = (pod, data, model) if pod > 1 else (data, model)
    axes = (("pod", "data", "model") if pod > 1 else ("data", "model"))
    return _make_mesh(shape, axes)


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` on new jax; the classic ``with mesh:``
    physical-mesh context on jax 0.4.x (where set_mesh doesn't exist)."""
    setter = getattr(jax, "set_mesh", None)
    return setter(mesh) if setter is not None else mesh


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return _make_mesh((data, model), ("data", "model"))
