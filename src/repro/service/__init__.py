"""Online RLC query serving subsystem.

Turns the offline index engines (:mod:`repro.core.rlc_index`,
:mod:`repro.core.device_index`, :mod:`repro.kernels.mergejoin`) into a
synchronous query service:

* :mod:`repro.service.expr` — textual ``(l1 l2 ...)+`` constraint parser
  with alphabet / ``k`` validation and minimum-repeat canonicalization;
* :mod:`repro.service.cache` — LRU result cache (positive and negative
  answers) with hit/miss accounting;
* :mod:`repro.service.scheduler` — micro-batching scheduler that packs
  requests into fixed-size padded batches bucketed by MR length;
* :mod:`repro.service.executor` — multi-backend batch executor (python /
  numpy / XLA-sorted / Pallas-dense) with automatic fallback;
* :mod:`repro.service.control` — the closed-loop control plane:
  SLO-aware per-MR-length batching, admission control with explicit
  ``SHED`` answers, and frequency-sketch-prioritized cache warming;
* :mod:`repro.service.service` — the :class:`RLCService` facade wiring
  build -> freeze -> device transfer -> serve;
* :mod:`repro.service.sharded` — sharded multi-host serving: shard
  planner, two-sided router, replica sets with hot-swap, scatter/gather
  fan-out behind the drop-in :class:`ShardedRLCService` facade.
"""
from .cache import CacheStats, ResultCache
from .control import (SHED, AdmissionController, CacheWarmer, ControlPlane,
                      FrequencySketch, SLOBatchController, VirtualClock)
from .executor import BACKENDS, BatchExecutor, ExecutorError
from .expr import ExpressionError, PathExpression, parse_expression
from .metrics import LatencyRecorder
from .scheduler import Batch, MicroBatcher, Request
from .service import RLCService, ServiceConfig
from .sharded import ShardedRLCService, ShardedServiceConfig

__all__ = [
    "BACKENDS", "AdmissionController", "Batch", "BatchExecutor",
    "CacheStats", "CacheWarmer", "ControlPlane", "ExecutorError",
    "ExpressionError", "FrequencySketch", "LatencyRecorder", "MicroBatcher",
    "PathExpression", "RLCService", "Request", "ResultCache", "SHED",
    "SLOBatchController", "ServiceConfig", "ShardedRLCService",
    "ShardedServiceConfig", "VirtualClock", "parse_expression",
]
