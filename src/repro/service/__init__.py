"""Online RLC query serving subsystem.

Turns the offline index engines (:mod:`repro.core.rlc_index`,
:mod:`repro.core.device_index`, :mod:`repro.kernels.mergejoin`) into a
synchronous query service:

* :mod:`repro.service.expr` — textual ``(l1 l2 ...)+`` constraint parser
  with alphabet / ``k`` validation and minimum-repeat canonicalization;
* :mod:`repro.service.cache` — LRU result cache (positive and negative
  answers) with hit/miss accounting;
* :mod:`repro.service.scheduler` — micro-batching scheduler that packs
  requests into fixed-size padded batches bucketed by MR length;
* :mod:`repro.service.executor` — multi-backend batch executor (python /
  numpy / XLA-sorted / Pallas-dense) with automatic fallback;
* :mod:`repro.service.control` — the closed-loop control plane:
  SLO-aware per-MR-length batching, admission control with explicit
  ``SHED`` answers, and frequency-sketch-prioritized cache warming;
* :mod:`repro.service.answer` — the typed :class:`Answer` result (value
  + disposition + backend attribution) and the :data:`SHED` sentinel;
* :mod:`repro.service.lifecycle` — async admission: ``submit()``
  futures behind the unified ``start()``/``close()`` protocol;
* :mod:`repro.service.stats` — the versioned ``repro.service.stats/1``
  stats schema shared by both facades, with :func:`validate_stats`;
* :mod:`repro.service.service` — the :class:`RLCService` facade wiring
  build -> freeze -> device transfer -> serve;
* :mod:`repro.service.sharded` — sharded multi-host serving: shard
  planner, two-sided router, replica sets with hot-swap, scatter/gather
  fan-out behind the drop-in :class:`ShardedRLCService` facade;
* :mod:`repro.service.rpc` — true multi-process serving: shard-host
  worker processes behind a message-based RPC transport
  (``ShardedServiceConfig(transport="rpc")``).

See ``src/repro/service/README.md`` for the API reference and the
bool->:class:`Answer` / sync->``submit()`` migration notes.
"""
from .answer import DISPOSITIONS, SHED, Answer
from .cache import CacheStats, ResultCache
from .control import (AdmissionController, CacheWarmer, ControlPlane,
                      FrequencySketch, SLOBatchController, VirtualClock)
from .executor import BACKENDS, BatchExecutor, ExecutorError
from .expr import ExpressionError, PathExpression, parse_expression
from .lifecycle import AsyncEngine
from .metrics import LatencyRecorder
from .scheduler import Batch, MicroBatcher, Request
from .service import RLCService, ServiceConfig
from .sharded import ShardedRLCService, ShardedServiceConfig
from .stats import STATS_SCHEMA, validate_stats

__all__ = [
    "Answer", "BACKENDS", "AdmissionController", "AsyncEngine", "Batch",
    "BatchExecutor", "CacheStats", "CacheWarmer", "ControlPlane",
    "DISPOSITIONS", "ExecutorError", "ExpressionError", "FrequencySketch",
    "LatencyRecorder", "MicroBatcher", "PathExpression", "RLCService",
    "Request", "ResultCache", "SHED", "SLOBatchController",
    "STATS_SCHEMA", "ServiceConfig", "ShardedRLCService",
    "ShardedServiceConfig", "VirtualClock", "parse_expression",
    "validate_stats",
]
