"""Multi-process shard serving: the RPC plane under ``transport="rpc"``.

This package is the process boundary the in-process sharded service
only simulated (ROADMAP item 1): a controller talks to one shard-host
*worker process* per (shard, replica) over length-prefixed
msgpack-or-JSON frames, each worker holding only its shard's
:meth:`FrozenRLCIndex.slice_rows` view plus a locally reconstructed
dict-index slice — never the global python fallback — and cross-shard
queries ship out-row digests over the wire instead of ``device_put``.

Layers, bottom up:

* :mod:`~repro.service.rpc.wire` — self-describing byte frames
  (msgpack preferred, JSON fallback; numpy arrays as dtype+shape+raw
  bytes).
* :mod:`~repro.service.rpc.transport` — framed request/response
  endpoints over :mod:`multiprocessing.connection` (HMAC-authed
  loopback sockets), :class:`WorkerGone` / :class:`RemoteError`
  taxonomy.
* :mod:`~repro.service.rpc.worker` — the jax-free shard-host process:
  ``execute`` / ``gather_digest`` / ``join_digest`` / ``swap``
  handlers over shard-local state.
* :mod:`~repro.service.rpc.controller` — :class:`RpcShardCluster`:
  elastic membership (join/leave/rejoin with epochs), round-robin
  replica routing with died-mid-call retry, per-worker fenced rolling
  swaps, and the ``rlc_rpc_*`` metric family.

``ShardedRLCService(cfg, transport="rpc")`` wires a cluster under the
normal fan-out; answers stay bit-identical to the in-process path.
"""
from __future__ import annotations

from .controller import RpcShardCluster, RpcWorkerHandle, WorkerLost
from .transport import (RemoteError, RpcEndpoint, RpcError, RpcListener,
                        WorkerGone, connect)
from .wire import codec_name, decode, encode
from .worker import ShardWorker, worker_main

__all__ = [
    "RpcShardCluster", "RpcWorkerHandle", "WorkerLost",
    "RpcEndpoint", "RpcListener", "RpcError", "RemoteError",
    "WorkerGone", "connect", "codec_name", "decode", "encode",
    "ShardWorker", "worker_main",
]
