"""Shard-host worker process: one shard slice, served over RPC.

``worker_main`` is the spawn target (top-level and importable, so the
``spawn`` start method works everywhere). A worker dials back to the
controller's listener, announces itself (``hello``), then serves
requests until ``shutdown`` or controller death.

A worker holds **only shard-local state**: the
:meth:`FrozenRLCIndex.slice_rows` view of its shard's row range shipped
over the wire, plus a dict-index slice reconstructed locally from those
same rows (:func:`repro.service.sharded.replica.dict_index_slice`) as
the always-available python fallback — never the global dict index. The
two-sided routing invariant makes that sufficient: every sub-batch a
worker executes has both endpoints in its range, and cross-shard
queries arrive as out-row digests to join against local in-rows
(``join_digest``) or leave as digests gathered from local out-rows
(``gather_digest``).

Deliberately **jax-free**: workers answer through the frozen-numpy
merge join (with the dict-slice python path as fallback); device
backends live with the controller process. Importing jax here would
cost every worker the full XLA startup for nothing.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.minimum_repeat import LabelSeq
from repro.core.rlc_index import FrozenRLCIndex, merge_join_rows

__all__ = ["worker_main", "ShardWorker"]


def _frozen_from_payload(p: dict) -> FrozenRLCIndex:
    return FrozenRLCIndex(
        int(p["num_vertices"]), int(p["k"]),
        np.asarray(p["aid"], dtype=np.int64),
        np.asarray(p["out_indptr"], dtype=np.int64),
        np.asarray(p["out_hub"], dtype=np.int32),
        np.asarray(p["out_mr"], dtype=np.int32),
        np.asarray(p["in_indptr"], dtype=np.int64),
        np.asarray(p["in_hub"], dtype=np.int32),
        np.asarray(p["in_mr"], dtype=np.int32))


class ShardWorker:
    """The in-process half of one worker: shard state + request
    handlers. Factored out of :func:`worker_main` so tests can drive the
    protocol without a process."""

    def __init__(self, worker_id: str):
        self.worker_id = worker_id
        self.shard_id: Optional[int] = None
        self.replica_id: Optional[int] = None
        self.generation = -1
        self.lo = 0
        self.hi = 0
        self.frozen: Optional[FrozenRLCIndex] = None
        self.executor = None
        self.id_to_mr: List[LabelSeq] = []
        self.batches = 0
        self.queries = 0
        self.joins = 0
        self.digests = 0
        self.swaps = 0

    # -- state install --------------------------------------------------- #
    def _install(self, p: dict) -> None:
        from repro.service.executor import BatchExecutor
        from repro.service.sharded.replica import dict_index_slice
        self.generation = int(p["generation"])
        self.lo, self.hi = int(p["lo"]), int(p["hi"])
        self.frozen = _frozen_from_payload(p)
        if "id_to_mr" in p:
            self.id_to_mr = [tuple(int(x) for x in mr)
                             for mr in p["id_to_mr"]]
        index_slice = dict_index_slice(self.frozen, self.lo, self.hi,
                                       self.id_to_mr)
        # backend pinned to "numpy" (not "auto"): auto-resolution probes
        # jax for the CPU check, and this process must stay jax-free
        self.executor = BatchExecutor(
            index_slice, self.frozen, None, self.id_to_mr,
            backend="numpy")

    # -- handlers --------------------------------------------------------- #
    def on_init(self, msg: dict) -> dict:
        self.shard_id = int(msg["shard_id"])
        self.replica_id = int(msg["replica_id"])
        self._install(msg)
        return dict(shard_id=self.shard_id, replica_id=self.replica_id,
                    generation=self.generation,
                    entries=int(self.frozen.num_entries()))

    def on_swap(self, msg: dict) -> dict:
        """Install a new generation (the fenced half of a rolling
        hot-swap/apply_delta: the controller fences this worker out of
        routing before sending, unfences after the reply)."""
        if int(msg["generation"]) < self.generation:
            raise ValueError(
                f"stale swap: at generation {self.generation}, "
                f"got {msg['generation']}")
        self._install(msg)
        self.swaps += 1
        return dict(generation=self.generation,
                    entries=int(self.frozen.num_entries()))

    def on_execute(self, msg: dict) -> dict:
        s = np.asarray(msg["s"], dtype=np.int32)
        t = np.asarray(msg["t"], dtype=np.int32)
        mr = np.asarray(msg["mr"], dtype=np.int32)
        n = int(msg.get("n_real", len(s)))
        ans, backend = self.executor.execute(s, t, mr, n_real=n)
        self.batches += 1
        self.queries += n
        return dict(ans=np.asarray(ans, dtype=bool), backend=backend)

    def on_gather_digest(self, msg: dict) -> dict:
        """Out-row digests for the scatter hop of cross-shard queries:
        per-query ``L_out(s)`` rows, concatenated + indexed (ragged rows
        serialize as three flat arrays instead of per-row frames)."""
        s = np.asarray(msg["s"], dtype=np.int64)
        indptr = np.zeros(len(s) + 1, dtype=np.int64)
        hubs, mrs = [], []
        for q, v in enumerate(s):
            oh, om = self.frozen.row_out(int(v))
            indptr[q + 1] = indptr[q] + len(oh)
            hubs.append(oh)
            mrs.append(om)
        cat = (lambda parts: np.concatenate(parts)
               if parts else np.empty(0, np.int32))
        self.digests += len(s)
        return dict(indptr=indptr, hub=cat(hubs).astype(np.int32),
                    mr=cat(mrs).astype(np.int32))

    def on_join_digest(self, msg: dict) -> dict:
        """The gather hop: merge-join shipped out-row digests against
        this shard's local in-rows (Algorithm 1 over two explicit rows;
        both sides share the global aid order)."""
        s = np.asarray(msg["s"], dtype=np.int64)
        t = np.asarray(msg["t"], dtype=np.int64)
        mr = np.asarray(msg["mr"], dtype=np.int64)
        dp = np.asarray(msg["digest_indptr"], dtype=np.int64)
        dh = np.asarray(msg["digest_hub"], dtype=np.int32)
        dm = np.asarray(msg["digest_mr"], dtype=np.int32)
        aid = self.frozen.aid
        out = np.zeros(len(s), dtype=bool)
        for q in range(len(s)):
            oh = dh[dp[q]:dp[q + 1]]
            om = dm[dp[q]:dp[q + 1]]
            ih, im = self.frozen.row_in(int(t[q]))
            out[q] = merge_join_rows(oh, om, ih, im, aid,
                                     int(s[q]), int(t[q]), int(mr[q]))
        self.joins += len(s)
        return dict(ans=out)

    def on_stats(self, msg: dict) -> dict:
        ex = self.executor
        return dict(
            worker_id=self.worker_id, shard_id=self.shard_id,
            replica_id=self.replica_id, generation=self.generation,
            lo=self.lo, hi=self.hi,
            entries=(int(self.frozen.num_entries())
                     if self.frozen is not None else 0),
            batches=self.batches, queries=self.queries,
            joins=self.joins, digests=self.digests, swaps=self.swaps,
            fallbacks=(ex.fallbacks if ex is not None else 0),
            backends=(ex.stats() if ex is not None else {}))

    def on_ping(self, msg: dict) -> dict:
        return dict(pong=True, generation=self.generation)

    def handle(self, msg: dict) -> Tuple[dict, bool]:
        """Dispatch one request; returns ``(reply, keep_serving)``."""
        method = msg.get("method")
        rid = msg.get("id")
        if method == "shutdown":
            return dict(id=rid, ok=True), False
        handler = getattr(self, f"on_{method}", None)
        if handler is None:
            return dict(id=rid, ok=False,
                        error=f"unknown method {method!r}"), True
        try:
            result = handler(msg)
        except Exception as e:  # noqa: BLE001 — reported to the peer
            return dict(id=rid, ok=False, error=repr(e)), True
        return dict(result, id=rid, ok=True), True


def worker_main(address, authkey: bytes, worker_id: str) -> None:
    """Spawn target: dial the controller, announce, serve until told to
    stop (or until the controller's side of the socket dies)."""
    from . import wire
    from .transport import WorkerGone, connect
    ep = connect(tuple(address), authkey)
    worker = ShardWorker(worker_id)
    import os
    ep.send(dict(method="hello", worker_id=worker_id, pid=os.getpid(),
                 codec=wire.codec_name()))
    try:
        while True:
            try:
                msg = ep.recv()
            except WorkerGone:
                break               # controller died: exit quietly
            reply, keep = worker.handle(msg)
            try:
                ep.send(reply)
            except WorkerGone:
                break
            if not keep:
                break
    finally:
        ep.close()
