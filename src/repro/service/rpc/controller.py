"""The controller side of multi-process shard serving: elastic worker
membership, per-worker fenced swaps, and the RPC call plane.

:class:`RpcShardCluster` owns one worker *process* per (shard, replica)
— spawned with the ``spawn`` start method so the topology works under
any interpreter/platform — and a loopback listener the workers dial
back to. Each worker is shipped only its shard's frozen slice
(:mod:`repro.service.rpc.worker`); the cluster keeps the per-shard
slice payloads so a worker that *rejoins* after leaving (crash, drain,
scale-up) can be re-initialized at the current generation without
touching the serving path.

Membership is elastic in the :mod:`repro.ft.elastic` sense: workers
join/leave at any time, each change bumps a membership epoch, routing
simply skips dead or fenced members, and the per-worker
``StragglerMonitor`` from that module watches round-trip times so a
slow host is visible before it is gone. Rolling ``hot_swap`` /
``apply_delta`` are **fenced per worker**: the worker is taken out of
routing, sent the new generation, and unfenced — its replica siblings
(or the controller's exact BiBFS degrade path) cover the gap, mirroring
the in-process ``ShardReplicaSet.swapping`` contract.

Every call is accounted in the ``rlc_rpc_*`` metric family: bytes on
the wire by direction/method, round-trip latency, outcomes, retries
after a worker died mid-call, and membership events.
"""
from __future__ import annotations

import itertools
import multiprocessing
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.rlc_index import FrozenRLCIndex
from repro.obs import NULL_OBS

from .transport import (RemoteError, RpcEndpoint, RpcError, RpcListener,
                        WorkerGone)
from .worker import worker_main

__all__ = ["RpcShardCluster", "RpcWorkerHandle", "WorkerLost"]

RPC_METHODS = ("init", "execute", "gather_digest", "join_digest", "swap",
               "stats", "ping", "shutdown")


class WorkerLost(RpcError):
    """No live worker can serve the shard (every replica is gone and the
    caller has no degrade path)."""


class RpcWorkerHandle:
    """One worker process + its connection, as the cluster sees it."""

    def __init__(self, shard_id: int, replica_id: int, worker_id: str,
                 proc, ep: RpcEndpoint):
        self.shard_id = shard_id
        self.replica_id = replica_id
        self.worker_id = worker_id
        self.proc = proc
        self.ep = ep
        self.generation = -1
        self.alive = True
        #: fenced workers are skipped by routing (mid-swap, draining)
        self.fenced = False
        self.pid = proc.pid if proc is not None else None
        self.straggler = None       # ft.elastic.StragglerMonitor, lazy
        self.calls = 0

    @property
    def serving(self) -> bool:
        return self.alive and not self.fenced

    def __repr__(self) -> str:
        state = ("fenced" if self.fenced else
                 "alive" if self.alive else "gone")
        return (f"RpcWorkerHandle({self.worker_id}, gen={self.generation}, "
                f"{state})")


def _slice_payload(frozen_slice: FrozenRLCIndex, lo: int, hi: int,
                   generation: int, id_to_mr) -> dict:
    """The wire form of one shard's serving state. ``aid``/``indptr``
    are global-length (the slice keeps global vertex ids) — O(n) per
    worker, the price of id-stable routing; entry arrays are the
    shard's span only."""
    return dict(
        generation=int(generation), lo=int(lo), hi=int(hi),
        num_vertices=int(frozen_slice.num_vertices),
        k=int(frozen_slice.k),
        aid=np.asarray(frozen_slice.aid, dtype=np.int64),
        out_indptr=np.asarray(frozen_slice.out_indptr, dtype=np.int64),
        out_hub=np.asarray(frozen_slice.out_hub, dtype=np.int32),
        out_mr=np.asarray(frozen_slice.out_mr, dtype=np.int32),
        in_indptr=np.asarray(frozen_slice.in_indptr, dtype=np.int64),
        in_hub=np.asarray(frozen_slice.in_hub, dtype=np.int32),
        in_mr=np.asarray(frozen_slice.in_mr, dtype=np.int32),
        id_to_mr=[list(mr) for mr in id_to_mr])


class RpcShardCluster:
    def __init__(self, ranges: List[Tuple[int, int]], num_replicas: int,
                 id_to_mr, obs=None, start_timeout_s: float = 60.0,
                 call_timeout_s: Optional[float] = 120.0,
                 ctx_method: str = "spawn"):
        self.ranges = [(int(lo), int(hi)) for lo, hi in ranges]
        self.num_shards = len(self.ranges)
        self.num_replicas = int(num_replicas)
        self.id_to_mr = list(id_to_mr)
        self.start_timeout_s = start_timeout_s
        self.call_timeout_s = call_timeout_s
        self._ctx = multiprocessing.get_context(ctx_method)
        self._listener: Optional[RpcListener] = None
        #: shard -> replica handles (dead ones stay listed until rejoin
        #: replaces them — membership history is part of the state)
        self.handles: Dict[int, List[RpcWorkerHandle]] = {
            sid: [] for sid in range(self.num_shards)}
        #: shard -> current slice payload (what a rejoining worker gets)
        self._payloads: Dict[int, dict] = {}
        self._rr = {sid: itertools.count()
                    for sid in range(self.num_shards)}
        self._lock = threading.RLock()
        self.membership_epoch = 0
        self.generation = 0
        self.started = False
        self.closed = False
        self.joins = 0
        self.leaves = 0
        self.rejoins = 0
        self.retries = 0
        try:        # per-worker round-trip watch (repro.ft.elastic)
            from repro.ft.elastic import StragglerMonitor
            self._straggler_cls = StragglerMonitor
        except Exception:                     # pragma: no cover - no jax
            self._straggler_cls = None
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        self._m_bytes = reg.counter(
            "rlc_rpc_bytes", desc="RPC bytes on the wire",
            unit="By", labelnames=("direction", "method"))
        self._m_rtt = reg.histogram(
            "rlc_rpc_roundtrip_seconds",
            desc="RPC request round-trip wall time", unit="s",
            labelnames=("method",))
        self._m_req = reg.counter(
            "rlc_rpc_requests", desc="RPC requests by outcome",
            labelnames=("method", "outcome"))
        self._m_retry = reg.counter(
            "rlc_rpc_retries",
            desc="calls retried on a sibling replica after a worker "
                 "died mid-request", labelnames=("method",))
        self._m_members = reg.counter(
            "rlc_rpc_membership", desc="worker membership events",
            labelnames=("event",))
        self._m_workers = reg.gauge(
            "rlc_rpc_workers", desc="live worker processes")

    # -- membership ------------------------------------------------------ #
    def start(self, frozen: FrozenRLCIndex, generation: int = 0) -> None:
        """Spawn one worker per (shard, replica), ship every shard its
        slice, and wait for the fleet to come up."""
        if self.started:
            return
        self.generation = int(generation)
        self._listener = RpcListener()
        for sid, (lo, hi) in enumerate(self.ranges):
            self._payloads[sid] = _slice_payload(
                frozen.slice_rows(lo, hi), lo, hi, self.generation,
                self.id_to_mr)
        pending: Dict[str, Tuple[int, int, object]] = {}
        for sid in range(self.num_shards):
            for rid in range(self.num_replicas):
                wid = f"s{sid}r{rid}"
                proc = self._spawn(wid)
                pending[wid] = (sid, rid, proc)
        deadline = time.monotonic() + self.start_timeout_s
        while pending:
            ep = self._listener.accept(
                timeout=max(deadline - time.monotonic(), 0.1))
            hello = ep.recv(timeout=self.start_timeout_s)
            wid = hello.get("worker_id")
            if wid not in pending:
                ep.close()
                continue
            sid, rid, proc = pending.pop(wid)
            h = RpcWorkerHandle(sid, rid, wid, proc, ep)
            self._init_handle(h)
            self.handles[sid].append(h)
            self._on_join("join")
        self.started = True

    def _spawn(self, worker_id: str):
        proc = self._ctx.Process(
            target=worker_main,
            args=(tuple(self._listener.address), self._listener.authkey,
                  worker_id),
            name=f"rlc-shard-{worker_id}", daemon=True)
        proc.start()
        return proc

    def _init_handle(self, h: RpcWorkerHandle) -> None:
        payload = self._payloads[h.shard_id]
        self._call(h, "init", shard_id=h.shard_id,
                   replica_id=h.replica_id, **payload)
        h.generation = int(payload["generation"])
        if self._straggler_cls is not None:
            h.straggler = self._straggler_cls(window=32, factor=4.0)

    def _on_join(self, event: str) -> None:
        self.membership_epoch += 1
        self.joins += 1 if event == "join" else 0
        self.rejoins += 1 if event == "rejoin" else 0
        self._m_members.labels(event=event).inc()
        self._m_workers.set(self.live_workers)

    def _mark_left(self, h: RpcWorkerHandle, event: str = "leave") -> None:
        if not h.alive:
            return
        h.alive = False
        h.ep.close()
        self.membership_epoch += 1
        self.leaves += 1
        self._m_members.labels(event=event).inc()
        self._m_workers.set(self.live_workers)

    def leave(self, shard_id: int, replica_id: int,
              graceful: bool = True) -> bool:
        """Take one worker out of the fleet (drain/failure drill). The
        remaining replicas — or the caller's degrade path — keep the
        shard serving."""
        with self._lock:
            h = self._find(shard_id, replica_id, alive=True)
            if h is None:
                return False
            if graceful:
                try:
                    h.ep.request("shutdown", timeout=5.0)
                except RpcError:
                    pass
            self._mark_left(h)
        if h.proc is not None:
            h.proc.join(timeout=5.0)
            if h.proc.is_alive():           # pragma: no cover - stuck
                h.proc.terminate()
        return True

    def rejoin(self, shard_id: int, replica_id: int) -> RpcWorkerHandle:
        """Bring a (shard, replica) seat back: spawn a fresh process and
        re-ship the shard's *current* slice payload."""
        with self._lock:
            live = self._find(shard_id, replica_id, alive=True)
            if live is not None:
                return live
            wid = f"s{shard_id}r{replica_id}g{self.membership_epoch}"
            proc = self._spawn(wid)
            deadline = time.monotonic() + self.start_timeout_s
            while True:
                ep = self._listener.accept(
                    timeout=max(deadline - time.monotonic(), 0.1))
                hello = ep.recv(timeout=self.start_timeout_s)
                if hello.get("worker_id") == wid:
                    break
                ep.close()
            h = RpcWorkerHandle(shard_id, replica_id, wid, proc, ep)
            self._init_handle(h)
            # replace the dead seat in place (membership history lives
            # in the counters, not the handle list)
            self.handles[shard_id] = [
                x for x in self.handles[shard_id]
                if not (x.replica_id == replica_id and not x.alive)]
            self.handles[shard_id].append(h)
            self._on_join("rejoin")
            return h

    def _find(self, shard_id: int, replica_id: int,
              alive: Optional[bool] = None) -> Optional[RpcWorkerHandle]:
        for h in self.handles[shard_id]:
            if h.replica_id == replica_id and (alive is None
                                               or h.alive == alive):
                return h
        return None

    @property
    def live_workers(self) -> int:
        return sum(h.alive for hs in self.handles.values() for h in hs)

    def serving_workers(self, shard_id: int) -> List[RpcWorkerHandle]:
        return [h for h in self.handles[shard_id] if h.serving]

    def swapping(self, shard_id: int) -> bool:
        """True when no worker of ``shard_id`` can take a sub-batch —
        the caller should degrade exactly like the in-process mid-swap
        path."""
        return not self.serving_workers(shard_id)

    # -- call plane ------------------------------------------------------ #
    def _call(self, h: RpcWorkerHandle, method: str, **params) -> dict:
        t0 = time.perf_counter()
        try:
            reply, sent, received = h.ep.request(
                method, timeout=self.call_timeout_s, **params)
        except WorkerGone:
            self._m_req.labels(method=method, outcome="gone").inc()
            self._mark_left(h, event="died")
            raise
        except RemoteError:
            self._m_req.labels(method=method, outcome="error").inc()
            raise
        dt = time.perf_counter() - t0
        h.calls += 1
        if h.straggler is not None:
            h.straggler.record(h.calls, dt)
        self._m_rtt.labels(method=method).observe(dt)
        self._m_bytes.labels(direction="sent", method=method).inc(sent)
        self._m_bytes.labels(direction="received",
                             method=method).inc(received)
        self._m_req.labels(method=method, outcome="ok").inc()
        return reply

    def _acquire(self, shard_id: int) -> Optional[RpcWorkerHandle]:
        live = self.serving_workers(shard_id)
        if not live:
            return None
        return live[next(self._rr[shard_id]) % len(live)]

    def _call_shard(self, shard_id: int, method: str, **params) -> dict:
        """Round-robin a request onto a live worker of ``shard_id``,
        retrying the sibling replicas when one dies mid-call."""
        tried = 0
        while True:
            h = self._acquire(shard_id)
            if h is None:
                raise WorkerLost(
                    f"shard {shard_id} has no serving worker "
                    f"(method={method!r})")
            try:
                return self._call(h, method, **params)
            except WorkerGone:
                tried += 1
                self.retries += 1
                self._m_retry.labels(method=method).inc()
                if tried > self.num_replicas:
                    raise WorkerLost(
                        f"shard {shard_id}: every replica died "
                        f"mid-{method}") from None

    # -- shard operations ------------------------------------------------ #
    def execute(self, shard_id: int, s, t, mr,
                n_real: int) -> Tuple[np.ndarray, str]:
        r = self._call_shard(shard_id, "execute",
                             s=np.asarray(s, np.int32),
                             t=np.asarray(t, np.int32),
                             mr=np.asarray(mr, np.int32),
                             n_real=int(n_real))
        return np.asarray(r["ans"], dtype=bool), str(r["backend"])

    def gather_digest(self, shard_id: int, s) -> dict:
        return self._call_shard(shard_id, "gather_digest",
                                s=np.asarray(s, np.int64))

    def join_digest(self, shard_id: int, s, t, mr,
                    digest: dict) -> np.ndarray:
        r = self._call_shard(shard_id, "join_digest",
                             s=np.asarray(s, np.int64),
                             t=np.asarray(t, np.int64),
                             mr=np.asarray(mr, np.int64),
                             digest_indptr=digest["indptr"],
                             digest_hub=digest["hub"],
                             digest_mr=digest["mr"])
        return np.asarray(r["ans"], dtype=bool)

    def swap_shard(self, shard_id: int, generation: int,
                   frozen_slice: FrozenRLCIndex) -> int:
        """Rolling, per-worker-fenced generation swap for one shard.
        Dead seats just record the new payload — a later rejoin ships
        it."""
        lo, hi = self.ranges[shard_id]
        payload = _slice_payload(frozen_slice, lo, hi, generation,
                                 self.id_to_mr)
        with self._lock:
            self._payloads[shard_id] = payload
            self.generation = max(self.generation, int(generation))
            swapped = 0
            for h in list(self.handles[shard_id]):
                if not h.alive:
                    continue
                h.fenced = True     # out of routing before state moves
                try:
                    self._call(h, "swap", **payload)
                    h.generation = int(generation)
                    swapped += 1
                except WorkerGone:
                    continue        # seat stays dead; rejoin re-ships
                finally:
                    h.fenced = False
            return swapped

    def worker_stats(self) -> List[dict]:
        out = []
        for sid in range(self.num_shards):
            for h in self.handles[sid]:
                row = dict(shard=sid, replica=h.replica_id,
                           worker_id=h.worker_id, pid=h.pid,
                           alive=h.alive, generation=h.generation,
                           calls=h.calls,
                           stragglers=(len(h.straggler.flagged)
                                       if h.straggler is not None else 0))
                if h.alive:
                    try:
                        row.update(self._call(h, "stats"))
                        row.pop("id", None)
                        row.pop("ok", None)
                    except RpcError:
                        pass
                out.append(row)
        return out

    def stats(self) -> dict:
        ep_bytes = dict(sent=0, received=0)
        for hs in self.handles.values():
            for h in hs:
                ep_bytes["sent"] += h.ep.bytes_sent
                ep_bytes["received"] += h.ep.bytes_received
        return dict(
            transport="rpc",
            num_shards=self.num_shards,
            num_replicas=self.num_replicas,
            live_workers=self.live_workers,
            membership_epoch=self.membership_epoch,
            generation=self.generation,
            joins=self.joins, leaves=self.leaves,
            rejoins=self.rejoins, retries=self.retries,
            wire_bytes=ep_bytes,
            workers=self.worker_stats(),
        )

    # -- shutdown -------------------------------------------------------- #
    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        for hs in self.handles.values():
            for h in hs:
                if not h.alive:
                    continue
                try:
                    h.ep.request("shutdown", timeout=5.0)
                except RpcError:
                    pass
                h.alive = False
                h.ep.close()
        for hs in self.handles.values():
            for h in hs:
                if h.proc is not None:
                    h.proc.join(timeout=5.0)
                    if h.proc.is_alive():   # pragma: no cover - stuck
                        h.proc.terminate()
        if self._listener is not None:
            self._listener.close()
        self._m_workers.set(0)
