"""Message transport for the shard-host RPC: length-prefixed frames over
sockets/pipes.

Built on :mod:`multiprocessing.connection` — its ``send_bytes`` /
``recv_bytes`` are exactly the length-prefixed byte frames the protocol
needs (over a loopback TCP socket here; the same API serves AF_UNIX and
Windows pipes), with HMAC connection auth for free. Payload encoding is
:mod:`repro.service.rpc.wire` (msgpack-or-JSON), *not* pickle: frames
stay self-describing and language-agnostic, and a malformed peer can't
execute code in the controller.

Request/response protocol: every request is ``{"id": n, "method": m,
...params}``; the peer answers ``{"id": n, "ok": true, ...result}`` or
``{"id": n, "ok": false, "error": repr}``. One outstanding request per
connection (the controller serializes per-worker calls behind a lock;
concurrency comes from having many workers, not from pipelining one
socket).
"""
from __future__ import annotations

import itertools
import os
import threading
from multiprocessing.connection import Client, Connection, Listener
from typing import Any, Dict, Optional, Tuple

from . import wire

__all__ = ["RpcError", "WorkerGone", "RemoteError", "RpcEndpoint",
           "RpcListener", "connect"]


class RpcError(RuntimeError):
    """Base class for transport-level failures."""


class WorkerGone(RpcError):
    """The peer hung up (process death or clean shutdown): EOF/broken
    pipe on the frame socket."""


class RemoteError(RpcError):
    """The peer processed the request and reported an application
    error."""


class RpcEndpoint:
    """One framed, codec'd connection (either side)."""

    def __init__(self, conn: Connection):
        self._conn = conn
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self.bytes_sent = 0
        self.bytes_received = 0

    # -- raw framed messages ------------------------------------------- #
    def send(self, msg: Dict[str, Any]) -> int:
        frame = wire.encode(msg)
        try:
            self._conn.send_bytes(frame)
        except (OSError, ValueError, EOFError, BrokenPipeError) as e:
            raise WorkerGone(f"send failed: {e!r}") from e
        self.bytes_sent += len(frame)
        return len(frame)

    def recv(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        try:
            if timeout is not None and not self._conn.poll(timeout):
                raise RpcError(f"no frame within {timeout}s")
            frame = self._conn.recv_bytes()
        except (EOFError, OSError, BrokenPipeError) as e:
            raise WorkerGone(f"peer hung up: {e!r}") from e
        self.bytes_received += len(frame)
        return wire.decode(frame)

    # -- request/response ---------------------------------------------- #
    def request(self, method: str, timeout: Optional[float] = None,
                **params) -> Tuple[Dict[str, Any], int, int]:
        """One round trip; returns ``(result, sent_bytes, recv_bytes)``.
        Raises :class:`WorkerGone` on transport death and
        :class:`RemoteError` when the peer reports a failure."""
        with self._lock:
            rid = next(self._ids)
            s0, r0 = self.bytes_sent, self.bytes_received
            self.send(dict(params, id=rid, method=method))
            reply = self.recv(timeout)
            sent = self.bytes_sent - s0
            received = self.bytes_received - r0
        if reply.get("id") != rid:
            raise RpcError(
                f"out-of-order reply: sent id {rid}, got {reply.get('id')}")
        if not reply.get("ok"):
            raise RemoteError(reply.get("error", "unknown remote error"))
        return reply, sent, received

    def close(self) -> None:
        try:
            self._conn.close()
        except OSError:
            pass


class RpcListener:
    """The controller's accept socket (loopback TCP, HMAC-authed)."""

    def __init__(self, authkey: Optional[bytes] = None,
                 backlog: int = 64):
        self.authkey = authkey if authkey is not None else os.urandom(16)
        # backlog must cover a whole fleet dialing back at once: with the
        # default listen(1), connects past the queue complete the TCP
        # handshake (Linux acks them) but never reach accept(), leaving
        # those workers waiting forever for an auth challenge
        self._listener = Listener(("127.0.0.1", 0), backlog=backlog,
                                  authkey=self.authkey)

    @property
    def address(self) -> Tuple[str, int]:
        return self._listener.address

    def accept(self, timeout: Optional[float] = None) -> RpcEndpoint:
        """Accept one peer. ``timeout`` bounds the wait for the TCP
        connect (the auth handshake then runs on the accepted socket)."""
        if timeout is not None:
            # Listener has no native timeout; poll the underlying socket
            sock = self._listener._listener._socket
            sock.settimeout(timeout)
        try:
            conn = self._listener.accept()
        except OSError as e:
            raise RpcError(f"accept failed/timed out: {e!r}") from e
        return RpcEndpoint(conn)

    def close(self) -> None:
        try:
            self._listener.close()
        except OSError:
            pass


def connect(address: Tuple[str, int], authkey: bytes) -> RpcEndpoint:
    """Worker-side dial back to the controller's listener."""
    return RpcEndpoint(Client(tuple(address), authkey=authkey))
