"""Wire codec for the shard-host RPC transport.

Messages are plain dicts (method name, params, numpy arrays) encoded to
one byte frame. Preferred encoding is **msgpack** with an extension hook
for numpy arrays (dtype + shape + raw little-endian bytes — zero-parse
on the receiving side); when msgpack is not installed the codec degrades
to **JSON** with base64-packed array payloads. Both sides of a
connection negotiate nothing: every frame is self-describing (first byte
tags the codec), so a msgpack controller can talk to a JSON worker and
vice versa.

Framing (the length prefix) is owned by the transport layer
(:mod:`repro.service.rpc.transport` rides
``multiprocessing.connection``'s length-prefixed byte frames); this
module only turns objects into bytes and back.
"""
from __future__ import annotations

import base64
import json
from typing import Any

import numpy as np

try:                                    # baked into the image; but the
    import msgpack                      # codec must survive without it
except Exception:                       # pragma: no cover - env dependent
    msgpack = None

__all__ = ["encode", "decode", "codec_name"]

_TAG_MSGPACK = b"M"
_TAG_JSON = b"J"

_ND_KEY = "__nd__"


def codec_name() -> str:
    """Which codec :func:`encode` will use (``msgpack`` or ``json``)."""
    return "msgpack" if msgpack is not None else "json"


def _nd_pack(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {_ND_KEY: True, "dtype": a.dtype.str, "shape": list(a.shape),
            "data": a.tobytes()}


def _nd_unpack(d: dict) -> np.ndarray:
    a = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
    return a.reshape(d["shape"]).copy()  # writable, owns its memory


def _msgpack_default(obj):
    if isinstance(obj, np.ndarray):
        return _nd_pack(obj)
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return obj.item()
    if isinstance(obj, tuple):
        return list(obj)
    raise TypeError(f"unencodable type {type(obj).__name__}")


def _msgpack_hook(d):
    if d.get(_ND_KEY):
        return _nd_unpack(d)
    return d


class _JsonEncoder(json.JSONEncoder):
    def default(self, obj):
        if isinstance(obj, np.ndarray):
            p = _nd_pack(obj)
            p["data"] = base64.b64encode(p["data"]).decode("ascii")
            return p
        if isinstance(obj, (np.integer, np.floating, np.bool_)):
            return obj.item()
        if isinstance(obj, bytes):
            return {"__b64__": base64.b64encode(obj).decode("ascii")}
        return super().default(obj)


def _json_hook(d):
    if d.get(_ND_KEY):
        d = dict(d, data=base64.b64decode(d["data"]))
        return _nd_unpack(d)
    if "__b64__" in d:
        return base64.b64decode(d["__b64__"])
    return d


def encode(msg: Any) -> bytes:
    """One message -> one tagged byte frame."""
    if msgpack is not None:
        return _TAG_MSGPACK + msgpack.packb(
            msg, default=_msgpack_default, use_bin_type=True)
    return _TAG_JSON + json.dumps(msg, cls=_JsonEncoder).encode("utf-8")


def decode(frame: bytes) -> Any:
    """One tagged byte frame -> the message it encodes."""
    tag, body = frame[:1], frame[1:]
    if tag == _TAG_MSGPACK:
        if msgpack is None:
            raise RuntimeError(
                "received a msgpack frame but msgpack is not importable")
        return msgpack.unpackb(body, object_hook=_msgpack_hook, raw=False,
                               strict_map_key=False)
    if tag == _TAG_JSON:
        return json.loads(body.decode("utf-8"), object_hook=_json_hook)
    raise ValueError(f"unknown wire codec tag {tag!r}")
