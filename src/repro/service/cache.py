"""LRU result cache for RLC query answers.

Keys are ``(s, t, mr_id)`` triples; values are booleans — *both* positive
and negative answers are cached (a false reachability answer is exactly as
expensive to recompute as a true one; the index is static between
rebuilds, so negatives never go stale). Hit/miss/eviction counters feed
the service stats and the Zipf-workload benchmark.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Tuple

Key = Tuple[int, int, int]  # (s, t, mr_id)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, hit_rate=self.hit_rate)


class ResultCache:
    """Bounded LRU mapping ``(s, t, mr_id) -> bool``."""

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._d: "OrderedDict[Key, bool]" = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Key) -> Optional[bool]:
        """Answer if cached (refreshing recency), else ``None``."""
        if self.capacity == 0:
            self.stats.misses += 1
            return None
        try:
            val = self._d[key]
        except KeyError:
            self.stats.misses += 1
            return None
        self._d.move_to_end(key)
        self.stats.hits += 1
        return val

    def put(self, key: Key, value: bool) -> None:
        if self.capacity == 0:
            return
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = bool(value)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.stats.evictions += 1

    def clear(self) -> None:
        self._d.clear()
