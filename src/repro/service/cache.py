"""LRU result cache for RLC query answers.

Keys are ``(s, t, mr_id)`` triples; values are booleans — *both* positive
and negative answers are cached (a false reachability answer is exactly as
expensive to recompute as a true one; the index is immutable between
rebuilds/deltas, so staleness is driven by explicit invalidation, not
time — but an optional TTL is available for deployments that prefer
bounded staleness over precise invalidation). Hit/miss/eviction counters
feed the service stats and the Zipf-workload benchmark.

Graphs became mutable with the delta-build engine
(:mod:`repro.build.delta`): a delta changes the answers of exactly the
queries whose source row (``L_out(s)``) or target row (``L_in(t)``) went
dirty, so :meth:`ResultCache.invalidate_rows` evicts only those keys and
every other cached answer survives.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

from repro.obs import NULL_OBS, Reservoir

Key = Tuple[int, int, int]  # (s, t, mr_id)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    expirations: int = 0
    invalidations: int = 0
    # per-MR-length (hits, misses) — the warming-priority input: MR
    # lengths that miss more benefit more from pre-materialization
    by_mr_len: Dict[int, Tuple[int, int]] = field(default_factory=dict)

    @property
    def lookups(self) -> int:
        # expirations are lookups too (an entry was found but stale) —
        # they dilute the hit rate without counting as plain misses
        return self.hits + self.misses + self.expirations

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def record(self, mr_len: Optional[int], hit: bool) -> None:
        if mr_len is None:
            return
        h, m = self.by_mr_len.get(mr_len, (0, 0))
        self.by_mr_len[mr_len] = (h + 1, m) if hit else (h, m + 1)

    def hit_rate_by_mr_len(self) -> Dict[int, float]:
        return {ln: h / (h + m) if h + m else 0.0
                for ln, (h, m) in sorted(self.by_mr_len.items())}

    def as_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions,
                    expirations=self.expirations,
                    invalidations=self.invalidations,
                    hit_rate=self.hit_rate,
                    hit_rate_by_mr_len=self.hit_rate_by_mr_len())


class ResultCache:
    """Bounded LRU mapping ``(s, t, mr_id) -> bool``.

    ``ttl_s``: optional time-to-live; an entry older than this counts as
    a miss (and is evicted) on lookup. ``clock`` is injectable for
    tests.
    """

    def __init__(self, capacity: int, ttl_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic, obs=None):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError(f"ttl_s must be > 0, got {ttl_s}")
        self.capacity = capacity
        self.ttl_s = ttl_s
        self.clock = clock
        self._d: "OrderedDict[Key, Tuple[bool, float]]" = OrderedDict()
        self.stats = CacheStats()
        # registry cells mirroring CacheStats (the registry survives
        # service-internal resets and feeds the exporters)
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        look = reg.counter("rlc_cache_lookups",
                           desc="result-cache lookups by outcome",
                           labelnames=("outcome",))
        self._m_hit = look.labels(outcome="hit")
        self._m_miss = look.labels(outcome="miss")
        self._m_expired = look.labels(outcome="expired")
        self._m_evict = reg.counter(
            "rlc_cache_evictions",
            desc="LRU capacity evictions").labels()
        self._m_inval = reg.counter(
            "rlc_cache_invalidations",
            desc="entries dropped by invalidate_rows/clear").labels()
        self._m_size = reg.gauge("rlc_cache_size",
                                 desc="entries currently cached").labels()
        self._m_mr = reg.counter(
            "rlc_cache_mr_lookups",
            desc="result-cache lookups by outcome and MR length",
            labelnames=("outcome", "mr_len"))
        self._m_evict_age = reg.histogram(
            "rlc_cache_eviction_age_seconds",
            desc="entry age (insert -> LRU eviction) at capacity "
                 "eviction", unit="s").labels()
        # standalone reservoir so eviction_age_summary works with the
        # null registry too (warming reads it without telemetry on)
        self.eviction_ages = Reservoir()

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Key, mr_len: Optional[int] = None) -> Optional[bool]:
        """Answer if cached and fresh (refreshing recency), else ``None``."""
        if self.capacity == 0:
            self.stats.misses += 1
            self.stats.record(mr_len, hit=False)
            self._m_miss.inc()
            return None
        try:
            val, stamp = self._d[key]
        except KeyError:
            self.stats.misses += 1
            self.stats.record(mr_len, hit=False)
            self._m_miss.inc()
            if mr_len is not None:
                self._m_mr.labels(outcome="miss", mr_len=mr_len).inc()
            return None
        if self.ttl_s is not None and self.clock() - stamp >= self.ttl_s:
            del self._d[key]
            # expired is its own outcome: the lookup found a (stale)
            # entry, so it is neither a hit nor a plain miss — it still
            # dilutes hit_rate via CacheStats.lookups
            self.stats.expirations += 1
            self.stats.record(mr_len, hit=False)
            self._m_expired.inc()
            if mr_len is not None:
                self._m_mr.labels(outcome="expired", mr_len=mr_len).inc()
            return None
        self._d.move_to_end(key)
        self.stats.hits += 1
        self.stats.record(mr_len, hit=True)
        self._m_hit.inc()
        if mr_len is not None:
            self._m_mr.labels(outcome="hit", mr_len=mr_len).inc()
        return val

    def peek(self, key: Key) -> Optional[bool]:
        """Non-mutating probe: the cached answer if present and fresh,
        else ``None``. No recency refresh, no stats, no counters —
        EXPLAIN's cache-disposition probe must not perturb the serving
        LRU or the hit-rate series it reports on."""
        if self.capacity == 0:
            return None
        pair = self._d.get(key)
        if pair is None:
            return None
        val, stamp = pair
        if self.ttl_s is not None and self.clock() - stamp >= self.ttl_s:
            return None
        return val

    def put(self, key: Key, value: bool,
            mr_len: Optional[int] = None) -> None:
        if self.capacity == 0:
            return
        if key in self._d:
            self._d.move_to_end(key)
        now = self.clock()
        self._d[key] = (bool(value), now)
        while len(self._d) > self.capacity:
            _k, (_v, stamp) = self._d.popitem(last=False)
            self.stats.evictions += 1
            self._m_evict.inc()
            age = max(now - stamp, 0.0)
            self.eviction_ages.add(age)
            self._m_evict_age.observe(age)
        self._m_size.set(len(self._d))

    def hit_rate_by_mr_len(self) -> Dict[int, float]:
        """Per-MR-length hit rates — the warmer's priority input."""
        return self.stats.hit_rate_by_mr_len()

    def eviction_age_summary(self) -> dict:
        """Percentiles of entry age at LRU eviction: a low p50 means the
        capacity is churning entries before they can be re-hit."""
        return self.eviction_ages.summary()

    def invalidate_rows(self, dirty_s=None, dirty_t=None) -> int:
        """Evict every key whose source row is in ``dirty_s`` or target
        row is in ``dirty_t`` (containers supporting ``in``); returns the
        eviction count. The targeted flavor of :meth:`clear` for delta
        updates: untouched keys keep serving."""
        dirty_s = dirty_s if dirty_s is not None else ()
        dirty_t = dirty_t if dirty_t is not None else ()
        doomed = [k for k in self._d
                  if k[0] in dirty_s or k[1] in dirty_t]
        for k in doomed:
            del self._d[k]
        self.stats.invalidations += len(doomed)
        self._m_inval.inc(len(doomed))
        self._m_size.set(len(self._d))
        return len(doomed)

    def clear(self) -> None:
        self.stats.invalidations += len(self._d)
        self._m_inval.inc(len(self._d))
        self._d.clear()
        self._m_size.set(0)
