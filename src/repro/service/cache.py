"""LRU result cache for RLC query answers.

Keys are ``(s, t, mr_id)`` triples; values are booleans — *both* positive
and negative answers are cached (a false reachability answer is exactly as
expensive to recompute as a true one; the index is immutable between
rebuilds/deltas, so staleness is driven by explicit invalidation, not
time — but an optional TTL is available for deployments that prefer
bounded staleness over precise invalidation). Hit/miss/eviction counters
feed the service stats and the Zipf-workload benchmark.

Graphs became mutable with the delta-build engine
(:mod:`repro.build.delta`): a delta changes the answers of exactly the
queries whose source row (``L_out(s)``) or target row (``L_in(t)``) went
dirty, so :meth:`ResultCache.invalidate_rows` evicts only those keys and
every other cached answer survives.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.obs import NULL_OBS

Key = Tuple[int, int, int]  # (s, t, mr_id)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    expirations: int = 0
    invalidations: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions,
                    expirations=self.expirations,
                    invalidations=self.invalidations,
                    hit_rate=self.hit_rate)


class ResultCache:
    """Bounded LRU mapping ``(s, t, mr_id) -> bool``.

    ``ttl_s``: optional time-to-live; an entry older than this counts as
    a miss (and is evicted) on lookup. ``clock`` is injectable for
    tests.
    """

    def __init__(self, capacity: int, ttl_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic, obs=None):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError(f"ttl_s must be > 0, got {ttl_s}")
        self.capacity = capacity
        self.ttl_s = ttl_s
        self.clock = clock
        self._d: "OrderedDict[Key, Tuple[bool, float]]" = OrderedDict()
        self.stats = CacheStats()
        # registry cells mirroring CacheStats (the registry survives
        # service-internal resets and feeds the exporters)
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        look = reg.counter("rlc_cache_lookups",
                           desc="result-cache lookups by outcome",
                           labelnames=("outcome",))
        self._m_hit = look.labels(outcome="hit")
        self._m_miss = look.labels(outcome="miss")
        self._m_expired = look.labels(outcome="expired")
        self._m_evict = reg.counter(
            "rlc_cache_evictions",
            desc="LRU capacity evictions").labels()
        self._m_inval = reg.counter(
            "rlc_cache_invalidations",
            desc="entries dropped by invalidate_rows/clear").labels()
        self._m_size = reg.gauge("rlc_cache_size",
                                 desc="entries currently cached").labels()

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Key) -> Optional[bool]:
        """Answer if cached and fresh (refreshing recency), else ``None``."""
        if self.capacity == 0:
            self.stats.misses += 1
            self._m_miss.inc()
            return None
        try:
            val, stamp = self._d[key]
        except KeyError:
            self.stats.misses += 1
            self._m_miss.inc()
            return None
        if self.ttl_s is not None and self.clock() - stamp >= self.ttl_s:
            del self._d[key]
            self.stats.expirations += 1
            self.stats.misses += 1
            self._m_expired.inc()
            self._m_miss.inc()
            return None
        self._d.move_to_end(key)
        self.stats.hits += 1
        self._m_hit.inc()
        return val

    def peek(self, key: Key) -> Optional[bool]:
        """Non-mutating probe: the cached answer if present and fresh,
        else ``None``. No recency refresh, no stats, no counters —
        EXPLAIN's cache-disposition probe must not perturb the serving
        LRU or the hit-rate series it reports on."""
        if self.capacity == 0:
            return None
        pair = self._d.get(key)
        if pair is None:
            return None
        val, stamp = pair
        if self.ttl_s is not None and self.clock() - stamp >= self.ttl_s:
            return None
        return val

    def put(self, key: Key, value: bool) -> None:
        if self.capacity == 0:
            return
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = (bool(value), self.clock())
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.stats.evictions += 1
            self._m_evict.inc()
        self._m_size.set(len(self._d))

    def invalidate_rows(self, dirty_s=None, dirty_t=None) -> int:
        """Evict every key whose source row is in ``dirty_s`` or target
        row is in ``dirty_t`` (containers supporting ``in``); returns the
        eviction count. The targeted flavor of :meth:`clear` for delta
        updates: untouched keys keep serving."""
        dirty_s = dirty_s if dirty_s is not None else ()
        dirty_t = dirty_t if dirty_t is not None else ()
        doomed = [k for k in self._d
                  if k[0] in dirty_s or k[1] in dirty_t]
        for k in doomed:
            del self._d[k]
        self.stats.invalidations += len(doomed)
        self._m_inval.inc(len(doomed))
        self._m_size.set(len(self._d))
        return len(doomed)

    def clear(self) -> None:
        self.stats.invalidations += len(self._d)
        self._m_inval.inc(len(self._d))
        self._d.clear()
        self._m_size.set(0)
