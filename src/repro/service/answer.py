"""The typed query answer: value + disposition + backend attribution.

``query`` / ``query_batch`` / ``submit`` all resolve to :class:`Answer`
objects instead of bare booleans, so callers can tell *how* a query was
answered — cache hit vs freshly computed vs degraded to the BiBFS oracle
mid-swap — and *which* backend computed it, without giving up boolean
ergonomics:

* ``bool(ans)`` / ``if ans:`` coerce to the reachability value exactly
  like the old bare-bool answers;
* ``ans == True`` / ``ans == other_answer`` compare by value only, so
  a cache hit and a computed answer for the same key compare equal and
  list-vs-list comparisons against expected booleans keep working;
* a *shed* answer (admission control dropped the query) is the
  :data:`SHED` singleton — ``ans is SHED`` still works, and ``bool()``
  on it still raises: a shed query has no reachability value and any
  code path coercing one is a bug that must fail loud.

Dispositions:

=============  =======================================================
``cache_hit``  answered from the result cache (no backend ran)
``computed``   executed through the batch path (``backend`` names the
               engine: ``sorted`` / ``numpy`` / ``python`` / ``pallas``,
               ``digest`` for a cross-shard digest join, ``rpc:*`` when
               a shard-host worker process answered over the wire)
``degraded``   answered exactly but off the index path (online BiBFS
               while a shard was mid-swap or its workers were gone)
``shed``       dropped by admission control — no value
=============  =======================================================
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["Answer", "SHED", "DISPOSITIONS"]

DISPOSITIONS = ("cache_hit", "computed", "degraded", "shed")


class Answer:
    """One resolved query result; immutable, value-comparable."""

    __slots__ = ("value", "disposition", "backend")

    def __init__(self, value: Optional[bool], disposition: str,
                 backend: Optional[str] = None):
        if disposition not in DISPOSITIONS:
            raise ValueError(
                f"unknown disposition {disposition!r}; "
                f"choose from {DISPOSITIONS}")
        if (value is None) != (disposition == "shed"):
            raise ValueError(
                "shed answers carry no value; every other disposition "
                f"requires one (got value={value!r}, "
                f"disposition={disposition!r})")
        object.__setattr__(self, "value", value)
        object.__setattr__(self, "disposition", disposition)
        object.__setattr__(self, "backend", backend)

    def __setattr__(self, name, value):
        raise AttributeError("Answer is immutable")

    @property
    def shed(self) -> bool:
        return self.disposition == "shed"

    def __bool__(self) -> bool:
        if self.shed:
            raise TypeError(
                "SHED is not a boolean answer; check `ans is SHED` before "
                "interpreting query results under admission control")
        return self.value

    def __eq__(self, other) -> bool:
        # value-only equality: a cache hit and a computed answer for the
        # same key are the same answer; sheds equal only sheds
        if isinstance(other, Answer):
            if self.shed or other.shed:
                return self.shed and other.shed
            return self.value == other.value
        if isinstance(other, (bool, int, np.bool_)) and not self.shed:
            return self.value == bool(other)
        return NotImplemented

    def __hash__(self) -> int:
        return hash((Answer, self.value))

    def __repr__(self) -> str:
        if self.shed:
            return "SHED"
        b = f", backend={self.backend!r}" if self.backend else ""
        return f"Answer({self.value}, {self.disposition!r}{b})"

    def as_dict(self) -> dict:
        return dict(value=self.value, disposition=self.disposition,
                    backend=self.backend)


#: The singleton explicit shed answer (admission control dropped the
#: query). ``repr(SHED) == "SHED"``, ``bool(SHED)`` raises, and shed
#: answers are always this object — ``ans is SHED`` is the check.
SHED = Answer(None, "shed")
