"""The versioned ``stats()`` schema shared by both service facades.

``RLCService.stats()`` and ``ShardedRLCService.stats()`` grew
independently and drifted: the same logical sections (served/shed
counts, cache, scheduler, control, build, telemetry, shadow) were
assembled twice, with the sharded facade nesting its executor summary
differently and neither document carrying a version. This module is the
dedup: :func:`base_stats` builds every shared section once, each facade
adds only its transport-specific sections (``executor`` / ``index`` /
``router`` / ``shards``), and the result declares itself as
``repro.service.stats/1``.

:func:`validate_stats` mirrors :func:`repro.obs.export.validate_snapshot`
— one validator shared by the tier-1 contract tests and the benchmark
smoke run, failing loudly at the first offending path.

Schema (``repro.service.stats/1``)::

    {
      "schema": "repro.service.stats/1",
      "facade": "single" | "sharded",
      "transport": "local" | "inproc" | "rpc",
      "queries_served": int, "queries_shed": int, "deltas_applied": int,
      "cache": {...}, "scheduler": {...}, "control": {...},
      "executor": {...},             # facade-specific layout
      "index": {...},
      "build": {...} | null,
      "telemetry": {"enabled": bool, "tracing": {...}},
      "shadow": {...} | null,
      "async": {...} | null,         # AsyncEngine ledger when start()ed
      "router": {...},               # sharded only
      "shards": [...],               # sharded only
      "rpc": {...},                  # sharded only, transport="rpc"
    }
"""
from __future__ import annotations

__all__ = ["STATS_SCHEMA", "base_stats", "validate_stats"]

STATS_SCHEMA = "repro.service.stats/1"

_FACADES = {"single", "sharded"}
_TRANSPORTS = {"local", "inproc", "rpc"}

#: sections every facade must carry (value type enforced where stable)
_REQUIRED = ("queries_served", "queries_shed", "deltas_applied",
             "cache", "scheduler", "control", "executor", "index",
             "telemetry")

_SCHED_KEYS = {"batches_full", "batches_deadline", "batches_drain",
               "coalesced", "pending"}


def base_stats(svc, facade: str, transport: str) -> dict:
    """Every section the two facades share, assembled once. The caller
    merges in its transport-specific sections afterwards."""
    return dict(
        schema=STATS_SCHEMA,
        facade=facade,
        transport=transport,
        queries_served=svc.queries_served,
        queries_shed=svc.queries_shed,
        deltas_applied=svc.deltas_applied,
        cache=svc.cache.stats.as_dict(),
        scheduler=dict(
            batches_full=svc.batcher.batches_full,
            batches_deadline=svc.batcher.batches_deadline,
            batches_drain=svc.batcher.batches_drain,
            coalesced=svc.batcher.coalesced,
            pending=svc.batcher.pending()),
        control=svc.ctl.stats(),
        build=(svc.build_stats.as_dict()
               if svc.build_stats is not None else None),
        telemetry=dict(enabled=svc.obs.enabled,
                       tracing=svc.obs.tracer.stats()),
        shadow=(svc._shadow.stats() if svc._shadow is not None else None),
        **{"async": (svc._engine.stats()
                     if svc._engine is not None else None)},
    )


def validate_stats(doc: dict) -> dict:
    """Validate ``doc`` against ``repro.service.stats/1``.

    Returns the doc on success; raises ``ValueError`` naming the first
    offending path otherwise (same contract as
    :func:`repro.obs.export.validate_snapshot`).
    """
    def fail(path: str, why: str):
        raise ValueError(f"service stats invalid at {path}: {why}")

    def expect_int(path: str, v):
        if isinstance(v, bool) or not isinstance(v, int) or v < 0:
            fail(path, f"expected non-negative int, got {v!r}")

    if not isinstance(doc, dict):
        fail("$", f"expected object, got {type(doc).__name__}")
    if doc.get("schema") != STATS_SCHEMA:
        fail("$.schema",
             f"expected {STATS_SCHEMA!r}, got {doc.get('schema')!r}")
    if doc.get("facade") not in _FACADES:
        fail("$.facade", f"expected one of {sorted(_FACADES)}, "
             f"got {doc.get('facade')!r}")
    if doc.get("transport") not in _TRANSPORTS:
        fail("$.transport", f"expected one of {sorted(_TRANSPORTS)}, "
             f"got {doc.get('transport')!r}")
    for k in _REQUIRED:
        if k not in doc:
            fail(f"$.{k}", "missing required section")
    for k in ("queries_served", "queries_shed", "deltas_applied"):
        expect_int(f"$.{k}", doc[k])
    for k in ("cache", "scheduler", "executor", "index", "telemetry"):
        if not isinstance(doc[k], dict):
            fail(f"$.{k}", f"expected object, got {type(doc[k]).__name__}")
    # the control plane reports null with every loop disabled
    if doc["control"] is not None and not isinstance(doc["control"], dict):
        fail("$.control", "expected object or null")
    sched = doc["scheduler"]
    missing = _SCHED_KEYS - set(sched)
    if missing:
        fail("$.scheduler", f"missing keys {sorted(missing)}")
    for k in _SCHED_KEYS:
        expect_int(f"$.scheduler.{k}", sched[k])
    tel = doc["telemetry"]
    if not isinstance(tel.get("enabled"), bool):
        fail("$.telemetry.enabled", "expected bool")
    if not isinstance(tel.get("tracing"), dict):
        fail("$.telemetry.tracing", "expected object")
    for k in ("build", "shadow", "async"):
        if doc.get(k) is not None and not isinstance(doc[k], dict):
            fail(f"$.{k}", "expected object or null")
    a = doc.get("async")
    if a is not None:
        for k in ("submitted", "completed", "shed", "inflight"):
            expect_int(f"$.async.{k}", a.get(k, -1))
        for k in ("admit_s", "exec_s", "overlap_s"):
            v = a.get(k)
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or v < 0:
                fail(f"$.async.{k}",
                     f"expected non-negative number, got {v!r}")
    if doc["facade"] == "sharded":
        if not isinstance(doc.get("router"), dict):
            fail("$.router", "sharded stats must carry the router section")
        if not isinstance(doc.get("shards"), list):
            fail("$.shards", "sharded stats must carry the shards list")
        for i, s in enumerate(doc["shards"]):
            if not isinstance(s, dict):
                fail(f"$.shards[{i}]", "expected object")
        if doc["transport"] == "rpc":
            rpc = doc.get("rpc")
            if not isinstance(rpc, dict):
                fail("$.rpc", "rpc transport must carry the rpc section")
            for k in ("live_workers", "membership_epoch", "joins",
                      "leaves", "rejoins", "retries"):
                expect_int(f"$.rpc.{k}", rpc.get(k, -1))
            if not isinstance(rpc.get("wire_bytes"), dict):
                fail("$.rpc.wire_bytes", "expected object")
    elif doc["transport"] != "local":
        fail("$.transport",
             f"single facade must be transport 'local', "
             f"got {doc['transport']!r}")
    return doc
