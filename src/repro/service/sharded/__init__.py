"""Sharded multi-host RLC serving.

Scales the single-process :class:`repro.service.RLCService` past one
host's memory and batch rate by partitioning the frozen index into
horizontal shards (the FERRARI-style answer to index size limits, applied
across hosts):

* :mod:`~repro.service.sharded.plan` — contiguous vertex-id ranges,
  balanced by *entry count* so hub-heavy vertices don't pile one shard;
* :mod:`~repro.service.sharded.router` — the two-sided router;
* :mod:`~repro.service.sharded.replica` — N replicas per shard,
  round-robin reads, rolling atomic hot-swap of rebuilt slices;
* :mod:`~repro.service.sharded.fanout` — the scatter/gather batch
  executor regrouping micro-batches into per-``(shard_s, shard_t)``
  sub-batches;
* :mod:`~repro.service.sharded.service` — the
  :class:`ShardedRLCService` facade (drop-in ``query`` / ``query_batch``
  / ``stats``).

The two-sided routing invariant
-------------------------------
The paper answers ``query(s, t, MR+)`` by intersecting ``L_out(s)`` with
``L_in(t)`` (Algorithm 1). Under sharding those two sides live on
``shard(s)`` and ``shard(t)`` respectively, so the subsystem maintains one
invariant: **every query executes on shard(t)**, which always reads
``L_in(t)`` locally. ``L_out(s)`` is local too iff ``shard(s) ==
shard(t)`` (the full single-host path over the shard's slice); otherwise
``shard(s)`` *scatters* s's out-row digest to ``shard(t)`` — one hop, one
padded row per query — and the merge-join runs against the local in-rows.
No query ever needs more than one inter-shard hop, and no shard ever
needs another shard's in-side.

Two transports serve the same contracts. ``transport="inproc"``
(default) simulates multi-host with in-process shard workers sharing
one address space; when JAX exposes multiple devices, shard layouts are
pinned round-robin across them and the digest ship becomes a real
``device_put`` transfer. ``transport="rpc"`` is the real thing: one
shard-host *worker process* per (shard, replica), each holding only its
shard's slice, driven over the message-based RPC plane in
:mod:`repro.service.rpc` — the digest hand-off serializes out-rows over
the wire, and answers stay bit-identical to the in-process path.
"""
from .fanout import RpcScatterGatherExecutor, ScatterGatherExecutor
from .plan import ShardPlan, plan_shards
from .replica import (ShardReplica, ShardReplicaSet, build_device_layout,
                      build_replica, dict_index_slice)
from .router import Route, TwoSidedRouter
from .service import ShardedRLCService, ShardedServiceConfig

__all__ = [
    "Route", "RpcScatterGatherExecutor", "ScatterGatherExecutor",
    "ShardPlan", "ShardReplica", "ShardReplicaSet", "ShardedRLCService",
    "ShardedServiceConfig", "TwoSidedRouter", "build_device_layout",
    "build_replica", "dict_index_slice", "plan_shards",
]
