"""Shard planner: contiguous vertex-id ranges balanced by entry count.

A :class:`ShardPlan` partitions ``[0, n)`` into ``num_shards`` contiguous
ranges. Contiguity is what makes :meth:`FrozenRLCIndex.slice_rows` a
zero-copy view (a shard's entries are one contiguous span of the frozen
arrays) and makes shard lookup a single ``searchsorted``. Balance is by
*entry count* (out + in entries per vertex), not vertex count: hub-heavy
vertices carry orders of magnitude more index entries than leaves, so an
equal-vertex split would leave one host holding most of the index — the
same skew FERRARI-style size-restricted indexes budget against per vertex,
applied here across hosts.

The planner walks the cumulative entry-weight prefix sum and cuts at the
``i/num_shards`` quantiles (each vertex weighted ``entries(v) + 1`` so
entry-less vertices still spread instead of all landing in the last
shard), then nudges cuts to keep every shard non-empty.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.core.rlc_index import FrozenRLCIndex


@dataclass(frozen=True)
class ShardPlan:
    """An immutable partition of vertex ids into contiguous shard ranges."""

    num_vertices: int
    starts: np.ndarray   # (num_shards + 1,) int64; starts[0]=0, [-1]=n
    entries: np.ndarray  # (num_shards,) entry count per shard at plan time

    @property
    def num_shards(self) -> int:
        return len(self.starts) - 1

    def shard_of(self, v: int) -> int:
        """Owning shard of vertex ``v`` (O(log num_shards))."""
        return int(np.searchsorted(self.starts, v, side="right")) - 1

    def shard_of_batch(self, v: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.starts, v, side="right") - 1

    def range(self, shard: int) -> Tuple[int, int]:
        """Vertex range ``[lo, hi)`` owned by ``shard``."""
        return int(self.starts[shard]), int(self.starts[shard + 1])

    def ranges(self) -> List[Tuple[int, int]]:
        return [self.range(i) for i in range(self.num_shards)]

    @property
    def balance(self) -> float:
        """max/mean shard entry count — 1.0 is a perfect split."""
        mean = float(self.entries.mean()) if len(self.entries) else 0.0
        return float(self.entries.max()) / mean if mean > 0 else 1.0

    def as_dict(self) -> dict:
        return dict(num_shards=self.num_shards,
                    starts=self.starts.tolist(),
                    entries=self.entries.tolist(),
                    balance=round(self.balance, 4))


def plan_shards(frozen: FrozenRLCIndex, num_shards: int) -> ShardPlan:
    """Cut ``[0, n)`` into ``num_shards`` entry-balanced contiguous ranges."""
    n = frozen.num_vertices
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_shards > n:
        raise ValueError(
            f"num_shards={num_shards} exceeds num_vertices={n}")
    w = frozen.entry_weights().astype(np.int64) + 1
    cum = np.cumsum(w)
    total = int(cum[-1])
    starts = np.zeros(num_shards + 1, dtype=np.int64)
    starts[num_shards] = n
    for i in range(1, num_shards):
        cut = int(np.searchsorted(cum, total * i / num_shards, side="left"))
        # keep every shard non-empty: this cut must leave room on both sides
        starts[i] = min(max(cut, starts[i - 1] + 1), n - (num_shards - i))
    ew = frozen.entry_weights()
    entries = np.array([int(ew[starts[i]:starts[i + 1]].sum())
                        for i in range(num_shards)], dtype=np.int64)
    return ShardPlan(n, starts, entries)
