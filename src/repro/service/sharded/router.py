"""Two-sided query router.

Every query ``(s, t, MR+)`` maps to the shard pair
``(shard(s), shard(t))``. The routing invariant:

    a query always *executes* on ``shard(t)`` — the owner of t's in-rows —
    reading ``L_in(t)`` locally; ``L_out(s)`` arrives either locally
    (same-shard query, full Algorithm 1 on the shard's slice) or as a
    one-hop *digest* shipped from ``shard(s)`` (cross-shard query, the
    paper's s-out ∩ t-in intersection becomes a scatter of s's out-row
    followed by a local merge-join).

Anchoring on the in-side is the cheaper direction for RLC indexes: the
digest is one padded out-row per query, while the join state (t's in-row
plus the merge machinery) never moves. The router only *decides*; moving
rows and running joins is :mod:`repro.service.sharded.fanout`'s job.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.obs import NULL_OBS

from .plan import ShardPlan


@dataclass(frozen=True)
class Route:
    """Where one query lives: executes on ``home`` (= ``shard_t``)."""

    shard_s: int
    shard_t: int

    @property
    def home(self) -> int:
        return self.shard_t

    @property
    def local(self) -> bool:
        return self.shard_s == self.shard_t


class TwoSidedRouter:
    """Maps admitted queries to shard pairs and keeps traffic counters."""

    def __init__(self, plan: ShardPlan, obs=None):
        self.plan = plan
        self.local_routes = 0
        self.remote_routes = 0
        self.pair_counts: Dict[Tuple[int, int], int] = {}
        self.obs = obs or NULL_OBS
        routes = self.obs.registry.counter(
            "rlc_router_routes", desc="query routing decisions",
            labelnames=("kind",))
        self._m_local = routes.labels(kind="local")
        self._m_remote = routes.labels(kind="remote")

    def route(self, s: int, t: int) -> Route:
        r = Route(self.plan.shard_of(s), self.plan.shard_of(t))
        if r.local:
            self.local_routes += 1
            self._m_local.inc()
        else:
            self.remote_routes += 1
            self._m_remote.inc()
        key = (r.shard_s, r.shard_t)
        self.pair_counts[key] = self.pair_counts.get(key, 0) + 1
        return r

    @property
    def total_routes(self) -> int:
        return self.local_routes + self.remote_routes

    @property
    def local_ratio(self) -> float:
        n = self.total_routes
        return self.local_routes / n if n else 0.0

    def stats(self) -> dict:
        return dict(
            local=self.local_routes,
            remote=self.remote_routes,
            local_ratio=round(self.local_ratio, 4),
            pairs={f"{a}->{b}": c
                   for (a, b), c in sorted(self.pair_counts.items())})
