"""Per-shard replica sets with round-robin reads and atomic hot-swap.

Each shard holds ``num_replicas`` interchangeable :class:`ShardReplica`
objects — a frozen slice, its (optional) device layout, and a
:class:`BatchExecutor` over them. Read traffic round-robins across
replicas (:meth:`ShardReplicaSet.acquire`); a rebuild swaps replicas in
*rolling* fashion: the replacement is fully constructed (freeze + device
transfer + executor) before a single reference assignment publishes it,
so a reader that acquired the old replica finishes its batch on a
consistent index while new acquires already see the new generation —
there is never a moment when a replica is half-swapped.

When the host exposes multiple JAX devices, each shard's device arrays are
placed round-robin across them (`shard_id % len(devices)`) — in-process
workers standing in for real multi-host placement; a failed placement
degrades to the default device rather than to no device layout.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.minimum_repeat import LabelSeq
from repro.core.rlc_index import FrozenRLCIndex, RLCIndex

from ..executor import BatchExecutor


def dict_index_slice(frozen_slice: FrozenRLCIndex, lo: int, hi: int,
                     id_to_mr: Sequence[LabelSeq]) -> RLCIndex:
    """Shard-local dict-layout index reconstructed from a frozen slice.

    The python-fallback twin of :meth:`FrozenRLCIndex.slice_rows`: entry
    dicts populated only for rows ``[lo, hi)``, global vertex ids and
    ``aid`` kept. This is what a shard-host *worker process* serves its
    python path from — a host never materializes the global dict index
    (:mod:`repro.service.rpc.worker`); the routing invariant guarantees
    every locally executed query has both endpoints in range.
    """
    n = frozen_slice.num_vertices
    l_out: List[dict] = [dict() for _ in range(n)]
    l_in: List[dict] = [dict() for _ in range(n)]

    def fill(maps, indptr, hub, mr):
        for v in range(lo, hi):
            a, b = int(indptr[v]), int(indptr[v + 1])
            d = maps[v]
            for h, m in zip(hub[a:b], mr[a:b]):
                d.setdefault(int(h), set()).add(tuple(id_to_mr[int(m)]))

    fill(l_out, frozen_slice.out_indptr, frozen_slice.out_hub,
         frozen_slice.out_mr)
    fill(l_in, frozen_slice.in_indptr, frozen_slice.in_hub,
         frozen_slice.in_mr)
    return RLCIndex(n, frozen_slice.k,
                    np.asarray(frozen_slice.aid, dtype=np.int64),
                    l_in=l_in, l_out=l_out)


@dataclasses.dataclass
class ShardReplica:
    """One serveable copy of a shard: frozen slice + device layout +
    executor."""

    shard_id: int
    replica_id: int
    generation: int
    frozen: FrozenRLCIndex          # slice view: rows [lo, hi) populated
    device_index: Optional[object]  # DeviceIndex or None (degraded mode)
    executor: BatchExecutor
    device: Optional[object] = None  # jax.Device this replica is pinned to


def _pin(device_index, device):
    """Move a DeviceIndex's arrays onto ``device`` (best-effort)."""
    if device_index is None or device is None:
        return device_index
    try:
        import jax
        put = lambda a: (jax.device_put(a, device)  # noqa: E731
                         if isinstance(a, jax.Array) else a)
        return dataclasses.replace(
            device_index,
            out_hub=put(device_index.out_hub),
            out_mr=put(device_index.out_mr),
            in_hub=put(device_index.in_hub),
            in_mr=put(device_index.in_mr),
            out_key=put(device_index.out_key),
            in_key=put(device_index.in_key))
    except Exception:
        return device_index


def build_device_layout(frozen_slice: FrozenRLCIndex, mr_ids,
                        rows: Optional[Tuple[int, int]] = None,
                        device=None):
    """Row-windowed device layout for one shard slice, or None (degraded
    CPU-only mode). Built once per (shard, generation) and shared by every
    replica pinned to the same device — the arrays are immutable."""
    try:
        from repro.core.device_index import DeviceIndex
        return _pin(DeviceIndex.from_frozen(frozen_slice, mr_ids,
                                            rows=rows), device)
    except Exception:   # no jax / no device
        return None


def build_replica(shard_id: int, replica_id: int, generation: int,
                  frozen_slice: FrozenRLCIndex, mr_ids,
                  index: RLCIndex, id_to_mr: Sequence[LabelSeq],
                  backend: str = "auto", use_device: bool = True,
                  device=None,
                  rows: Optional[Tuple[int, int]] = None,
                  shared_device_index=None, obs=None) -> ShardReplica:
    """Fully construct one replica (the unit hot-swap publishes).

    ``rows=(lo, hi)`` is the shard's vertex range: the device layout packs
    only that row window, so per-shard device memory shrinks ~1/S. Pass
    ``shared_device_index`` (from :func:`build_device_layout`) to reuse one
    immutable layout across a shard's replicas instead of re-packing it
    per replica. ``index``/``id_to_mr`` are the global dict-layout
    reference — the always-available python fallback; the simulated hosts
    share it in-process, a real deployment would ship each shard a slice
    of it.
    """
    device_index = None
    if use_device:
        device_index = (shared_device_index
                        if shared_device_index is not None
                        else build_device_layout(frozen_slice, mr_ids,
                                                 rows=rows, device=device))
    executor = BatchExecutor(index, frozen_slice, device_index,
                             id_to_mr, backend=backend, obs=obs,
                             shard=str(shard_id))
    return ShardReplica(shard_id, replica_id, generation, frozen_slice,
                        device_index, executor, device)


class ShardReplicaSet:
    """All replicas of one shard; round-robin reads, rolling hot-swap."""

    def __init__(self, shard_id: int, lo: int, hi: int,
                 replicas: List[ShardReplica], obs=None):
        if not replicas:
            raise ValueError(f"shard {shard_id} needs >= 1 replica")
        self.shard_id = shard_id
        self.lo = lo
        self.hi = hi
        self.replicas = replicas
        self._rr = itertools.count()
        self._swap_lock = threading.Lock()
        #: True while :meth:`swap` is rebuilding this shard's replicas —
        #: the fan-out's signal to degrade new sub-batches to the online
        #: BiBFS fallback instead of racing the rolling publish
        self.swapping = False
        self.swaps = 0
        self.last_build_backend: Optional[str] = None
        self.obs = obs
        # Executors are rebuilt on every hot-swap, which used to zero their
        # per-shard fallback counts mid-stream; swap() banks the outgoing
        # replicas' counts here so attribution survives the generation.
        self._carried_fallbacks = 0
        self._carried_batches: dict = {}
        self._carried_queries: dict = {}

    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    @property
    def generation(self) -> int:
        return min(r.generation for r in self.replicas)

    def acquire(self) -> ShardReplica:
        """Round-robin pick; the returned replica stays valid for the whole
        batch even if a swap lands meanwhile (old object keeps serving)."""
        return self.replicas[next(self._rr) % len(self.replicas)]

    def swap(self, generation: int, frozen_slice: FrozenRLCIndex, mr_ids,
             index: RLCIndex, id_to_mr: Sequence[LabelSeq],
             backend: str = "auto", use_device: bool = True,
             build_backend: Optional[str] = None) -> None:
        """Rolling replace of every replica with a freshly built one.
        ``build_backend`` records which :mod:`repro.build` backend
        produced the incoming index (surfaced in :meth:`stats`)."""
        with self._swap_lock:
            self.swapping = True
            try:
                self._swap_locked(generation, frozen_slice, mr_ids, index,
                                  id_to_mr, backend, use_device,
                                  build_backend)
            finally:
                self.swapping = False

    def _swap_locked(self, generation, frozen_slice, mr_ids, index,
                     id_to_mr, backend, use_device, build_backend) -> None:
        self.last_build_backend = build_backend
        # one device pack per (shard, generation, device); replicas on
        # the same device share the immutable layout
        layouts = {}
        if use_device:
            for old in self.replicas:
                if old.device not in layouts:
                    layouts[old.device] = build_device_layout(
                        frozen_slice, mr_ids, rows=(self.lo, self.hi),
                        device=old.device)
        for i, old in enumerate(list(self.replicas)):
            fresh = build_replica(
                self.shard_id, old.replica_id, generation, frozen_slice,
                mr_ids, index, id_to_mr, backend=backend,
                use_device=use_device, device=old.device,
                rows=(self.lo, self.hi),
                shared_device_index=layouts.get(old.device),
                obs=self.obs)
            # bank the outgoing replica's counters before the publish:
            # the fresh executor starts at zero, the set-level totals
            # must not
            self._carried_fallbacks += old.executor.fallbacks
            for b, rec in old.executor.recorders.items():
                if rec.batches:
                    self._carried_batches[b] = (
                        self._carried_batches.get(b, 0) + rec.batches)
                    self._carried_queries[b] = (
                        self._carried_queries.get(b, 0) + rec.queries)
            # single reference assignment = the atomic publish point
            self.replicas[i] = fresh
        self.swaps += 1

    @property
    def fallbacks(self) -> int:
        """Fallback batches attributed to this shard across *all*
        generations: counts banked at swap time plus the live replicas'."""
        return self._carried_fallbacks + sum(
            r.executor.fallbacks for r in self.replicas)

    def backend_totals(self) -> dict:
        """Per-backend ``{batches, queries}`` across generations."""
        out = {b: dict(batches=n, queries=self._carried_queries.get(b, 0))
               for b, n in self._carried_batches.items()}
        for r in self.replicas:
            for b, rec in r.executor.recorders.items():
                if rec.batches:
                    d = out.setdefault(b, dict(batches=0, queries=0))
                    d["batches"] += rec.batches
                    d["queries"] += rec.queries
        return out

    def stats(self) -> dict:
        r0 = self.replicas[0]
        return dict(
            shard=self.shard_id,
            lo=self.lo, hi=self.hi,
            vertices=self.hi - self.lo,
            entries=r0.frozen.num_entries(),
            size_bytes=r0.frozen.size_bytes(),
            replicas=self.num_replicas,
            generation=self.generation,
            swaps=self.swaps,
            fallbacks=self.fallbacks,
            backends=self.backend_totals(),
            build_backend=self.last_build_backend,
            device=r0.device_index is not None,
            row_len=(r0.device_index.row_len
                     if r0.device_index is not None else None),
        )
