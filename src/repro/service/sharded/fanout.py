"""Scatter/gather batch executor over shard replica sets.

Takes one admitted micro-batch (:class:`repro.service.scheduler.Batch`),
regroups its requests into per-``(shard_s, shard_t)`` sub-batches, runs
each sub-batch on the owning shard's replicas, and gathers the answers
back into admission order:

* **same-shard** ``(i, i)`` — the full multi-backend
  :class:`BatchExecutor` of one replica of shard *i* (pallas / XLA-sorted /
  frozen-numpy / python with fallback), exactly the single-host path but
  over the shard's slice;
* **cross-shard** ``(i, j)`` — the *scatter* hop: a replica of shard *i*
  gathers the padded out-row digests of the batch's source vertices and
  ships them to shard *j*'s device (simulated one-hop transfer;
  ``jax.device_put`` when the shards are pinned to different devices),
  where :func:`repro.core.device_index.join_rows` merge-joins digests
  against *j*'s local in-rows. Without device layouts the same join runs
  row-by-row through :func:`repro.core.rlc_index.merge_join_rows`.

Sub-batches are padded to the next power of two (capped at the admission
batch size) by repeating their first request, so each shard pair sees a
small, bounded set of jit shapes instead of one per sub-batch length.

When either side's replica set is mid-swap (``ShardReplicaSet.swapping``),
the sub-batch gracefully degrades to the online BiBFS fallback on the
live graph instead of racing the rolling publish — exact answers (BiBFS
is the oracle), just slower, counted in ``rlc_fanout_degraded``. Requires
the executor to be constructed with ``graph``/``id_to_mr``; without them
the degrade path is unavailable and sub-batches acquire replicas as
before.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.rlc_index import merge_join_rows
from repro.obs import NULL_OBS

from ..metrics import LatencyRecorder
from ..scheduler import Batch
from .replica import ShardReplica, ShardReplicaSet
from .router import TwoSidedRouter


def _pad_pow2(vals: List[int], cap: int) -> np.ndarray:
    """Pad to the next power of two (<= cap) by repeating the first value."""
    n = len(vals)
    size = 1
    while size < n:
        size *= 2
    size = min(size, cap) if cap >= n else n
    out = np.full(size, vals[0], dtype=np.int32)
    out[:n] = np.asarray(vals, dtype=np.int32)
    return out


class ScatterGatherExecutor:
    def __init__(self, shards: List[ShardReplicaSet],
                 router: TwoSidedRouter, batch_size: int, obs=None,
                 graph=None, id_to_mr=None):
        self.shards = shards
        self.router = router
        self.batch_size = batch_size
        self.graph = graph          # live graph for the BiBFS degrade path
        self.id_to_mr = id_to_mr
        self.recorders = dict(local=LatencyRecorder("local"),
                              remote=LatencyRecorder("remote"))
        self.sub_batches: Dict[Tuple[int, int], int] = {}
        self.remote_joins_device = 0
        self.remote_joins_numpy = 0
        self.degraded = 0       # sub-batches answered by BiBFS mid-swap
        self.digest_bytes = 0   # simulated cross-host traffic
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        sub = reg.histogram(
            "rlc_fanout_subbatch_seconds",
            desc="wall time of one per-(shard_s, shard_t) sub-batch",
            unit="s", labelnames=("path",))
        self._m_sub = {p: sub.labels(path=p) for p in ("local", "remote")}
        self._m_digest = reg.counter(
            "rlc_fanout_digest_bytes",
            desc="simulated cross-shard digest traffic", unit="By").labels()
        joins = reg.counter("rlc_fanout_remote_joins",
                            desc="cross-shard digest joins by path",
                            labelnames=("path",))
        self._m_join = {p: joins.labels(path=p)
                        for p in ("device", "numpy")}
        self._m_degraded = reg.counter(
            "rlc_fanout_degraded",
            desc="sub-batches degraded to online BiBFS because a shard "
                 "replica set was mid-swap").labels()

    def _degrade_bibfs(self, reqs, idxs) -> np.ndarray:
        """Answer one sub-batch by online bidirectional BFS on the live
        graph — the mid-swap fallback. Exact (BiBFS is the oracle), so
        answers stay bit-identical to the index path."""
        from repro.core.baselines import bibfs_rlc
        out = np.zeros(len(idxs), dtype=bool)
        for j, q in enumerate(idxs):
            r = reqs[q]
            out[j] = bibfs_rlc(self.graph, r.s, r.t,
                               self.id_to_mr[r.mr_id])
        self.degraded += 1
        self._m_degraded.inc()
        return out

    # -- transport hooks (overridden by the RPC executor) --------------- #
    def _swapping(self, shard_id: int) -> bool:
        """True when ``shard_id`` cannot take a sub-batch right now (its
        replica set is mid-swap) and the degrade path should answer."""
        return self.shards[shard_id].swapping

    def _run_sub(self, ss: int, st: int, s: np.ndarray, t: np.ndarray,
                 mr: np.ndarray, n_real: int,
                 trace=None) -> Tuple[np.ndarray, str]:
        """Execute one padded ``(shard_s, shard_t)`` sub-batch; returns
        ``(answers[:n_real], backend_label)``. The in-process transport
        acquires replicas directly; :class:`RpcScatterGatherExecutor`
        sends the same sub-batch to worker processes."""
        if ss == st:
            rep = self.shards[st].acquire()
            ans, backend = rep.executor.execute(s, t, mr, n_real=n_real,
                                                trace=trace)
            return np.asarray(ans[:n_real], dtype=bool), backend
        ans = self._cross_shard(ss, st, s, t, mr, n_real, trace=trace)
        return np.asarray(ans[:n_real], dtype=bool), "digest"

    # ------------------------------------------------------------------ #
    def execute(self, batch: Batch,
                trace=None) -> Tuple[np.ndarray, List[str]]:
        """Answer every real request of ``batch``, in admission order.
        Returns ``(answers, backends)``: the bool answers plus one
        backend-attribution label per request (same order) for the typed
        :class:`~repro.service.answer.Answer` results. ``trace``:
        optional sampled :class:`repro.obs.Trace` — the shard route,
        each sub-batch, and the digest hand-off get spans."""
        reqs = batch.requests
        t_route = time.perf_counter()
        groups: Dict[Tuple[int, int], List[int]] = {}
        for q, r in enumerate(reqs):
            route = self.router.route(r.s, r.t)
            groups.setdefault((route.shard_s, route.home), []).append(q)
        if trace is not None:
            dt = time.perf_counter() - t_route
            trace.add("route", trace.tracer._now() - dt, dt, cat="fanout",
                      n=len(reqs), sub_batches=len(groups))
        answers = np.zeros(len(reqs), dtype=bool)
        backends: List[str] = [""] * len(reqs)
        for (ss, st), idxs in sorted(groups.items()):
            self.sub_batches[(ss, st)] = self.sub_batches.get((ss, st), 0) + 1
            can_degrade = (self.graph is not None
                           and self.id_to_mr is not None)
            if can_degrade and (self._swapping(ss) or self._swapping(st)):
                t0 = time.perf_counter()
                ans = self._degrade_bibfs(reqs, idxs)
                dt = time.perf_counter() - t0
                self.recorders["local"].record(dt, len(idxs))
                if trace is not None:
                    trace.add(f"sub[{ss}->{st}]",
                              trace.tracer._now() - dt, dt, cat="fanout",
                              n=len(idxs), path="degraded")
                answers[np.asarray(idxs)] = ans
                for q in idxs:
                    backends[q] = "bibfs"
                continue
            s = _pad_pow2([reqs[q].s for q in idxs], self.batch_size)
            t = _pad_pow2([reqs[q].t for q in idxs], self.batch_size)
            mr = _pad_pow2([reqs[q].mr_id for q in idxs], self.batch_size)
            t0 = time.perf_counter()
            try:
                ans, backend = self._run_sub(ss, st, s, t, mr, len(idxs),
                                             trace=trace)
            except Exception:
                # transport failure (e.g. every worker of a shard died
                # mid-call): the degrade path still answers exactly
                if not can_degrade:
                    raise
                ans, backend = self._degrade_bibfs(reqs, idxs), "bibfs"
            path = "local" if ss == st else "remote"
            dt = time.perf_counter() - t0
            self.recorders[path].record(dt, len(idxs))
            self._m_sub[path].observe(dt)
            if trace is not None:
                trace.add(f"sub[{ss}->{st}]", trace.tracer._now() - dt, dt,
                          cat="fanout", n=len(idxs), path=path)
            answers[np.asarray(idxs)] = np.asarray(ans[:len(idxs)],
                                                   dtype=bool)
            for q in idxs:
                backends[q] = backend
        return answers, backends

    # ------------------------------------------------------------------ #
    def _cross_shard(self, ss: int, st: int, s: np.ndarray, t: np.ndarray,
                     mr: np.ndarray, n_real: int,
                     trace=None) -> np.ndarray:
        """Digest scatter from shard ``ss`` + merge-join at shard ``st``.

        ``s``/``t``/``mr`` are shape-padded; only the first ``n_real``
        entries are real queries (padding exists solely to bound jit
        shapes on the device path — the numpy path and the traffic
        accounting skip it).
        """
        src = self.shards[ss].acquire()
        dst = self.shards[st].acquire()
        if src.device_index is not None and dst.device_index is not None:
            try:
                ans = self._join_device(src, dst, s, t, mr, n_real)
                self.remote_joins_device += 1
                self._m_join["device"].inc()
                return ans[:n_real]
            except Exception:
                pass    # device trouble: the numpy join always works
        self.remote_joins_numpy += 1
        self._m_join["numpy"].inc()
        return self._join_numpy(src, dst, s[:n_real], t[:n_real],
                                mr[:n_real])

    def _join_device(self, src: ShardReplica, dst: ShardReplica,
                     s, t, mr, n_real: int) -> np.ndarray:
        import jax
        from repro.core.device_index import join_rows
        oh, om = src.device_index.gather_out_rows(s)
        if src.device is not None and src.device != dst.device:
            # the one-hop digest ship (real transfer when pinned apart)
            oh = jax.device_put(oh, dst.device)
            om = jax.device_put(om, dst.device)
        ih, im = dst.device_index.gather_in_rows(t)
        import jax.numpy as jnp
        ans = np.asarray(join_rows(oh, om, ih, im,
                                   jnp.asarray(s, jnp.int32),
                                   jnp.asarray(t, jnp.int32),
                                   jnp.asarray(mr, jnp.int32)))
        # traffic accounting only after the join succeeded (a failure falls
        # back to the numpy join, which does its own counting) — real rows
        # only, padding ships just for the jit shape
        nbytes = 2 * n_real * int(oh.shape[1]) * 4
        self.digest_bytes += nbytes
        self._m_digest.inc(nbytes)
        return ans

    def _join_numpy(self, src: ShardReplica, dst: ShardReplica,
                    s, t, mr) -> np.ndarray:
        out = np.zeros(len(s), dtype=bool)
        aid = src.frozen.aid
        for q in range(len(s)):
            oh, om = src.frozen.row_out(int(s[q]))     # the digest
            ih, im = dst.frozen.row_in(int(t[q]))
            self.digest_bytes += (oh.nbytes + om.nbytes)
            self._m_digest.inc(oh.nbytes + om.nbytes)
            out[q] = merge_join_rows(oh, om, ih, im, aid,
                                     int(s[q]), int(t[q]), int(mr[q]))
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return dict(
            local=self.recorders["local"].summary(),
            remote=self.recorders["remote"].summary(),
            sub_batches={f"{a}->{b}": c
                         for (a, b), c in sorted(self.sub_batches.items())},
            remote_joins_device=self.remote_joins_device,
            remote_joins_numpy=self.remote_joins_numpy,
            degraded=self.degraded,
            digest_bytes=self.digest_bytes,
        )


class RpcScatterGatherExecutor(ScatterGatherExecutor):
    """The same scatter/gather, but every sub-batch crosses a process
    boundary: same-shard work goes to a shard-host worker over RPC
    (``transport="rpc"``), and the cross-shard digest hand-off gathers
    out-row digests from shard *i*'s worker and ships the *bytes* to
    shard *j*'s worker for the merge join — the wire replacing
    ``jax.device_put``.

    Inherits routing, padding, accounting, tracing, and the BiBFS
    degrade path; only the three transport hooks differ. A shard is
    "swapping" here when no live, unfenced worker can serve it (the
    cluster fences workers one at a time during a rolling swap, so with
    replicas > 1 this almost never degrades). A :class:`WorkerLost`
    escaping a sub-batch is caught by the base class and answered by
    BiBFS — exact answers survive total shard loss.
    """

    def __init__(self, cluster, router: TwoSidedRouter, batch_size: int,
                 obs=None, graph=None, id_to_mr=None):
        # the base class wants replica sets; the cluster stands in for
        # them — shards=[] keeps every inherited in-process path unused
        super().__init__([], router, batch_size, obs=obs, graph=graph,
                         id_to_mr=id_to_mr)
        self.cluster = cluster
        self.remote_joins_rpc = 0

    def _swapping(self, shard_id: int) -> bool:
        return self.cluster.swapping(shard_id)

    def _run_sub(self, ss: int, st: int, s: np.ndarray, t: np.ndarray,
                 mr: np.ndarray, n_real: int,
                 trace=None) -> Tuple[np.ndarray, str]:
        if ss == st:
            ans, backend = self.cluster.execute(st, s, t, mr, n_real)
            return np.asarray(ans[:n_real], dtype=bool), f"rpc:{backend}"
        # scatter: shard ss's worker gathers out-row digests ...
        digest = self.cluster.gather_digest(ss, s[:n_real])
        nbytes = int(digest["hub"].nbytes + digest["mr"].nbytes)
        # ... which cross the wire (real bytes, not simulated) ...
        self.digest_bytes += nbytes
        self._m_digest.inc(nbytes)
        if trace is not None:
            trace.add(f"digest[{ss}->{st}]", trace.tracer._now(), 0.0,
                      cat="fanout", bytes=nbytes)
        # ... and shard st's worker merge-joins them against its in-rows
        ans = self.cluster.join_digest(st, s[:n_real], t[:n_real],
                                       mr[:n_real], digest)
        self.remote_joins_rpc += 1
        self._m_join["numpy"].inc()
        return np.asarray(ans[:n_real], dtype=bool), "rpc:digest"

    def stats(self) -> dict:
        st = super().stats()
        st["remote_joins_rpc"] = self.remote_joins_rpc
        st["rpc"] = self.cluster.stats()
        return st
