"""The :class:`ShardedRLCService` facade: plan -> slice -> replicate ->
route -> scatter/gather.

Drop-in for :class:`repro.service.RLCService` (same ``query`` /
``query_batch`` / ``stats`` surface, same admission pipeline of parser ->
result cache -> micro-batcher), but flushed batches fan out across shard
replica sets instead of one executor::

    g = erdos_renyi(2000, 4.0, 4)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=4, num_replicas=2))
    svc.query(3, 1700, "(0 1)+")
    svc.hot_swap(graph=updated_g)       # rolling rebuild under traffic

See :mod:`repro.service.sharded` for the routing invariant and
:mod:`repro.service.sharded.fanout` for the scatter/gather mechanics.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.build import BuildStats, build_rlc_index_with_stats
from repro.core.graph import LabeledGraph
from repro.core.minimum_repeat import LabelSeq, mr_id_space
from repro.core.rlc_index import RLCIndex
from repro.obs import Observability

from ..cache import ResultCache
from ..control import ControlPlane
from ..scheduler import Batch, MicroBatcher
from ..service import RLCService, ServiceConfig
from .fanout import ScatterGatherExecutor
from .plan import ShardPlan, plan_shards
from .replica import ShardReplicaSet, build_device_layout, build_replica
from .router import TwoSidedRouter


@dataclass
class ShardedServiceConfig(ServiceConfig):
    num_shards: int = 2
    num_replicas: int = 1
    #: "inproc" — shard replicas live in this process (the simulated
    #: multi-host of ISSUE-3); "rpc" — one shard-host *worker process*
    #: per (shard, replica), each holding only its shard slice, driven
    #: over the message-based RPC transport (:mod:`repro.service.rpc`)
    transport: str = "inproc"
    #: per-request RPC timeout (rpc transport only)
    rpc_call_timeout_s: float = 120.0
    #: worker fleet boot timeout (rpc transport only)
    rpc_start_timeout_s: float = 60.0


def _shard_devices(num_shards: int) -> List[Optional[object]]:
    """Round-robin shard -> device placement when >1 device is visible
    (in-process stand-in for multi-host; None pins nothing)."""
    try:
        import jax
        devs = jax.devices()
        if len(devs) > 1:
            return [devs[i % len(devs)] for i in range(num_shards)]
    except Exception:
        pass
    return [None] * num_shards


class ShardedRLCService:
    def __init__(self, graph: LabeledGraph, index: RLCIndex,
                 config: ShardedServiceConfig,
                 build_stats: Optional[BuildStats] = None,
                 obs: Optional[Observability] = None):
        self.graph = graph
        self.index = index
        self.config = config
        self.build_stats = build_stats   # None when the index was adopted
        self.obs = obs or Observability(
            enabled=config.telemetry,
            trace_sample_rate=config.trace_sample_rate,
            max_trace_events=config.trace_max_events)
        self.mr_ids = mr_id_space(graph.num_labels, config.k)
        self._id_to_mr: List[LabelSeq] = [
            mr for mr, _ in sorted(self.mr_ids.items(), key=lambda kv: kv[1])]
        self.frozen = index.freeze(self.mr_ids)
        self.plan: ShardPlan = plan_shards(self.frozen, config.num_shards)
        self.generation = 0
        if config.transport not in ("inproc", "rpc"):
            raise ValueError(
                f"transport must be 'inproc' or 'rpc', "
                f"got {config.transport!r}")
        self.cluster = None         # RpcShardCluster under transport="rpc"
        self.shards: List[ShardReplicaSet] = []
        self.router = TwoSidedRouter(self.plan, obs=self.obs)
        if config.transport == "rpc":
            # true multi-process serving: one shard-host worker process
            # per (shard, replica); this process keeps only the global
            # frozen (for EXPLAIN/audit/rebuilds) — serving state lives
            # in the workers, each holding its slice alone
            from ..rpc import RpcShardCluster
            from .fanout import RpcScatterGatherExecutor
            self.cluster = RpcShardCluster(
                self.plan.ranges(), config.num_replicas, self._id_to_mr,
                obs=self.obs, start_timeout_s=config.rpc_start_timeout_s,
                call_timeout_s=config.rpc_call_timeout_s)
            self.cluster.start(self.frozen, generation=self.generation)
            self.fanout = RpcScatterGatherExecutor(
                self.cluster, self.router, config.batch_size,
                obs=self.obs, graph=graph, id_to_mr=self._id_to_mr)
        else:
            devices = _shard_devices(config.num_shards)
            for sid in range(config.num_shards):
                lo, hi = self.plan.range(sid)
                sl = self.frozen.slice_rows(lo, hi)
                layout = (build_device_layout(sl, self.mr_ids,
                                              rows=(lo, hi),
                                              device=devices[sid])
                          if config.use_device else None)
                replicas = [
                    build_replica(sid, rid, self.generation, sl,
                                  self.mr_ids, index, self._id_to_mr,
                                  backend=config.backend,
                                  use_device=config.use_device,
                                  device=devices[sid], rows=(lo, hi),
                                  shared_device_index=layout, obs=self.obs)
                    for rid in range(config.num_replicas)]
                self.shards.append(
                    ShardReplicaSet(sid, lo, hi, replicas, obs=self.obs))
            self.fanout = ScatterGatherExecutor(
                self.shards, self.router, config.batch_size, obs=self.obs,
                graph=graph, id_to_mr=self._id_to_mr)
        self.cache = ResultCache(config.cache_capacity,
                                 ttl_s=config.cache_ttl_s, obs=self.obs)
        clock = (config.clock if config.clock is not None
                 else time.monotonic)
        self.ctl = ControlPlane.from_config(
            config, self.obs, self.cache, self._warm_execute, clock)
        self.batcher = MicroBatcher(
            config.batch_size, config.max_wait_ms * 1e-3,
            clock=clock, obs=self.obs,
            params_fn=(self.ctl.slo.params
                       if self.ctl.slo is not None else None))
        self.queries_served = 0
        self.queries_shed = 0
        self.deltas_applied = 0
        self._delta = None          # lazy DeltaBuilder (apply_delta)
        self._engine = None         # lazy AsyncEngine (start()/submit())
        self._closed = False
        self._last_audit = None     # most recent audit_report() document
        self._m_explain = self.obs.registry.counter(
            "rlc_explain_requests",
            desc="EXPLAIN bundles produced, by witness kind",
            labelnames=("kind",))
        from repro.obs.shadow import attach_shadow
        self._shadow = attach_shadow(self)

    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, graph: LabeledGraph,
              config: Optional[ShardedServiceConfig] = None,
              index: Optional[RLCIndex] = None) -> "ShardedRLCService":
        """Build (or adopt) the RLC index for ``graph``, shard it, serve.
        Builds go through the configured :mod:`repro.build` backend."""
        config = config or ShardedServiceConfig()
        obs = Observability(enabled=config.telemetry,
                            trace_sample_rate=config.trace_sample_rate,
                            max_trace_events=config.trace_max_events)
        build_stats = None
        if index is None:
            index, build_stats = build_rlc_index_with_stats(
                graph, config.k, backend=config.build_backend,
                observer=obs.build_observer())
        elif index.k != config.k:
            raise ValueError(
                f"index built with k={index.k} but config.k={config.k}")
        return cls(graph, index, config, build_stats=build_stats, obs=obs)

    # -- admission + serving loop (shared with RLCService) --------------- #
    # Borrowed unbound: the whole parser -> cache -> micro-batcher ->
    # backfill loop is identical; only _run_batch (scatter/gather fan-out
    # instead of one executor) differs.
    parse = RLCService.parse
    _admit = RLCService._admit
    query = RLCService.query
    query_batch = RLCService.query_batch
    _execute = RLCService._execute
    _warm_execute = RLCService._warm_execute
    _delta_backend_name = RLCService._delta_backend_name
    _ensure_delta_builder = RLCService._ensure_delta_builder
    explain = RLCService.explain
    drain_shadow = RLCService.drain_shadow
    telemetry_snapshot = RLCService.telemetry_snapshot
    chrome_trace = RLCService.chrome_trace
    prometheus = RLCService.prometheus
    # unified lifecycle: identical start()/submit()/close()/context-
    # manager protocol on both facades (one AsyncEngine implementation)
    start = RLCService.start
    submit = RLCService.submit
    start_ticker = RLCService.start_ticker
    stop_ticker = RLCService.stop_ticker
    __enter__ = RLCService.__enter__
    __exit__ = RLCService.__exit__

    def close(self) -> None:
        """Same contract as :meth:`RLCService.close`, plus the worker
        fleet: under ``transport="rpc"`` the shard-host processes get a
        graceful shutdown after the engine drains."""
        already = self._closed
        RLCService.close(self)
        if not already and self.cluster is not None:
            self.cluster.close()

    def _adopt_rebuilt_index(self, db) -> None:
        """Sharded flavor of the bootstrap-over-adopted-index resync:
        a full hot swap onto the builder's index (hot_swap nulls the
        builder reference it knows nothing about; the caller reassigns
        it right after this returns)."""
        self.hot_swap(index=db.index)
        self.build_stats = db.stats

    def _run_batch(self, batch: Batch, tr=None):
        return self.fanout.execute(batch, trace=tr)

    def _explain_admitted(self, s: int, t: int, mr_id: int,
                          max_hubs: int = 8) -> dict:
        """Sharded backend dispatch for one admitted query, with the
        routing hops attached: which shards own ``s``/``t``, whether the
        join ran on one shard or joined a shipped out-row digest against
        the remote in-row, and what that digest weighed. Uses
        :meth:`ShardPlan.shard_of` directly (not the router) so EXPLAIN
        never skews the routing counters."""
        shard_s = self.plan.shard_of(s)
        shard_t = self.plan.shard_of(t)
        route = dict(shard_s=shard_s, shard_t=shard_t, home=shard_t)
        if self.cluster is not None:
            # rpc transport: serving rows live in worker processes, but
            # the controller's global frozen holds byte-identical rows
            # (workers were initialized from its slices) — EXPLAIN joins
            # those without a round-trip, off the routing counters
            from repro.obs.explain import explain_rows
            oh, om = self.frozen.row_out(s)
            ih, im = self.frozen.row_in(t)
            w = explain_rows(oh, om, ih, im, s, t, mr_id,
                             aid=self.frozen.aid, max_hubs=max_hubs)
            if shard_s == shard_t:
                route.update(path="local")
            else:
                route.update(path="remote", digest_entries=int(len(oh)),
                             digest_bytes=int(oh.nbytes + om.nbytes))
            return dict(answer=w["answer"], backend="rpc:frozen",
                        witness=w, route=route)
        if shard_s == shard_t:
            rep = self.shards[shard_s].acquire()
            ws, backend = rep.executor.explain_batch(
                np.array([s]), np.array([t]), np.array([mr_id]),
                max_hubs=max_hubs)
            w = ws[0]
            route.update(path="local")
        else:
            # cross-shard: the serving path ships s's out-row digest to
            # the in-side owner (two-sided routing); the witness joins
            # the exact rows that digest join would see
            from repro.obs.explain import explain_rows
            src = self.shards[shard_s].acquire()
            dst = self.shards[shard_t].acquire()
            oh, om = src.frozen.row_out(s)
            ih, im = dst.frozen.row_in(t)
            w = explain_rows(oh, om, ih, im, s, t, mr_id,
                             aid=src.frozen.aid, max_hubs=max_hubs)
            backend = "digest"
            route.update(path="remote", digest_entries=int(len(oh)),
                         digest_bytes=int(oh.nbytes + om.nbytes))
        return dict(answer=w["answer"], backend=backend, witness=w,
                    route=route)

    # -- incremental graph mutation -------------------------------------- #
    def apply_delta(self, delta) -> dict:
        """Apply a :class:`repro.core.graph.GraphDelta` across the shards.

        The delta is re-derived incrementally once (the in-process global
        build), then routed to its owning shards: only shards whose row
        range intersects the dirty/re-sorted rows swap in fresh slices
        (rolling, replica by replica, under the same atomic-publish
        contract as :meth:`hot_swap`); untouched shards keep their
        replicas and only repoint the always-available python-fallback
        index. Cached answers are evicted only for dirty ``(s, t)`` rows.
        """
        # fence in-flight warm work before any state moves (see
        # RLCService.apply_delta)
        self.ctl.bump_epoch()
        db = self._ensure_delta_builder()
        res = db.apply(delta)
        self.graph = db.graph
        self.fanout.graph = self.graph   # mid-swap BiBFS walks the live graph
        self.index = db.index
        self.build_stats = res.stats
        if res.fallback:
            frozen = self.index.freeze(self.mr_ids)
            refreeze = None           # every shard swaps
        else:
            dirty_out = set(res.dirty_out.tolist())
            dirty_in = set(res.dirty_in.tolist())
            # patch under the *stable* aid every shard already serves
            # with (Algorithm 1 only needs one consistent hub order, and
            # cross-shard digest joins mix row vintages) — so re-sorted
            # mover rows need no re-freeze, only content-dirty rows do
            frozen = self.frozen.patch_rows(
                self.index, self.mr_ids, dirty_out, dirty_in,
                aid=self.frozen.aid)
            refreeze = np.unique(np.concatenate(
                [res.dirty_out, res.dirty_in]))
        self.frozen = frozen
        self.generation += 1
        touched: List[int] = []
        backend_name = f"delta[{self._delta_backend_name()}]"
        if self.cluster is not None:
            # rpc transport: ship fresh slices only to shards whose row
            # range went dirty, worker by worker behind the per-worker
            # fence (each worker rebuilds its dict-index slice from the
            # shipped rows, so there is no global fallback to repoint)
            for sid, (lo, hi) in enumerate(self.plan.ranges()):
                owns_dirty = (refreeze is None or bool(
                    np.searchsorted(refreeze, lo)
                    < np.searchsorted(refreeze, hi)))
                if owns_dirty:
                    self.cluster.swap_shard(sid, self.generation,
                                            frozen.slice_rows(lo, hi))
                    touched.append(sid)
        for rs in self.shards:
            owns_dirty = (refreeze is None or bool(
                np.searchsorted(refreeze, rs.lo)
                < np.searchsorted(refreeze, rs.hi)))
            if owns_dirty:
                rs.swap(self.generation, frozen.slice_rows(rs.lo, rs.hi),
                        self.mr_ids, self.index, self._id_to_mr,
                        backend=self.config.backend,
                        use_device=self.config.use_device,
                        build_backend=backend_name)
                touched.append(rs.shard_id)
            else:
                # rows unchanged: keep the replicas (their slices view
                # identical row content), but the python fallback must
                # see the new dict index
                for replica in rs.replicas:
                    replica.executor.index = self.index
        # invalidate only after every shard serves the new state (see
        # RLCService.apply_delta on the ticker-flush ordering)
        if res.fallback:
            evicted = len(self.cache)
            self.cache.clear()
        else:
            evicted = self.cache.invalidate_rows(dirty_s=dirty_out,
                                                 dirty_t=dirty_in)
        self.deltas_applied += 1
        if self._shadow is not None:
            # pre-delta answers may legitimately differ from the mutated
            # graph's oracle (see RLCService.apply_delta)
            self._shadow.discard_pending()
        warm = self.ctl.warm("apply_delta")
        return dict(delta=res.as_dict(), shards_touched=touched,
                    dirty_out=res.dirty_out.tolist(),
                    dirty_in=res.dirty_in.tolist(),
                    cache_evicted=evicted, generation=self.generation,
                    warm=warm)

    # -- hot swap -------------------------------------------------------- #
    def hot_swap(self, index: Optional[RLCIndex] = None,
                 graph: Optional[LabeledGraph] = None,
                 build_backend: Optional[str] = None) -> int:
        """Atomically replace every shard's frozen/device slice.

        Rebuild the index from ``graph`` (same vertex set — the plan's
        ranges keep their meaning), or adopt a pre-built ``index``, or —
        with neither — re-freeze the current index (a no-op refresh).
        Rebuilds run on ``build_backend`` (default: the configured
        ``config.build_backend``, i.e. a batched builder — the rebuild
        pause stops paying the sequential python path). Shards swap
        rolling, replica by replica; in-flight sub-batches finish on the
        replica object they acquired. The result cache is cleared —
        cached answers may be stale against the new index. Returns the
        new generation number.
        """
        build_backend = build_backend or self.config.build_backend
        # a swap invalidates any in-flight warm pass the same way a delta
        # does — its answers were computed against the outgoing index
        self.ctl.bump_epoch()
        rebuilt = False
        if index is not None:
            # adopted pre-built index: we didn't build it, don't claim to
            self.build_stats = None
        if graph is not None:
            if (graph.num_vertices != self.graph.num_vertices
                    or graph.num_labels != self.graph.num_labels):
                raise ValueError(
                    "hot_swap requires an identical vertex/label space "
                    f"(got V={graph.num_vertices} L={graph.num_labels}, "
                    f"serving V={self.graph.num_vertices} "
                    f"L={self.graph.num_labels})")
            if index is None:
                index, self.build_stats = build_rlc_index_with_stats(
                    graph, self.config.k, backend=build_backend,
                    observer=self.obs.build_observer("swap"))
                rebuilt = True
            self.graph = graph
            self.fanout.graph = graph
        if index is None:
            index = self.index
        if index.k != self.config.k:
            raise ValueError(
                f"index built with k={index.k} but config.k={self.config.k}")
        if index.num_vertices != self.plan.num_vertices:
            raise ValueError(
                f"index has {index.num_vertices} vertices but the shard "
                f"plan covers {self.plan.num_vertices}")
        frozen = index.freeze(self.mr_ids)
        self.generation += 1
        if self.cluster is not None:
            # rolling fenced swap, worker by worker: replica siblings
            # keep serving while one worker installs the new generation
            for sid, (lo, hi) in enumerate(self.plan.ranges()):
                self.cluster.swap_shard(sid, self.generation,
                                        frozen.slice_rows(lo, hi))
        for rs in self.shards:
            sl = frozen.slice_rows(rs.lo, rs.hi)
            rs.swap(self.generation, sl, self.mr_ids, index, self._id_to_mr,
                    backend=self.config.backend,
                    use_device=self.config.use_device,
                    build_backend=build_backend if rebuilt else None)
        self.index = index
        self.frozen = frozen
        self.cache.clear()
        if self._shadow is not None:
            # answers served pre-swap verified against the old state
            self._shadow.discard_pending()
        # a cached DeltaBuilder is pinned to the pre-swap graph/index —
        # drop it so the next apply_delta re-bootstraps from the swapped
        # state instead of silently reverting the swap
        self._delta = None
        # refill the hot Zipf head against the swapped index (the clear
        # above just cold-started the whole cache); no-op when warming
        # is off
        self.ctl.warm("hot_swap")
        return self.generation

    # -- observability --------------------------------------------------- #
    def audit_report(self, sample: int = 128, seed: int = 0) -> dict:
        """Global-index audit plus a per-shard byte/entry breakdown —
        the serving state a sharded stack actually holds is the shard
        slices, so the global report carries one row per shard naming
        its frozen/device allocation and entry count."""
        from repro.obs.audit import (audit_index, bank_audit_metrics,
                                     device_nbytes, frozen_nbytes)
        rep = audit_index(self.frozen, self._id_to_mr, index=self.index,
                          graph=self.graph, device_index=None,
                          sample=sample, seed=seed)
        shards = []
        for rs in self.shards:
            r0 = rs.replicas[0]
            shards.append(dict(
                shard=rs.shard_id, lo=int(rs.lo), hi=int(rs.hi),
                generation=rs.generation,
                replicas=len(rs.replicas),
                entries=int(r0.frozen.num_entries()),
                frozen_bytes=frozen_nbytes(r0.frozen),
                device_bytes=device_nbytes(r0.device_index)))
        rep["shards"] = shards
        dev = sum(s["device_bytes"] or 0 for s in shards)
        rep["bytes"]["device"] = dev if any(
            s["device_bytes"] is not None for s in shards) else None
        self._last_audit = rep
        bank_audit_metrics(self.obs.registry, rep)
        return rep

    def stats(self) -> dict:
        """The ``repro.service.stats/1`` shape plus per-shard breakdowns
        (shared sections built once in :mod:`repro.service.stats`).
        Under ``transport="rpc"`` the ``shards`` list carries one row
        per worker process and ``rpc`` the cluster's membership/wire
        accounting."""
        from ..stats import base_stats
        out = base_stats(self, "sharded", self.config.transport)
        out.update(
            executor=self.fanout.stats(),
            router=self.router.stats(),
            shards=([rs.stats() for rs in self.shards]
                    if self.cluster is None
                    else self.cluster.worker_stats()),
            index=dict(
                entries=self.frozen.num_entries(),
                size_bytes=self.frozen.size_bytes(),
                num_mrs=len(self.mr_ids),
                num_shards=self.plan.num_shards,
                num_replicas=self.config.num_replicas,
                generation=self.generation,
                plan=self.plan.as_dict()),
        )
        if self.cluster is not None:
            out["rpc"] = self.cluster.stats()
        return out
