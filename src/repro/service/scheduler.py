"""Micro-batching scheduler for RLC queries.

Incoming ``(s, t, mr)`` requests accumulate into fixed-size batches so the
batched engines (XLA sorted-key / Pallas dense) amortize dispatch and keep
a single jit specialization per batch shape — the same slot pattern as the
LM serving engine (:mod:`repro.serve.engine`), transplanted to queries.

Buckets are keyed by MR length: all requests in a batch share ``|MR|``, so
Zipf-heavy short constraints don't ride in batches padded for long ones,
and per-bucket arrival rates stay observable. A batch flushes when it is
full (``batch_size`` requests) or when its oldest request has waited
``max_wait_s`` (deadline flush, checked by :meth:`MicroBatcher.poll`).
Underfull deadline flushes are padded by repeating the first request up to
``batch_size`` — always a valid query, and keeping one static batch shape
avoids jit re-tracing (padding answers are sliced off).

The scheduler is clock-driven and synchronous: callers hand it a ``now``
timestamp (or let it read the injected clock), and flushed batches come
back for the caller to execute. That keeps it deterministic under test and
leaves async admission to a later PR (see ROADMAP).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    """One admitted query, already canonicalized to an indexed MR."""

    req_id: int
    s: int
    t: int
    mr_id: int
    mr_len: int
    enqueued_at: float = 0.0


@dataclass
class Batch:
    """A padded, launch-ready batch of same-``|MR|`` requests."""

    requests: List[Request]     # the real requests, in admission order
    s: np.ndarray               # (batch_size,) int32, padded
    t: np.ndarray
    mr_id: np.ndarray
    mr_len: int
    reason: str                 # "full" | "deadline" | "drain"

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def n_padding(self) -> int:
        return len(self.s) - len(self.requests)


class MicroBatcher:
    def __init__(self, batch_size: int, max_wait_s: float = 0.002,
                 clock: Callable[[], float] = time.monotonic):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.clock = clock
        self._buckets: Dict[int, List[Request]] = {}
        self._ids = itertools.count()
        self.batches_full = 0
        self.batches_deadline = 0
        self.batches_drain = 0

    # ------------------------------------------------------------------ #
    def submit(self, s: int, t: int, mr_id: int, mr_len: int,
               now: Optional[float] = None) -> Tuple[Request, List[Batch]]:
        """Admit one request; return it plus any batches now ready (the
        request's own bucket on fill, any bucket past its deadline)."""
        now = self.clock() if now is None else now
        req = Request(next(self._ids), int(s), int(t), int(mr_id),
                      int(mr_len), now)
        bucket = self._buckets.setdefault(mr_len, [])
        bucket.append(req)
        out: List[Batch] = []
        if len(bucket) >= self.batch_size:
            out.append(self._flush_bucket(mr_len, "full"))
        # An admission is also a natural poll point for other buckets.
        out.extend(self.poll(now))
        return req, out

    def poll(self, now: Optional[float] = None) -> List[Batch]:
        """Flush every bucket whose oldest request has hit the deadline."""
        now = self.clock() if now is None else now
        out: List[Batch] = []
        for mr_len in list(self._buckets):
            bucket = self._buckets[mr_len]
            if bucket and now - bucket[0].enqueued_at >= self.max_wait_s:
                out.append(self._flush_bucket(mr_len, "deadline"))
        return out

    def drain(self) -> List[Batch]:
        """Flush everything regardless of fill or age (end of a sync call)."""
        out = [self._flush_bucket(m, "drain") for m in list(self._buckets)
               if self._buckets[m]]
        return out

    def pending(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    # ------------------------------------------------------------------ #
    def _flush_bucket(self, mr_len: int, reason: str) -> Batch:
        bucket = self._buckets[mr_len]
        reqs, rest = bucket[:self.batch_size], bucket[self.batch_size:]
        self._buckets[mr_len] = rest
        if reason == "full":
            self.batches_full += 1
        elif reason == "deadline":
            self.batches_deadline += 1
        else:
            self.batches_drain += 1
        B = self.batch_size
        s = np.empty(B, np.int32)
        t = np.empty(B, np.int32)
        mr = np.empty(B, np.int32)
        for i in range(B):
            r = reqs[min(i, len(reqs) - 1)]  # pad by repeating the first/last
            s[i], t[i], mr[i] = r.s, r.t, r.mr_id
        return Batch(reqs, s, t, mr, mr_len, reason)
