"""Micro-batching scheduler for RLC queries.

Incoming ``(s, t, mr)`` requests accumulate into fixed-size batches so the
batched engines (XLA sorted-key / Pallas dense) amortize dispatch and keep
a single jit specialization per batch shape — the same slot pattern as the
LM serving engine (:mod:`repro.serve.engine`), transplanted to queries.

Buckets are keyed by MR length: all requests in a batch share ``|MR|``, so
Zipf-heavy short constraints don't ride in batches padded for long ones,
and per-bucket arrival rates stay observable. A batch flushes when it is
full (``batch_size`` requests) or when its oldest request has waited
``max_wait_s`` (deadline flush, checked by :meth:`MicroBatcher.poll`).
Both limits are per-bucket overridable via ``params_fn`` — the hook the
SLO batch controller (:mod:`repro.service.control`) uses to size batches
and deadlines per MR length from observed queue-wait/compute costs.

Flushed batches carry exactly their real requests — underfull deadline
flushes are *not* padded to ``batch_size`` (repeating the first request
used to burn executor slots on every deadline flush; the executor now
pads to a power-of-two internally for the jit backends, which bounds the
number of compiled shapes without recomputing duplicate slots). The
``rlc_batcher_padding_ratio`` histogram records padded/total slots per
flush so the waste stays provably gone.

Duplicate in-flight keys are *coalesced*: submitting a ``(s, t, mr_id)``
already queued returns the queued :class:`Request` instead of occupying a
second batch slot — the caller fans the single answer out to every
submitter (see ``RLCService.query_batch``'s slot map). Under a Zipf
workload most duplicates are absorbed by the result cache, but duplicates
*within one in-flight window* only exist here, before any answer is
cached.

The scheduler is clock-driven and synchronous by default: callers hand it
a ``now`` timestamp (or let it read the injected clock), and flushed
batches come back for the caller to execute. An optional background
*deadline ticker* (:meth:`MicroBatcher.start_ticker`, off by default) adds
the first step toward async admission: a daemon thread polls for deadline
flushes so an underfull bucket drains even when no new admission ever
arrives to piggyback the poll on. All mutating entry points take the
internal lock, so ticker flushes and caller admissions interleave safely.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import NULL_OBS


@dataclass(frozen=True)
class Request:
    """One admitted query, already canonicalized to an indexed MR."""

    req_id: int
    s: int
    t: int
    mr_id: int
    mr_len: int
    enqueued_at: float = 0.0

    @property
    def key(self) -> Tuple[int, int, int]:
        return (self.s, self.t, self.mr_id)


@dataclass
class Batch:
    """A launch-ready batch of same-``|MR|`` requests (real slots only)."""

    requests: List[Request]     # the real requests, in admission order
    s: np.ndarray               # (n_real,) int32 — no padding slots
    t: np.ndarray
    mr_id: np.ndarray
    mr_len: int
    reason: str                 # "full" | "deadline" | "drain"
    flushed_at: float = 0.0     # scheduler-clock flush time (queue-wait
                                # spans: flushed_at - request.enqueued_at)

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def n_padding(self) -> int:
        return len(self.s) - len(self.requests)


class MicroBatcher:
    def __init__(self, batch_size: int, max_wait_s: float = 0.002,
                 clock: Callable[[], float] = time.monotonic, obs=None,
                 params_fn: Optional[
                     Callable[[int], Tuple[int, float]]] = None):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        #: optional per-MR-length override: ``mr_len -> (batch_size,
        #: max_wait_s)`` — the SLO controller's entry point; ``None``
        #: keeps the fixed constructor values for every bucket
        self.params_fn = params_fn
        self.clock = clock
        self._buckets: Dict[int, List[Request]] = {}
        self._inflight: Dict[Tuple[int, int, int], Request] = {}
        self._ids = itertools.count()
        self._lock = threading.RLock()
        self._ticker: Optional[threading.Thread] = None
        self._ticker_stop = threading.Event()
        self.batches_full = 0
        self.batches_deadline = 0
        self.batches_drain = 0
        self.coalesced = 0
        self.ticker_errors = 0
        # registry cells: per-request queue wait (admission -> flush) and
        # per-batch flush reason — the always-on half of the queue-wait
        # vs compute decomposition (spans are the sampled half)
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        wait = reg.histogram(
            "rlc_batcher_queue_wait_seconds",
            desc="per-request wait from admission to batch flush",
            unit="s", labelnames=("reason",))
        flush = reg.counter("rlc_batcher_batches",
                            desc="flushed batches by reason",
                            labelnames=("reason",))
        self._m_wait = {r: wait.labels(reason=r)
                        for r in ("full", "deadline", "drain")}
        self._m_flush = {r: flush.labels(reason=r)
                         for r in ("full", "deadline", "drain")}
        self._m_coalesced = reg.counter(
            "rlc_batcher_coalesced",
            desc="duplicate in-flight requests coalesced").labels()
        fill = reg.histogram(
            "rlc_batcher_batch_fill",
            desc="real requests per flushed batch", unit="1",
            labelnames=("reason",))
        self._m_fill = {r: fill.labels(reason=r)
                        for r in ("full", "deadline", "drain")}
        self._m_padding = reg.histogram(
            "rlc_batcher_padding_ratio",
            desc="padded slots / total slots per flushed batch "
                 "(0 since underfull flushes stopped padding)",
            unit="1").labels()
        self._m_evicted = reg.counter(
            "rlc_batcher_evicted",
            desc="queued requests evicted pre-flush by admission "
                 "control").labels()

    # ------------------------------------------------------------------ #
    def params(self, mr_len: int) -> Tuple[int, float]:
        """Effective ``(batch_size, max_wait_s)`` for one bucket."""
        if self.params_fn is None:
            return self.batch_size, self.max_wait_s
        return self.params_fn(mr_len)

    # ------------------------------------------------------------------ #
    def submit(self, s: int, t: int, mr_id: int, mr_len: int,
               now: Optional[float] = None) -> Tuple[Request, List[Batch]]:
        """Admit one request; return it plus any batches now ready (the
        request's own bucket on fill, any bucket past its deadline).

        A duplicate of an in-flight ``(s, t, mr_id)`` is coalesced: the
        already-queued request comes back (compare ``req_id``) and no new
        batch slot is taken — the caller must fan the answer out to every
        position that mapped onto that request.
        """
        with self._lock:
            now = self.clock() if now is None else now
            key = (int(s), int(t), int(mr_id))
            existing = self._inflight.get(key)
            if existing is not None:
                self.coalesced += 1
                self._m_coalesced.inc()
                # still a natural poll point for every bucket's deadline
                return existing, self.poll(now)
            req = Request(next(self._ids), key[0], key[1], key[2],
                          int(mr_len), now)
            bucket = self._buckets.setdefault(mr_len, [])
            bucket.append(req)
            self._inflight[key] = req
            out: List[Batch] = []
            cap, _wait = self.params(mr_len)
            if len(bucket) >= cap:
                out.append(self._flush_bucket(mr_len, "full"))
            # An admission is also a natural poll point for other buckets.
            out.extend(self.poll(now))
            return req, out

    def poll(self, now: Optional[float] = None) -> List[Batch]:
        """Flush every bucket whose oldest request has hit the deadline."""
        with self._lock:
            now = self.clock() if now is None else now
            out: List[Batch] = []
            for mr_len in list(self._buckets):
                bucket = self._buckets[mr_len]
                if not bucket:
                    continue
                _cap, wait = self.params(mr_len)
                if now - bucket[0].enqueued_at >= wait:
                    out.append(self._flush_bucket(mr_len, "deadline"))
            return out

    def drain(self) -> List[Batch]:
        """Flush everything regardless of fill or age (end of a sync call)."""
        with self._lock:
            return [self._flush_bucket(m, "drain")
                    for m in list(self._buckets) if self._buckets[m]]

    def pending(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._buckets.values())

    def evict(self, req: Request) -> bool:
        """Remove one still-queued request before it flushes (admission
        control sheds it in favor of a higher-priority arrival). Returns
        ``False`` when the request already flushed or was coalesced away
        — the caller must then answer it normally."""
        with self._lock:
            bucket = self._buckets.get(req.mr_len)
            if not bucket:
                return False
            for i, r in enumerate(bucket):
                if r.req_id == req.req_id:
                    del bucket[i]
                    self._inflight.pop(r.key, None)
                    self._m_evicted.inc()
                    return True
            return False

    def lowest_priority_pending(
            self, score_fn: Callable[[Request], float]
    ) -> Optional[Request]:
        """The queued request minimizing ``score_fn`` (admission control's
        eviction victim scan), or ``None`` when nothing is queued."""
        with self._lock:
            worst: Optional[Request] = None
            worst_score = float("inf")
            for bucket in self._buckets.values():
                for r in bucket:
                    sc = score_fn(r)
                    if sc < worst_score:
                        worst, worst_score = r, sc
            return worst

    def median_pending_priority(
            self, score_fn: Callable[[Request], float]
    ) -> Optional[float]:
        """Lower-median ``score_fn`` over queued requests (the
        back-pressure shed threshold — lower, so that in a uniform-
        priority queue arrivals at that priority still shed), or
        ``None`` when the queue is empty."""
        with self._lock:
            scores = sorted(score_fn(r) for bucket in self._buckets.values()
                            for r in bucket)
            if not scores:
                return None
            return scores[(len(scores) - 1) // 2]

    def is_inflight(self, key: Tuple[int, int, int]) -> bool:
        """Whether ``(s, t, mr_id)`` is queued awaiting a flush — i.e. a
        duplicate submitted now would coalesce. Read-only (EXPLAIN's
        coalescing disposition; never takes a batch slot)."""
        with self._lock:
            return tuple(int(x) for x in key) in self._inflight

    # -- background deadline ticker ------------------------------------- #
    def start_ticker(self, on_batch: Callable[[Batch], None],
                     interval_s: Optional[float] = None,
                     on_error: Optional[
                         Callable[[BaseException], None]] = None) -> None:
        """Start a daemon thread that fires deadline flushes on its own.

        Without a ticker, ``max_wait_s`` is only honored when some caller
        happens to submit or poll; with it, an underfull bucket flushes at
        most ~``interval_s`` after its deadline even if no admission ever
        arrives again. ``on_batch`` runs on the ticker thread for every
        flushed batch (execute + backfill caches there). Off by default.

        ``on_error`` (optional) is invoked with the exception when
        ``on_batch`` raises — async callers use it to fail pending
        futures instead of silently counting the error; without it (or
        if it raises itself) the failure just lands in
        ``ticker_errors``. The ticker survives either way.
        """
        if interval_s is None:
            interval_s = max(self.max_wait_s / 4.0, 1e-4)

        def loop():
            while not self._ticker_stop.wait(interval_s):
                for batch in self.poll():
                    try:
                        on_batch(batch)
                    except Exception as exc:
                        # a failing callback must not kill the ticker —
                        # later deadline flushes still have to fire
                        self.ticker_errors += 1
                        if on_error is not None:
                            try:
                                on_error(exc)
                            except Exception:
                                self.ticker_errors += 1

        with self._lock:
            if self._ticker is not None:
                raise RuntimeError("ticker already running")
            self._ticker_stop.clear()
            self._ticker = threading.Thread(
                target=loop, name="microbatcher-ticker", daemon=True)
            self._ticker.start()

    def stop_ticker(self) -> None:
        """Stop the ticker thread (no-op when not running)."""
        with self._lock:
            ticker, self._ticker = self._ticker, None
            if ticker is None:
                return
            self._ticker_stop.set()
        # join outside the lock: the ticker's poll() needs it to finish
        ticker.join()

    @property
    def ticker_running(self) -> bool:
        return self._ticker is not None

    # ------------------------------------------------------------------ #
    def _flush_bucket(self, mr_len: int, reason: str) -> Batch:
        bucket = self._buckets[mr_len]
        cap, _wait = self.params(mr_len)
        reqs, rest = bucket[:cap], bucket[cap:]
        self._buckets[mr_len] = rest
        for r in reqs:
            self._inflight.pop(r.key, None)
        if reason == "full":
            self.batches_full += 1
        elif reason == "deadline":
            self.batches_deadline += 1
        else:
            self.batches_drain += 1
        now = self.clock()
        self._m_flush[reason].inc()
        self._m_fill[reason].observe(len(reqs))
        wait_cell = self._m_wait[reason]
        for r in reqs:
            wait_cell.observe(now - r.enqueued_at)
        # real slots only — the executor pads jit backends internally
        self._m_padding.observe(0.0)
        n = len(reqs)
        s = np.fromiter((r.s for r in reqs), np.int32, n)
        t = np.fromiter((r.t for r in reqs), np.int32, n)
        mr = np.fromiter((r.mr_id for r in reqs), np.int32, n)
        return Batch(reqs, s, t, mr, mr_len, reason, flushed_at=now)
