"""Async admission: ``submit()`` futures + the unified service
lifecycle.

:class:`AsyncEngine` turns a synchronous :class:`RLCService` /
``ShardedRLCService`` into a non-blocking one. ``submit(s, t,
constraint)`` runs *admission only* on the caller's thread — parse,
cache probe, admission-control decision, micro-batch enqueue — and
returns a :class:`concurrent.futures.Future` that resolves to a typed
:class:`~repro.service.answer.Answer`. Batch *execution* happens on the
engine's worker thread, fed by the scheduler's deadline ticker and by
full batches handed over at submit time — so admission of query *i+1*
overlaps execution of query *i*'s batch, which is the point.

Correctness notes (the races this design closes):

* Waiter registration and future resolution both happen under one
  engine lock, and a submitter registers its future *before* releasing
  it — a ticker-flushed batch picked up by the worker thread blocks on
  that lock, so a future can never miss its answer.
* Duplicate in-flight keys coalesce in the scheduler exactly like the
  sync path: every coalesced submitter's future hangs off the same
  ``req_id`` and resolves from the single execution.
* Admission-control evictions resolve the victim's futures with
  :data:`SHED` (never a fabricated boolean), same as ``query_batch``.
* An execution failure resolves every future of the failed batch with
  the exception (``Future.set_exception``); later submits still work.

The engine also keeps the overlap ledger the benches report: wall time
spent admitting vs executing and how much of the execution happened
*while* admission was still going (``stats()["overlap_s"]``) — the
observable proof that ``submit()`` is actually asynchronous.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional

from .answer import SHED, Answer

__all__ = ["AsyncEngine"]

_CLOSE = object()       # worker-thread shutdown sentinel


class AsyncEngine:
    def __init__(self, svc, tick_interval_s: float = 0.002):
        self.svc = svc
        self.tick_interval_s = float(tick_interval_s)
        self._lock = threading.RLock()
        #: req_id -> futures awaiting that request (coalesced submits
        #: share one req_id)
        self._waiters: Dict[int, List[Future]] = {}
        self._queue: "queue.Queue" = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self.active = False
        # counters + the admission/execution overlap ledger
        self.submitted = 0
        self.completed = 0
        self.cache_hits = 0
        self.shed = 0
        self.failed_batches = 0
        self.exec_batches = 0
        self.exec_s = 0.0
        self.admit_s = 0.0
        self.overlap_s = 0.0
        self._first_submit: Optional[float] = None
        self._last_submit: Optional[float] = None
        reg = svc.obs.registry
        self._m_inflight = reg.gauge(
            "rlc_async_inflight", desc="futures awaiting resolution")
        self._m_submit = reg.counter(
            "rlc_async_submits", desc="async submissions by outcome",
            labelnames=("outcome",))

    # -- lifecycle ------------------------------------------------------- #
    def start(self) -> None:
        if self.active:
            return
        self.active = True
        self._thread = threading.Thread(
            target=self._serve, name="rlc-async-exec", daemon=True)
        self._thread.start()
        # deadline flushes land in the execution queue; ticker errors
        # must surface on futures, not die in a counter
        self.svc.batcher.start_ticker(self._queue.put,
                                      self.tick_interval_s,
                                      on_error=self._on_ticker_error)

    def close(self) -> None:
        """Drain everything admitted so far, resolve its futures, stop
        the threads. Idempotent."""
        if not self.active:
            return
        self.active = False
        self.svc.batcher.stop_ticker()
        with self._lock:
            for batch in self.svc.batcher.drain():
                self._queue.put(batch)
        self._queue.put(_CLOSE)
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None

    def flush(self) -> None:
        """Force-flush the scheduler and block until every batch queued
        so far has executed (the sync-bridge for ``query_batch``)."""
        with self._lock:
            for batch in self.svc.batcher.drain():
                self._queue.put(batch)
        self._queue.join()

    # -- admission (caller thread) --------------------------------------- #
    def submit(self, s: int, t: int, constraint,
               now: Optional[float] = None) -> Future:
        """Non-blocking admission; the returned future resolves to an
        :class:`Answer` (or :data:`SHED`). Malformed queries raise here,
        synchronously — an argument error is the caller's bug, not a
        deferred execution outcome."""
        svc = self.svc
        t0 = time.perf_counter()
        fut: Future = Future()
        with self._lock:
            s, t, mr_id, mr_len = svc._admit(s, t, constraint)
            key = (s, t, mr_id)
            self.submitted += 1
            self._first_submit = self._first_submit or t0
            svc.queries_served += 1
            svc.ctl.observe_admit(key, mr_len)
            hit = svc.cache.get(key, mr_len=mr_len)
            if hit is not None:
                self.cache_hits += 1
                self._m_submit.labels(outcome="cache_hit").inc()
                fut.set_result(Answer(hit, "cache_hit"))
                return fut
            admission = svc.ctl.admission
            if admission is not None:
                decision, victim = admission.decide(key, mr_len,
                                                    svc.batcher)
                if decision == "shed":
                    self._shed_future(fut)
                    return fut
                if decision == "evict" and svc.batcher.evict(victim):
                    for vf in self._waiters.pop(victim.req_id, ()):
                        self._shed_future(vf)
            req, ready = svc.batcher.submit(s, t, mr_id, mr_len, now)
            self._waiters.setdefault(req.req_id, []).append(fut)
            self._m_inflight.set(sum(len(v)
                                     for v in self._waiters.values()))
            self._m_submit.labels(outcome="queued").inc()
            for batch in ready:
                self._queue.put(batch)
            self._last_submit = time.perf_counter()
            self.admit_s += self._last_submit - t0
        return fut

    def _shed_future(self, fut: Future) -> None:
        self.shed += 1
        self.svc.queries_shed += 1
        self._m_submit.labels(outcome="shed").inc()
        fut.set_result(SHED)

    # -- execution (engine thread) ---------------------------------------- #
    def _serve(self) -> None:
        while True:
            batch = self._queue.get()
            try:
                if batch is _CLOSE:
                    return
                self._execute(batch)
            finally:
                self._queue.task_done()

    def _on_ticker_error(self, exc: BaseException) -> None:
        """A deadline flush blew up inside the scheduler ticker: fail
        every pending future rather than hang their callers."""
        with self._lock:
            waiters, self._waiters = self._waiters, {}
        for futures in waiters.values():
            for fut in futures:
                if not fut.done():
                    fut.set_exception(exc)

    def _execute(self, batch) -> None:
        svc = self.svc
        t0 = time.perf_counter()
        try:
            vals, backends = svc._run_batch(batch)
        except Exception as exc:    # noqa: BLE001 — delivered to futures
            self.failed_batches += 1
            with self._lock:
                for req in batch.requests:
                    for fut in self._waiters.pop(req.req_id, ()):
                        if not fut.done():
                            fut.set_exception(exc)
            return
        t1 = time.perf_counter()
        svc.ctl.on_batch_executed(batch, t1 - t0)
        with self._lock:
            self.exec_batches += 1
            self.exec_s += t1 - t0
            if self._first_submit is not None:
                # execution time spent while admission was still running
                # = the overlap submit() buys over the sync path
                lo = max(t0, self._first_submit)
                hi = min(t1, self._last_submit or t1)
                self.overlap_s += max(hi - lo, 0.0)
            for req, val, backend in zip(batch.requests, vals, backends):
                val = bool(val)
                svc.cache.put((req.s, req.t, req.mr_id), val,
                              mr_len=batch.mr_len)
                ans = Answer(
                    val,
                    "degraded" if backend == "bibfs" else "computed",
                    backend)
                futures = self._waiters.pop(req.req_id, ())
                for fut in futures:
                    if not fut.done():
                        fut.set_result(ans)
                self.completed += len(futures)
                if svc._shadow is not None:
                    svc._shadow.offer(req.s, req.t, req.mr_id, val)
            self._m_inflight.set(sum(len(v)
                                     for v in self._waiters.values()))

    # -- introspection ---------------------------------------------------- #
    def stats(self) -> dict:
        with self._lock:
            inflight = sum(len(v) for v in self._waiters.values())
            return dict(
                active=self.active,
                submitted=self.submitted,
                completed=self.completed,
                cache_hits=self.cache_hits,
                shed=self.shed,
                inflight=inflight,
                exec_batches=self.exec_batches,
                failed_batches=self.failed_batches,
                admit_s=round(self.admit_s, 6),
                exec_s=round(self.exec_s, 6),
                overlap_s=round(self.overlap_s, 6),
            )
