"""Latency / throughput accounting for the serving path."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class LatencyRecorder:
    """Per-backend wall-clock samples with percentile summaries.

    One sample = one executed batch; ``queries`` tracks the real (unpadded)
    queries answered so throughput reflects useful work.
    """

    def __init__(self, name: str):
        self.name = name
        self.samples_s: List[float] = []
        self.queries = 0
        self.batches = 0

    def record(self, seconds: float, n_queries: int) -> None:
        self.samples_s.append(float(seconds))
        self.queries += int(n_queries)
        self.batches += 1

    @property
    def total_s(self) -> float:
        return float(sum(self.samples_s))

    def percentile(self, p: float) -> float:
        """p in [0, 100]; seconds per batch. 0.0 when empty."""
        if not self.samples_s:
            return 0.0
        return float(np.percentile(np.asarray(self.samples_s), p))

    @property
    def qps(self) -> float:
        return self.queries / self.total_s if self.total_s > 0 else 0.0

    def summary(self) -> Dict[str, float]:
        return dict(
            batches=self.batches,
            queries=self.queries,
            total_s=self.total_s,
            p50_ms=self.percentile(50) * 1e3,
            p99_ms=self.percentile(99) * 1e3,
            qps=self.qps,
        )
