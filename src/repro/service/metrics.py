"""Latency / throughput accounting for the serving path.

Backed by :class:`repro.obs.metrics.Reservoir` since the telemetry PR:
the recorder previously kept *every* batch sample in a grow-forever
python list (``samples_s``), a memory leak under sustained traffic — a
service doing 1k batches/s leaked ~30 MB/hour per backend. Percentiles
are exact below the reservoir cap and reservoir-sampled estimates above
it; ``queries`` / ``batches`` / ``total_s`` stay exact forever. The
``summary()`` keys are unchanged (backward-compatible with every bench
artifact and stats consumer).
"""
from __future__ import annotations

from typing import Dict, List

from repro.obs.metrics import Reservoir

#: per-recorder sample bound: exact percentiles below, reservoir above.
DEFAULT_SAMPLE_CAP = 4096


class LatencyRecorder:
    """Per-backend wall-clock samples with percentile summaries.

    One sample = one executed batch; ``queries`` tracks the real
    (unpadded) queries answered so throughput reflects useful work.
    Memory is bounded by ``sample_cap``.
    """

    def __init__(self, name: str, sample_cap: int = DEFAULT_SAMPLE_CAP):
        self.name = name
        self._reservoir = Reservoir(sample_cap)
        self.queries = 0
        self.batches = 0

    def record(self, seconds: float, n_queries: int) -> None:
        self._reservoir.add(float(seconds))
        self.queries += int(n_queries)
        self.batches += 1

    @property
    def samples_s(self) -> List[float]:
        """The *stored* samples (bounded; all of them while under the
        cap). Kept for callers that eyeball distributions."""
        return list(self._reservoir.samples)

    @property
    def total_s(self) -> float:
        return self._reservoir.total

    def percentile(self, p: float) -> float:
        """p in [0, 100]; seconds per batch. 0.0 when empty. Exact while
        ``batches <= sample_cap``, a reservoir estimate beyond."""
        return self._reservoir.percentile(p)

    @property
    def qps(self) -> float:
        return self.queries / self.total_s if self.total_s > 0 else 0.0

    def summary(self) -> Dict[str, float]:
        return dict(
            batches=self.batches,
            queries=self.queries,
            total_s=self.total_s,
            p50_ms=self.percentile(50) * 1e3,
            p99_ms=self.percentile(99) * 1e3,
            qps=self.qps,
        )
