"""The :class:`RLCService` facade: build -> freeze -> device -> serve.

Wires the whole serving path together::

    g = erdos_renyi(500, 4.0, 4)
    svc = RLCService.build(g, ServiceConfig(k=2, batch_size=16))
    svc.query(3, 17, "(0 1)+")                  # single, through the cache
    svc.query_batch([(s, t, "(a b)+"), ...])    # micro-batched

Admission: each query's constraint is parsed/validated/canonicalized to a
minimum repeat (:mod:`repro.service.expr`), checked against the result
cache, and — on miss — handed to the micro-batcher. Flushed batches run on
the executor (device backend with python fallback); answers backfill the
cache. ``query_batch`` is synchronous: it drains the scheduler before
returning, so every admitted query is answered in admission order.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.build import BuildStats, build_rlc_index_with_stats
from repro.core.graph import LabeledGraph
from repro.core.minimum_repeat import LabelSeq, mr_id_space
from repro.core.rlc_index import RLCIndex
from repro.obs import Observability

from .answer import SHED, Answer
from .cache import ResultCache
from .control import ControlPlane
from .executor import BatchExecutor
from .expr import PathExpression, canonicalize, parse_expression
from .scheduler import Batch, MicroBatcher, Request

Constraint = Union[str, Sequence[int], PathExpression]
Query = Tuple[int, int, Constraint]


@dataclass
class ServiceConfig:
    k: int = 2
    batch_size: int = 32
    max_wait_ms: float = 2.0
    cache_capacity: int = 4096
    cache_ttl_s: Optional[float] = None   # optional TTL on cached answers
    backend: str = "auto"           # "auto" | "pallas" | "sorted" | "numpy" | "python"
    build_backend: str = "auto"     # repro.build backend for (re)builds
    use_device: bool = True         # build the padded DeviceIndex layout
    label_names: Optional[Dict[str, int]] = None  # e.g. {"knows": 0, ...}
    #: incremental-build budget for apply_delta (see DeltaBuilder);
    #: 1.0 disables the full-rebuild fallback
    delta_fallback_frac: float = 0.25
    #: metrics registry on/off (counters and histograms, default-on —
    #: cheap). Off replaces every cell with the null registry.
    telemetry: bool = True
    #: fraction of query_batch calls that record spans (0 = tracing off)
    trace_sample_rate: float = 0.0
    #: span buffer bound; past it spans are dropped and counted
    trace_max_events: int = 50_000
    #: fraction of answered queries re-executed against the BiBFS oracle
    #: by the shadow verifier (0 = shadow verification off)
    shadow_sample_rate: float = 0.0
    #: shadow queue bound; past it the oldest pending check is dropped
    shadow_max_pending: int = 1024
    #: run shadow checks on a background thread (else they run when
    #: drained explicitly or at snapshot time)
    shadow_background: bool = False
    # -- control plane (repro.service.control) --------------------------- #
    #: per-query p99 latency SLO; setting it turns on the SLO batch
    #: controller (per-MR-length batch sizes + deadlines replace the
    #: fixed batch_size/max_wait_ms above)
    target_p99_ms: Optional[float] = None
    #: minimum time between controller parameter recomputations
    control_interval_s: float = 0.05
    #: ceiling for controller-grown batch sizes (None -> 4 * batch_size)
    max_batch_size: Optional[int] = None
    #: hard admission bound: scheduler pending depth past which arrivals
    #: are shed (or evict a lower-priority queued request); None = off
    admission_max_pending: Optional[int] = None
    #: soft back-pressure: shed low-priority arrivals while the EWMA
    #: queue wait exceeds this (None -> 2 * target_p99_ms when the SLO
    #: controller is on, else off)
    admission_backpressure_ms: Optional[float] = None
    #: hot-key candidates tracked for warming; > 0 turns the prioritized
    #: cache warmer on (it runs after apply_delta / hot_swap)
    warm_capacity: int = 0
    #: warming budgets: estimated cache bytes written / wall seconds
    warm_budget_bytes: int = 1 << 20
    warm_budget_s: float = 0.25
    #: injectable scheduler clock (e.g. control.VirtualClock for open-loop
    #: overload replay); None = time.monotonic
    clock: Optional[Callable[[], float]] = None


class RLCService:
    def __init__(self, graph: LabeledGraph, index: RLCIndex,
                 config: ServiceConfig,
                 build_stats: Optional[BuildStats] = None,
                 obs: Optional[Observability] = None):
        self.graph = graph
        self.index = index
        self.config = config
        self.build_stats = build_stats   # None when the index was adopted
        # one telemetry context for the whole stack (passed in by build()
        # so offline build phases land in the same registry)
        self.obs = obs or Observability(
            enabled=config.telemetry,
            trace_sample_rate=config.trace_sample_rate,
            max_trace_events=config.trace_max_events)
        self.mr_ids = mr_id_space(graph.num_labels, config.k)
        self._id_to_mr: List[LabelSeq] = [
            mr for mr, _ in sorted(self.mr_ids.items(), key=lambda kv: kv[1])]
        self.frozen = index.freeze(self.mr_ids)
        self.device_index = None
        if config.use_device:
            try:
                from repro.core.device_index import DeviceIndex
                self.device_index = DeviceIndex.from_frozen(
                    self.frozen, self.mr_ids)
            except Exception:   # no jax / no device: CPU-only degraded mode
                self.device_index = None
        self.executor = BatchExecutor(
            index, self.frozen, self.device_index, self._id_to_mr,
            backend=config.backend, obs=self.obs)
        self.cache = ResultCache(config.cache_capacity,
                                 ttl_s=config.cache_ttl_s, obs=self.obs)
        clock = config.clock if config.clock is not None else time.monotonic
        self.ctl = ControlPlane.from_config(
            config, self.obs, self.cache, self._warm_execute, clock)
        self.batcher = MicroBatcher(
            config.batch_size, config.max_wait_ms * 1e-3,
            clock=clock, obs=self.obs,
            params_fn=(self.ctl.slo.params
                       if self.ctl.slo is not None else None))
        self.queries_served = 0
        self.queries_shed = 0
        self.deltas_applied = 0
        self._delta = None          # lazy DeltaBuilder (apply_delta)
        self._engine = None         # lazy AsyncEngine (start()/submit())
        self._closed = False
        self._last_audit = None     # most recent audit_report() document
        self._m_explain = self.obs.registry.counter(
            "rlc_explain_requests",
            desc="EXPLAIN bundles produced, by witness kind",
            labelnames=("kind",))
        from repro.obs.shadow import attach_shadow
        self._shadow = attach_shadow(self)

    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, graph: LabeledGraph,
              config: Optional[ServiceConfig] = None,
              index: Optional[RLCIndex] = None) -> "RLCService":
        """Build (or adopt) the RLC index for ``graph`` and start serving.
        Builds go through the configured :mod:`repro.build` backend."""
        config = config or ServiceConfig()
        obs = Observability(enabled=config.telemetry,
                            trace_sample_rate=config.trace_sample_rate,
                            max_trace_events=config.trace_max_events)
        build_stats = None
        if index is None:
            index, build_stats = build_rlc_index_with_stats(
                graph, config.k, backend=config.build_backend,
                observer=obs.build_observer())
        elif index.k != config.k:
            raise ValueError(
                f"index built with k={index.k} but config.k={config.k}")
        return cls(graph, index, config, build_stats=build_stats, obs=obs)

    # -- admission ------------------------------------------------------ #
    def parse(self, constraint: Constraint) -> PathExpression:
        if isinstance(constraint, PathExpression):
            return constraint
        if isinstance(constraint, str):
            return parse_expression(
                constraint, num_labels=self.graph.num_labels,
                k=self.config.k, label_names=self.config.label_names)
        return canonicalize(constraint, num_labels=self.graph.num_labels,
                            k=self.config.k)

    def _admit(self, s: int, t: int, constraint: Constraint
               ) -> Tuple[int, int, int, int]:
        n = self.graph.num_vertices
        s, t = int(s), int(t)
        if not (0 <= s < n and 0 <= t < n):
            raise ValueError(
                f"vertex ids ({s}, {t}) out of range [0, {n})")
        expr = self.parse(constraint)
        return s, t, self.mr_ids[expr.mr], len(expr.mr)

    # -- serving -------------------------------------------------------- #
    def query(self, s: int, t: int, constraint: Constraint) -> Answer:
        """Synchronous single query (cache -> batch-of-one on miss).
        Returns a typed :class:`Answer` — truthy/comparable exactly like
        the bool it wraps, plus disposition + backend attribution."""
        return self.query_batch([(s, t, constraint)])[0]

    def query_batch(self, queries: Sequence[Query],
                    now: Optional[float] = None) -> List[Answer]:
        """Answer ``queries`` in order through cache + scheduler + executor.

        Each answer is a typed :class:`Answer` (value + disposition +
        backend attribution); ``bool(ans)`` / ``ans == True`` behave
        like the bare boolean this method used to return.

        ``now``: optional admission timestamp (for replaying a timed
        arrival trace); defaults to the scheduler's clock per admission.

        With admission control on (``admission_max_pending`` /
        ``admission_backpressure_ms``), a dropped query's answer is the
        :data:`SHED` sentinel — never a fabricated boolean; check
        ``ans is SHED`` or ``ans.shed`` (SHED raises on ``bool()``).
        Eviction of queued victims assumes the synchronous single-caller
        contract this method already requires (see the lost-answer guard
        below): a victim admitted by a concurrent call would trip that
        guard there.

        When the async engine is running (:meth:`start`), the scheduler
        is ticker-driven and shared with :meth:`submit` callers, so this
        method bridges through the engine instead of draining the
        batcher itself — same answers, no lost-flush race.
        """
        if self._engine is not None and self._engine.active:
            futures = [self.submit(s, t, c) for (s, t, c) in queries]
            self._engine.flush()
            return [f.result(timeout=60.0) for f in futures]
        answers: List[Optional[Answer]] = [None] * len(queries)
        # canonical (s, t, mr_id) per position, kept only when the shadow
        # verifier wants to sample answered queries afterwards
        keys: Optional[List[Tuple[int, int, int]]] = (
            [None] * len(queries) if self._shadow is not None else None)
        # scheduler req_id -> output positions (> 1 when duplicate in-flight
        # queries were coalesced onto one request)
        slot: Dict[int, List[int]] = {}
        # one sampled trace per query_batch call; None on the unsampled
        # hot path, so every span below is a single comparison away
        tr = self.obs.tracer.maybe_trace()
        admission = self.ctl.admission
        for i, (s, t, constraint) in enumerate(queries):
            t0 = tr.tracer._now() if tr is not None else 0.0
            s, t, mr_id, mr_len = self._admit(s, t, constraint)
            if keys is not None:
                keys[i] = (s, t, mr_id)
            # the frequency sketch counts every arrival (hits included):
            # key popularity is a property of the workload, not of the
            # cache's current contents
            self.ctl.observe_admit((s, t, mr_id), mr_len)
            hit = self.cache.get((s, t, mr_id), mr_len=mr_len)
            if tr is not None:
                tr.add(f"admit[{i}]", t0, tr.tracer._now() - t0,
                       cat="admission", mr_len=mr_len,
                       cache="hit" if hit is not None else "miss")
            if hit is not None:
                answers[i] = Answer(hit, "cache_hit")
                continue
            if admission is not None:
                decision, victim = admission.decide(
                    (s, t, mr_id), mr_len, self.batcher)
                if decision == "shed":
                    answers[i] = SHED
                    continue
                if decision == "evict" and self.batcher.evict(victim):
                    # the victim's submitters get the explicit SHED
                    for pos in slot.pop(victim.req_id, ()):
                        answers[pos] = SHED
            req, ready = self.batcher.submit(s, t, mr_id, mr_len, now)
            slot.setdefault(req.req_id, []).append(i)
            for batch in ready:
                self._execute(batch, answers, slot, tr)
        for batch in self.batcher.drain():
            self._execute(batch, answers, slot, tr)
        if any(a is None for a in answers):
            # a batch was flushed outside this call (ticker thread or a
            # concurrent query_batch stealing a coalesced key) — fail loud
            # rather than coerce the hole to False
            raise RuntimeError(
                "query_batch lost answers to an external flush; do not "
                "share a ticker-driven or concurrent MicroBatcher with "
                "synchronous query_batch")
        self.queries_served += len(queries)
        out: List[Answer] = answers
        self.queries_shed += sum(1 for a in out if a.shed)
        if keys is not None:
            for (s, t, mr_id), ans in zip(keys, out):
                if not ans.shed:        # no answer to verify
                    self._shadow.offer(s, t, mr_id, ans.value)
        return out

    def _run_batch(self, batch: Batch, tr=None):
        """Produce one answer per real request, plus per-request backend
        attribution: ``(values, backends)`` where ``backends`` is one
        label per request. Overridden by the sharded service, which fans
        the batch out across shards instead."""
        ans, backend = self.executor.execute(
            batch.s, batch.t, batch.mr_id, batch.n_real, trace=tr)
        return ans, [backend] * len(batch.requests)

    def _warm_execute(self, s: np.ndarray, t: np.ndarray,
                      mr_id: np.ndarray, mr_len: int) -> np.ndarray:
        """Cache-warmer execution hook: answer hot keys through the same
        batch path queries take (the sharded override of ``_run_batch``
        fans warm batches across shards too). Bypasses the scheduler —
        warming is off the serving critical path by construction."""
        reqs = [Request(-1 - i, int(s[i]), int(t[i]), int(mr_id[i]),
                        int(mr_len)) for i in range(len(s))]
        batch = Batch(reqs, np.asarray(s, np.int32),
                      np.asarray(t, np.int32),
                      np.asarray(mr_id, np.int32), int(mr_len), "warm")
        vals, _backends = self._run_batch(batch)
        return np.asarray(vals, dtype=bool)

    def _execute(self, batch: Batch, answers: List[Optional[Answer]],
                 slot: Dict[int, List[int]], tr=None) -> None:
        t0 = time.perf_counter()
        if tr is not None:
            # queue wait is measured on the scheduler's clock; only the
            # duration crosses into the tracer's timeline
            oldest = min(r.enqueued_at for r in batch.requests)
            tr.add_ending_now("queue_wait",
                              max(batch.flushed_at - oldest, 0.0),
                              cat="batcher", reason=batch.reason,
                              mr_len=batch.mr_len, n=batch.n_real)
            with tr.span("execute", cat="service",
                         n=batch.n_real, mr_len=batch.mr_len):
                vals, backends = self._run_batch(batch, tr)
        else:
            vals, backends = self._run_batch(batch)
        exec_s = time.perf_counter() - t0
        # feed the control loops (SLO EWMAs, back-pressure queue waits);
        # a VirtualClock scheduler clock also advances by the measured
        # execute time so open-loop replay accumulates realistic waits
        self.ctl.on_batch_executed(batch, exec_s)
        advance = getattr(self.batcher.clock, "advance", None)
        if advance is not None:
            advance(exec_s)
        for req, val, backend in zip(batch.requests, vals, backends):
            val = bool(val)
            self.cache.put((req.s, req.t, req.mr_id), val,
                           mr_len=batch.mr_len)
            ans = Answer(val,
                         "degraded" if backend == "bibfs" else "computed",
                         backend)
            for pos in slot.get(req.req_id, ()):
                answers[pos] = ans

    # -- EXPLAIN / provenance -------------------------------------------- #
    def explain(self, s: int, t: int, constraint: Constraint,
                max_hubs: int = 8) -> dict:
        """Answer ``(s, t, constraint)`` with its full derivation.

        The bundle carries the witness the serving join path would
        produce (``repro.obs.witness/1``: Case-2 entries / Case-1 join
        hubs for positives, the ruling-out fact for negatives), which
        backend explained it, and the *disposition* the query would get
        right now — whether the answer is sitting in the result cache
        and whether an identical key is in-flight in the micro-batcher.
        Read-only: no cache mutation, no batch slot, no served-query
        accounting; when a trace is sampled it lands as one ``explain``
        span.
        """
        tr = self.obs.tracer.maybe_trace()
        t0 = tr.tracer._now() if tr is not None else 0.0
        s, t, mr_id, _mr_len = self._admit(s, t, constraint)
        key = (s, t, mr_id)
        bundle = self._explain_admitted(s, t, mr_id, max_hubs=max_hubs)
        cached = self.cache.peek(key)
        bundle.update(
            s=s, t=t, mr_id=mr_id, mr=list(self._id_to_mr[mr_id]),
            cache=dict(
                disposition="hit" if cached is not None else "miss",
                answer=cached),
            coalesced=self.batcher.is_inflight(key))
        kind = bundle["witness"].get("kind", "unknown")
        if tr is not None:
            tr.add("explain", t0, tr.tracer._now() - t0, cat="explain",
                   answer=bundle["answer"], backend=bundle["backend"],
                   kind=kind)
        self._m_explain.labels(kind=kind).inc()
        return bundle

    def _explain_admitted(self, s: int, t: int, mr_id: int,
                          max_hubs: int = 8) -> dict:
        """Backend dispatch for one admitted query (single-host: the
        executor's chain; overridden by the sharded service to add
        routing hops)."""
        import numpy as np
        ws, backend = self.executor.explain_batch(
            np.array([s]), np.array([t]), np.array([mr_id]),
            max_hubs=max_hubs)
        return dict(answer=ws[0]["answer"], backend=backend,
                    witness=ws[0])

    # -- incremental graph mutation -------------------------------------- #
    def _delta_backend_name(self) -> str:
        # "parallel" maps to its sequential batched equivalent: delta
        # rebuilds touch a dirty phase subset too small to amortize the
        # epoch/merge protocol
        b = self.config.build_backend
        return b if b not in ("auto", "python", "parallel") else "numpy"

    def _make_device_index(self):
        if not self.config.use_device:
            return None
        try:
            from repro.core.device_index import DeviceIndex
            return DeviceIndex.from_frozen(self.frozen, self.mr_ids)
        except Exception:   # no jax / no device: CPU-only degraded mode
            return None

    def _ensure_delta_builder(self):
        """Bootstrap the incremental builder on first use: one traced
        full (re)build of the current graph. If the serving index was
        *adopted* pre-built (possibly with non-default pruning flags),
        the whole serving state is resynced to the rebuilt index — the
        later partial re-freezes patch rows against the builder's entry
        sets, so serving a different vintage would leave stale entries
        in rows the builder never marks dirty."""
        if self._delta is None:
            from repro.build.delta import DeltaBuilder
            adopted = self.build_stats is None
            db = DeltaBuilder(
                self.graph, self.config.k,
                backend=self._delta_backend_name(),
                fallback_frac=self.config.delta_fallback_frac,
                obs=self.obs)
            db.full()
            if adopted:
                # may itself clear self._delta (sharded hot_swap), so
                # assign the builder only afterwards
                self._adopt_rebuilt_index(db)
            self._delta = db
        return self._delta

    def _adopt_rebuilt_index(self, db) -> None:
        """Swap the full serving state onto the delta builder's index
        (bootstrap over an adopted index; see _ensure_delta_builder)."""
        self.index = db.index
        self.build_stats = db.stats
        self.frozen = self.index.freeze(self.mr_ids)
        if self.device_index is not None:
            self.device_index = self._make_device_index()
        self.executor.index = self.index
        self.executor.frozen = self.frozen
        self.executor.device_index = self.device_index
        self.cache.clear()

    def apply_delta(self, delta) -> dict:
        """Apply a :class:`repro.core.graph.GraphDelta` end-to-end.

        Incrementally re-derives the index (:mod:`repro.build.delta`),
        re-freezes only the dirty/re-sorted row ranges, refreshes the
        device layout, and evicts exactly the cached answers whose
        ``(s, t)`` rows went dirty — everything else keeps serving from
        cache. Returns a summary dict (delta accounting + evictions).
        """
        # fence in-flight warm work first: answers computed against the
        # pre-delta index must never land in the post-delta cache
        self.ctl.bump_epoch()
        db = self._ensure_delta_builder()
        res = db.apply(delta)
        self.graph = db.graph
        self.index = db.index
        self.build_stats = res.stats
        if res.fallback:
            self.frozen = self.index.freeze(self.mr_ids)
        else:
            self.frozen = self.frozen.patch_rows(
                self.index, self.mr_ids,
                set(res.dirty_out.tolist()) | set(res.resort_out.tolist()),
                set(res.dirty_in.tolist()) | set(res.resort_in.tolist()))
        if self.device_index is not None:
            self.device_index = self._make_device_index()
        # the executor keeps its latency recorders; only the index
        # references move. Repoint BEFORE invalidating the cache: a
        # concurrent ticker flush that executed on the old index must not
        # be able to re-cache a stale answer for a just-evicted key.
        self.executor.index = self.index
        self.executor.frozen = self.frozen
        self.executor.device_index = self.device_index
        if res.fallback:
            evicted = len(self.cache)
            self.cache.clear()
        else:
            evicted = self.cache.invalidate_rows(
                dirty_s=set(res.dirty_out.tolist()),
                dirty_t=set(res.dirty_in.tolist()))
        self.deltas_applied += 1
        if self._shadow is not None:
            # pending checks were served by the pre-delta index; the
            # oracle now walks the mutated graph, so they'd diverge
            # spuriously
            self._shadow.discard_pending()
        # re-materialize the hot Zipf head against the new index, under
        # the warmer's byte/time budget (no-op when warming is off)
        warm = self.ctl.warm("apply_delta")
        return dict(delta=res.as_dict(), cache_evicted=evicted,
                    dirty_out=res.dirty_out.tolist(),
                    dirty_in=res.dirty_in.tolist(),
                    deltas_applied=self.deltas_applied,
                    warm=warm)

    # -- lifecycle -------------------------------------------------------- #
    def start(self, tick_interval_s: float = 0.002) -> "RLCService":
        """Bring up async admission: after ``start()``, :meth:`submit`
        returns immediately with a future and batches execute on a
        background thread (deadline-ticker driven). Idempotent; returns
        ``self`` so ``with svc.start():`` reads naturally. Synchronous
        :meth:`query` / :meth:`query_batch` keep working (they bridge
        through the engine). The sharded service shares this exact
        protocol — one lifecycle across both facades."""
        if self._closed:
            raise RuntimeError("service is closed")
        if self._engine is None:
            from .lifecycle import AsyncEngine
            self._engine = AsyncEngine(self, tick_interval_s)
        self._engine.start()
        return self

    def submit(self, s: int, t: int, constraint: Constraint,
               now: Optional[float] = None):
        """Non-blocking query: admission happens now, execution happens
        on the engine thread; returns a
        :class:`concurrent.futures.Future` resolving to an
        :class:`Answer` (or :data:`SHED` under admission control).
        Starts the engine on first use."""
        if self._engine is None or not self._engine.active:
            self.start()
        return self._engine.submit(s, t, constraint, now)

    def close(self) -> None:
        """Idempotent shutdown: drain + stop the async engine (resolving
        every in-flight future), stop the background deadline ticker and
        the shadow verifier. Safe to call any number of times; the
        service can keep answering synchronous queries afterwards."""
        if self._closed:
            return
        self._closed = True
        if self._engine is not None:
            self._engine.close()
        self.batcher.stop_ticker()
        if self._shadow is not None:
            self._shadow.stop()

    def __enter__(self) -> "RLCService":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    # -- deprecated lifecycle entry points -------------------------------- #
    def start_ticker(self, on_batch=None,
                     interval_s: Optional[float] = None) -> None:
        """Deprecated: use :meth:`start`. Kept as a shim for callers
        that drove the scheduler ticker through the service; ignores
        ``on_batch`` and brings up the unified async engine instead."""
        import warnings
        warnings.warn(
            "RLCService.start_ticker() is deprecated; use start() — "
            "the unified lifecycle runs the ticker and an execution "
            "thread for you", DeprecationWarning, stacklevel=2)
        self.start(tick_interval_s=interval_s
                   if interval_s is not None else 0.002)

    def stop_ticker(self) -> None:
        """Deprecated: use :meth:`close` (or the context manager)."""
        import warnings
        warnings.warn(
            "RLCService.stop_ticker() is deprecated; use close()",
            DeprecationWarning, stacklevel=2)
        self.close()

    # -- observability --------------------------------------------------- #
    def audit_report(self, sample: int = 128, seed: int = 0) -> dict:
        """Walk the serving index and return a ``repro.obs.audit/1``
        health report (entry histograms, redundancy/soundness probes,
        byte accounting, drift fingerprint). The report is kept for the
        next :meth:`telemetry_snapshot` and its headline numbers are
        banked as ``rlc_audit_*`` gauges."""
        from repro.obs.audit import audit_index, bank_audit_metrics
        rep = audit_index(self.frozen, self._id_to_mr, index=self.index,
                          graph=self.graph,
                          device_index=self.device_index,
                          sample=sample, seed=seed)
        self._last_audit = rep
        bank_audit_metrics(self.obs.registry, rep)
        return rep

    def drain_shadow(self) -> int:
        """Run every pending shadow check now (foreground); returns the
        number checked. No-op (0) when shadow verification is off."""
        return self._shadow.drain() if self._shadow is not None else 0

    def telemetry_snapshot(self, extra: Optional[dict] = None) -> dict:
        """Versioned registry+tracer snapshot (``repro.obs.export``)."""
        ex = dict(extra) if extra else {}
        ex.setdefault("queries_served", self.queries_served)
        ex.setdefault("deltas_applied", self.deltas_applied)
        if self._shadow is not None:
            self._shadow.drain()
            ex.setdefault("shadow", self._shadow.stats())
        if self._last_audit is not None:
            ex.setdefault("audit", self._last_audit)
        return self.obs.snapshot(extra=ex)

    def chrome_trace(self) -> dict:
        """Recorded spans as a Chrome ``trace_event`` JSON object."""
        return self.obs.chrome_trace()

    def prometheus(self) -> str:
        """The registry in Prometheus text exposition format."""
        return self.obs.prometheus()

    def stats(self) -> dict:
        """Versioned observability snapshot (``repro.service.stats/1``,
        the bench-JSON shape; see :mod:`repro.service.stats`).

        Every subsystem is one sub-dict — ``executor`` holds both the
        per-backend latency summaries and the fallback count. The cache
        section's ``hit_rate`` is a ratio in [0, 1]. Shared sections
        come from :func:`repro.service.stats.base_stats`; validate with
        :func:`repro.service.stats.validate_stats`.
        """
        from .stats import base_stats
        out = base_stats(self, "single", "local")
        out.update(
            executor=dict(
                backends=self.executor.stats(),
                fallbacks=self.executor.fallbacks),
            index=dict(
                entries=self.index.num_entries(),
                size_bytes=self.index.size_bytes(),
                num_mrs=len(self.mr_ids),
                device=self.device_index is not None,
                row_len=(self.device_index.row_len
                         if self.device_index else None)),
        )
        return out
