"""Multi-backend batch executor for RLC query batches.

One interface over the four existing engines:

* ``python`` — dict-layout Algorithm 1 (:meth:`RLCIndex.query`), the
  always-available reference;
* ``numpy``  — frozen CSR merge-join (:meth:`FrozenRLCIndex.query_batch`);
* ``sorted`` — XLA sorted-key intersection on the padded device layout
  (:meth:`DeviceIndex.query_batch` with ``method="sorted"``);
* ``pallas`` — the Pallas dense merge-join kernel (interpreted on CPU).

Backends that need a :class:`DeviceIndex` degrade gracefully: when the
device layout is absent or a device dispatch raises, the executor walks a
fallback chain toward ``python`` and records which backend actually
answered. Per-backend latency/throughput lands in
:class:`repro.service.metrics.LatencyRecorder` and — when an
:class:`repro.obs.Observability` is attached — in the shared metrics
registry (labeled by backend and shard), with per-attempt spans when the
batch rides a sampled trace.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.minimum_repeat import LabelSeq
from repro.core.rlc_index import FrozenRLCIndex, RLCIndex
from repro.obs import NULL_OBS

from .metrics import LatencyRecorder

# Preference order: fastest batched path first, reference last.
BACKENDS = ("pallas", "sorted", "numpy", "python")


def _on_cpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "cpu"
    except Exception:
        return True


class ExecutorError(RuntimeError):
    """Raised when no backend (including the fallbacks) can run a batch."""


class BatchExecutor:
    def __init__(self, index: RLCIndex,
                 frozen: Optional[FrozenRLCIndex] = None,
                 device_index=None,
                 id_to_mr: Optional[Sequence[LabelSeq]] = None,
                 backend: str = "auto", obs=None, shard: str = "-"):
        if backend != "auto" and backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; choose from "
                f"{('auto',) + BACKENDS}")
        self.index = index
        self.frozen = frozen
        self.device_index = device_index
        self.id_to_mr = list(id_to_mr) if id_to_mr is not None else None
        self.backend = backend
        self.recorders: Dict[str, LatencyRecorder] = {
            b: LatencyRecorder(b) for b in BACKENDS}
        self.fallbacks = 0
        # registry cells, pre-bound per backend (shard = "-" single-host).
        # The registry outlives this executor, so replica hot-swaps never
        # reset the labeled series even though self.fallbacks restarts.
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        lat = reg.histogram(
            "rlc_executor_batch_seconds",
            desc="wall time of one executed batch, by answering backend",
            unit="s", labelnames=("backend", "shard"))
        bat = reg.counter("rlc_executor_batches",
                          desc="batches answered, by backend",
                          labelnames=("backend", "shard"))
        qry = reg.counter("rlc_executor_queries",
                          desc="real (unpadded) queries answered",
                          labelnames=("backend", "shard"))
        self._m_lat = {b: lat.labels(backend=b, shard=shard)
                       for b in BACKENDS}
        self._m_bat = {b: bat.labels(backend=b, shard=shard)
                       for b in BACKENDS}
        self._m_qry = {b: qry.labels(backend=b, shard=shard)
                       for b in BACKENDS}
        self._m_fallback = reg.counter(
            "rlc_executor_fallbacks",
            desc="batches not answered by the first-choice backend",
            labelnames=("from", "to", "shard"))
        self._shard = shard

    # ------------------------------------------------------------------ #
    def available(self, backend: str) -> bool:
        if backend in ("pallas", "sorted"):
            return self.device_index is not None
        if backend == "numpy":
            return self.frozen is not None
        if backend == "python":
            return self.id_to_mr is not None
        return False

    def resolve(self, backend: Optional[str] = None) -> str:
        """Map ``auto`` (or None) to the best available backend."""
        b = backend or self.backend
        if b == "auto":
            order = BACKENDS
            if _on_cpu():
                # the Pallas kernel only *interprets* on CPU — the XLA
                # sorted-key path is the fast lowering there.
                order = ("sorted", "numpy", "pallas", "python")
            for cand in order:
                if self.available(cand):
                    return cand
            raise ExecutorError("no backend available")
        return b

    # ------------------------------------------------------------------ #
    def execute(self, s: np.ndarray, t: np.ndarray, mr_id: np.ndarray,
                n_real: Optional[int] = None,
                backend: Optional[str] = None,
                trace=None) -> Tuple[np.ndarray, str]:
        """Answer a padded batch; returns ``(answers[:n_real], backend)``.

        Tries the requested backend, then every remaining backend in
        ``BACKENDS`` order (a device failure must never fail the query —
        the python reference can always answer). ``trace``: optional
        :class:`repro.obs.Trace`; each attempt gets an ``exec:<backend>``
        span, so a fallback chain is visible as consecutive spans.
        """
        first = self.resolve(backend)
        chain = [first] + [b for b in BACKENDS
                           if b != first and self.available(b)]
        n = len(s) if n_real is None else int(n_real)
        last_err: Optional[Exception] = None
        for i, b in enumerate(chain):
            if not self.available(b):
                continue
            try:
                t0 = time.perf_counter()
                ans = self._run(b, s, t, mr_id, n)
                dt = time.perf_counter() - t0
                self.recorders[b].record(dt, n)
                self._m_lat[b].observe(dt)
                self._m_bat[b].inc()
                self._m_qry[b].inc(n)
                if trace is not None:
                    trace.add(f"exec:{b}", trace.tracer._now() - dt, dt,
                              cat="executor", n=n, fallback=i > 0)
                if i > 0:
                    self.fallbacks += 1
                    self._m_fallback.labels(
                        **{"from": first, "to": b,
                           "shard": self._shard}).inc()
                return np.asarray(ans[:n], dtype=bool), b
            except Exception as e:  # noqa: BLE001 — fall through the chain
                last_err = e
                if trace is not None:
                    dt = time.perf_counter() - t0
                    trace.add(f"exec:{b}", trace.tracer._now() - dt, dt,
                              cat="executor", error=type(e).__name__)
        raise ExecutorError(
            f"all backends failed for batch of {n} queries") from last_err

    def explain_batch(self, s: np.ndarray, t: np.ndarray,
                      mr_id: np.ndarray, n_real: Optional[int] = None,
                      backend: Optional[str] = None,
                      max_hubs: int = 8) -> Tuple[list, str]:
        """Witness mode of :meth:`execute`: per-query derivations instead
        of bare booleans; returns ``(witnesses[:n_real], backend)``.

        The backend is resolved with the same chain as ``execute`` so the
        witness reflects the layout the serving path would actually join
        — device backends explain over the padded/truncated device rows,
        ``numpy`` over the frozen CSR, ``python`` over the dict layout.
        Device failures degrade the same way the serving path does.
        """
        first = self.resolve(backend)
        n = len(s) if n_real is None else int(n_real)
        if first in ("pallas", "sorted") and self.device_index is not None:
            try:
                ws = self.device_index.explain_batch(s[:n], t[:n],
                                                     mr_id[:n],
                                                     max_hubs=max_hubs)
                return ws, first
            except Exception:  # noqa: BLE001 — degrade like execute()
                pass
        if self.frozen is not None:
            ws = [self.frozen.explain(int(s[q]), int(t[q]),
                                      int(mr_id[q]), max_hubs=max_hubs)
                  for q in range(n)]
            return ws, "numpy"
        if self.id_to_mr is None:
            raise ExecutorError("no backend can explain this batch")
        ws = []
        for q in range(n):
            mr = self.id_to_mr[int(mr_id[q])]
            ws.append(self.index.explain(int(s[q]), int(t[q]), mr,
                                         mr_id=int(mr_id[q]),
                                         max_hubs=max_hubs))
        return ws, "python"

    @staticmethod
    def _pad_pow2(s, t, mr_id, n: int):
        """Pad a real-length batch to the next power of two by repeating
        slot 0 — batches arrive unpadded from the scheduler, and the jit
        backends need a bounded shape set ({1, 2, 4, ...}) to avoid
        re-tracing per fill level. Slot 0 is always a valid query; the
        caller slices answers back to ``n``."""
        cap = 1
        while cap < n:
            cap <<= 1
        if cap == len(s):
            return s, t, mr_id
        pad = lambda a: np.concatenate(  # noqa: E731
            [np.asarray(a[:n]), np.full(cap - n, a[0], dtype=a.dtype)])
        return pad(s), pad(t), pad(mr_id)

    def _run(self, backend: str, s, t, mr_id, n: int) -> np.ndarray:
        # The device backends get pow2-padded shapes (static jit set);
        # the per-query loop backends run exactly the real slots.
        if backend == "pallas":
            s, t, mr_id = self._pad_pow2(s, t, mr_id, n)
            return self.device_index.query_batch(s, t, mr_id,
                                                 use_pallas=True)
        if backend == "sorted":
            s, t, mr_id = self._pad_pow2(s, t, mr_id, n)
            return self.device_index.query_batch(s, t, mr_id,
                                                 method="sorted")
        if backend == "numpy":
            return self.frozen.query_batch(s[:n], t[:n], mr_id[:n])
        if backend == "python":
            out = np.zeros(n, dtype=bool)
            for q in range(n):
                out[q] = self.index.query(int(s[q]), int(t[q]),
                                          self.id_to_mr[int(mr_id[q])])
            return out
        raise ExecutorError(f"unknown backend {backend!r}")

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, Dict[str, float]]:
        """Summaries for every backend that actually served a batch."""
        return {b: r.summary() for b, r in self.recorders.items()
                if r.batches}
