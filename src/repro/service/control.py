"""Closed-loop serving control plane: SLO-aware batching, admission
control + load shedding, and prioritized cache warming.

PR 6 made the serving path observable — `rlc_batcher_queue_wait_seconds`
and `rlc_batcher_batch_fill` expose where a request's latency goes,
`rlc_executor_batch_seconds` what each backend costs, `rlc_cache_lookups`
how the Zipf head behaves. This module adds the feedback loops that
*consume* those series:

* :class:`SLOBatchController` — replaces the fixed
  ``batch_size``/``max_wait_s`` with a per-MR-length controller. Each
  bucket's deadline is sized from the latency budget left after the
  observed compute cost (``target_p99_ms`` minus the EWMA batch-execute
  time), and its batch size adapts multiplicatively: grow while compute
  is cheap relative to the budget and batches flush full (amortize
  more), shrink when a batch's execute time alone threatens the SLO.

* :class:`AdmissionController` — a bounded admission queue with a
  back-pressure signal. Two triggers: the *hard* bound (scheduler
  pending >= ``admission_max_pending``) and the *soft* back-pressure
  signal (EWMA queue wait past ``admission_backpressure_ms``, the
  control-loop reading of ``rlc_batcher_queue_wait_seconds``). Shed
  requests get the explicit :data:`SHED` answer — never a fabricated
  boolean. Priority follows the issue's rule: deepest-MR, coldest-key
  requests go first (score = frequency estimate / MR length); under the
  hard bound a high-priority arrival may instead *evict* the
  lowest-priority queued request.

* :class:`CacheWarmer` — a frequency-sketch-backed warmer that
  re-materializes the hottest ``(s, t, mr)`` answers after
  ``apply_delta`` / ``hot_swap`` under a byte/time budget, so an
  invalidation storm refills the Zipf head off the critical path instead
  of as a p99 spike of cold misses. Warming is *epoch-fenced* exactly
  like the PR 8 shadow verifier: a mutation bumps the epoch, and a warm
  pass started against the old index aborts rather than writing stale
  answers into the new-epoch cache.

:class:`FrequencySketch` is the shared signal: a count-min sketch (with
periodic halving, so it tracks *recent* popularity) plus a bounded
exact top-K candidate heap — the priority-queue sampling shape from
prioritized experience replay, applied to query keys. Admission reads it
for "coldest-key", the warmer for "hot rows worth re-materializing".

:class:`VirtualClock` supports open-loop overload replay in a
synchronous harness: the bench advances it to each request's *arrival*
stamp while the service advances it by measured *execute* time, so queue
waits grow exactly as they would in an open-loop system where offered
load exceeds capacity (the ``bench_sharded`` overload stage and the
injected-overload tests both drive it).
"""
from __future__ import annotations

import heapq
import time
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import NULL_OBS

from .answer import SHED  # noqa: F401 — historical home, re-exported

Key = Tuple[int, int, int]  # (s, t, mr_id)

__all__ = [
    "SHED", "VirtualClock", "FrequencySketch", "SLOBatchController",
    "AdmissionController", "CacheWarmer", "ControlPlane",
]


class VirtualClock:
    """Settable + advanceable clock for open-loop arrival replay.

    Inject as ``ServiceConfig.clock``: the scheduler stamps admissions
    and flushes with it, the service advances it by each batch's
    measured execute time, and the driver advances it to each chunk's
    arrival stamp (:meth:`at_least`). Monotone by construction.
    """

    __slots__ = ("t",)

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt > 0:
            self.t += float(dt)
        return self.t

    def at_least(self, t: float) -> float:
        if t > self.t:
            self.t = float(t)
        return self.t


# --------------------------------------------------------------------- #
# Frequency sketch
# --------------------------------------------------------------------- #
class FrequencySketch:
    """Count-min sketch with halving decay + bounded exact top-K heap.

    ``observe(key)`` increments the sketch and returns the (conservative)
    count estimate; every ``decay_every`` observations all counts halve,
    so estimates track the *recent* request mix rather than all of
    history — a post-delta warm pass should refill today's Zipf head, not
    last hour's. The top-K candidate set (``hot()``) is the part a sketch
    alone cannot give: warming needs actual keys to re-execute, so the
    hottest ``hot_capacity`` keys ride a min-heap keyed by estimate (the
    PER priority-queue shape: cheap priorities for everyone, exact
    entries for the head of the distribution).
    """

    def __init__(self, width: int = 2048, depth: int = 4,
                 hot_capacity: int = 256, decay_every: int = 8192):
        if width < 8 or depth < 1:
            raise ValueError(f"bad sketch shape ({width}x{depth})")
        self.width = int(width)
        self.depth = int(depth)
        self.hot_capacity = int(hot_capacity)
        self.decay_every = int(decay_every)
        self.counts = np.zeros((depth, width), dtype=np.int64)
        self.observed = 0
        self.decays = 0
        # exact candidates: key -> (estimate, mr_len); kept <= capacity
        self._hot: Dict[Key, Tuple[int, int]] = {}

    def _rows(self, key: Key) -> List[int]:
        h = zlib.crc32(np.asarray(key, dtype=np.int64).tobytes())
        out = []
        for d in range(self.depth):
            h = (h * 1103515245 + 12345 + d) & 0x7FFFFFFF
            out.append(h % self.width)
        return out

    def observe(self, key: Key, mr_len: int = 0) -> int:
        """Count one occurrence; returns the post-increment estimate."""
        cols = self._rows(key)
        for d, c in enumerate(cols):
            self.counts[d, c] += 1
        est = int(min(self.counts[d, c]
                      for d, c in enumerate(cols)))
        self.observed += 1
        hot = self._hot
        if key in hot or len(hot) < self.hot_capacity:
            hot[key] = (est, int(mr_len))
        else:
            # admit only past the coldest current candidate
            coldest = min(hot, key=lambda k: hot[k][0])
            if est > hot[coldest][0]:
                del hot[coldest]
                hot[key] = (est, int(mr_len))
        if self.observed % self.decay_every == 0:
            self.decay()
        return est

    def estimate(self, key: Key) -> int:
        return int(min(self.counts[d, c]
                       for d, c in enumerate(self._rows(key))))

    def decay(self) -> None:
        """Halve every count (recency: old traffic fades geometrically)."""
        self.counts >>= 1
        self._hot = {k: (e >> 1, ln) for k, (e, ln) in self._hot.items()
                     if e >> 1 > 0}
        self.decays += 1

    def hot(self, n: Optional[int] = None) -> List[Tuple[int, int, Key]]:
        """Top candidates as ``(estimate, mr_len, key)``, hottest first."""
        items = [(est, ln, k) for k, (est, ln) in self._hot.items()]
        n = len(items) if n is None else int(n)
        return heapq.nlargest(n, items, key=lambda it: (it[0], -it[1]))

    def stats(self) -> dict:
        return dict(observed=self.observed, decays=self.decays,
                    hot_tracked=len(self._hot),
                    hot_capacity=self.hot_capacity)


def _ewma(prev: Optional[float], x: float, alpha: float) -> float:
    return x if prev is None else prev + alpha * (x - prev)


# --------------------------------------------------------------------- #
# SLO-aware batching
# --------------------------------------------------------------------- #
class SLOBatchController:
    """Per-MR-length batch size + deadline from the queue-wait/compute
    decomposition, targeting ``target_p99_s``.

    The control law, applied per MR-length bucket at most every
    ``interval_s`` seconds (piggybacked on batch completions):

    * **deadline**: the wait a request may be held is the SLO budget
      minus what executing its batch costs —
      ``max_wait = clamp(headroom_frac * (target - exec_ewma), floor,
      target/2)``. Expensive buckets get short deadlines (they cannot
      afford to sit), cheap ones batch longer.
    * **batch size**: multiplicative-increase/decrease within
      ``[min_batch, max_batch]``. Shrink (halve) when the EWMA execute
      time alone eats more than ``shrink_frac`` of the budget; grow
      (double) when execute time is under ``grow_frac`` of the budget
      *and* batches have been flushing full (fill ratio — the
      ``rlc_batcher_batch_fill`` signal — says demand exists).

    Observations arrive via :meth:`observe_batch` (the service calls it
    after every executed batch); the pooled registry reservoirs
    (``rlc_batcher_queue_wait_seconds``, ``rlc_executor_batch_seconds``)
    remain the monitoring view of the same signals and seed the global
    p99 read-back in :meth:`stats`.
    """

    #: bounds and gains — class attrs so tests can subclass/monkeypatch
    WAIT_FLOOR_S = 5e-5
    HEADROOM_FRAC = 0.25
    SHRINK_FRAC = 0.5
    GROW_FRAC = 0.125
    FULL_FILL = 0.9
    ALPHA = 0.3

    def __init__(self, registry, target_p99_s: float, base_batch: int,
                 base_wait_s: float, min_batch: int = 1,
                 max_batch: Optional[int] = None,
                 interval_s: float = 0.05,
                 clock: Callable[[], float] = time.monotonic):
        if target_p99_s <= 0:
            raise ValueError(f"target_p99_s must be > 0, got {target_p99_s}")
        self.registry = registry
        self.target_p99_s = float(target_p99_s)
        self.base_batch = int(base_batch)
        self.base_wait_s = float(base_wait_s)
        self.min_batch = max(1, int(min_batch))
        self.max_batch = int(max_batch if max_batch is not None
                             else 4 * base_batch)
        self.interval_s = float(interval_s)
        self.clock = clock
        self.updates = 0
        # per-mr-len state: current params + EWMAs of exec time and fill
        self._batch: Dict[int, int] = {}
        self._wait: Dict[int, float] = {}
        self._exec_ewma: Dict[int, float] = {}
        self._fill_ewma: Dict[int, float] = {}
        self._last_update: Dict[int, float] = {}
        reg = registry if registry is not None else NULL_OBS.registry
        self._m_batch = reg.gauge(
            "rlc_control_batch_size",
            desc="controller-chosen batch size per MR length",
            labelnames=("mr_len",))
        self._m_wait = reg.gauge(
            "rlc_control_max_wait_seconds",
            desc="controller-chosen deadline per MR length", unit="s",
            labelnames=("mr_len",))
        self._m_updates = reg.counter(
            "rlc_control_updates",
            desc="SLO controller parameter recomputations").labels()

    # -- the scheduler-facing surface ----------------------------------- #
    def params(self, mr_len: int) -> Tuple[int, float]:
        """Current ``(batch_size, max_wait_s)`` for one MR-length bucket."""
        return (self._batch.get(mr_len, self.base_batch),
                self._wait.get(mr_len, self.base_wait_s))

    # -- the service-facing feedback ------------------------------------ #
    def observe_batch(self, mr_len: int, n_real: int, exec_s: float,
                      now: Optional[float] = None) -> None:
        """Feed one executed batch; recompute the bucket's params when
        its update interval elapsed."""
        mr_len = int(mr_len)
        self._exec_ewma[mr_len] = _ewma(
            self._exec_ewma.get(mr_len), float(exec_s), self.ALPHA)
        cap = self._batch.get(mr_len, self.base_batch)
        self._fill_ewma[mr_len] = _ewma(
            self._fill_ewma.get(mr_len), min(n_real / cap, 1.0), self.ALPHA)
        now = self.clock() if now is None else now
        if now - self._last_update.get(mr_len, -1e18) >= self.interval_s:
            self._update(mr_len, now)

    def _update(self, mr_len: int, now: float) -> None:
        target = self.target_p99_s
        exec_s = self._exec_ewma.get(mr_len, 0.0)
        fill = self._fill_ewma.get(mr_len, 0.0)
        cap = self._batch.get(mr_len, self.base_batch)
        if exec_s > self.SHRINK_FRAC * target:
            cap = max(self.min_batch, cap // 2)
        elif exec_s < self.GROW_FRAC * target and fill >= self.FULL_FILL:
            cap = min(self.max_batch, cap * 2)
        wait = min(self.HEADROOM_FRAC * (target - exec_s), target / 2)
        wait = max(wait, self.WAIT_FLOOR_S)
        self._batch[mr_len] = cap
        self._wait[mr_len] = wait
        self._last_update[mr_len] = now
        self.updates += 1
        self._m_batch.set(cap, mr_len=mr_len)
        self._m_wait.set(wait, mr_len=mr_len)
        self._m_updates.inc()

    # -- monitoring ------------------------------------------------------ #
    def _pooled_p99(self, name: str) -> float:
        m = self.registry.get(name) if self.registry is not None else None
        if m is None:
            return 0.0
        samples: List[float] = []
        for _key, cell in m.series():
            samples.extend(cell.reservoir.samples)
        if not samples:
            return 0.0
        return float(np.percentile(np.asarray(samples), 99))

    def stats(self) -> dict:
        return dict(
            target_p99_ms=self.target_p99_s * 1e3,
            updates=self.updates,
            batch_size={ln: b for ln, b in sorted(self._batch.items())},
            max_wait_ms={ln: round(w * 1e3, 4)
                         for ln, w in sorted(self._wait.items())},
            exec_ewma_ms={ln: round(v * 1e3, 4)
                          for ln, v in sorted(self._exec_ewma.items())},
            queue_p99_ms=round(
                self._pooled_p99("rlc_batcher_queue_wait_seconds") * 1e3, 4),
            exec_p99_ms=round(
                self._pooled_p99("rlc_executor_batch_seconds") * 1e3, 4),
        )


# --------------------------------------------------------------------- #
# Admission control + load shedding
# --------------------------------------------------------------------- #
class AdmissionController:
    """Bounded admission queue + back-pressure shedding.

    ``decide`` runs per cache-missed arrival, *before* the scheduler
    takes a slot:

    * pending < bound and back-pressure clear — ``("admit", None)``;
    * soft back-pressure (EWMA queue wait > ``backpressure_s``) — shed
      the arrival only if it is low-priority (colder/deeper than the
      current queue median priority); hot short queries keep flowing
      while the controller drains the backlog;
    * hard bound (pending >= ``max_pending``) — compare the arrival
      against the lowest-priority *queued* request: if the arrival wins,
      ``("evict", victim)`` (the caller sheds the victim and admits the
      arrival); otherwise ``("shed", None)``.

    Priority: ``frequency_estimate / mr_len`` — deepest-MR, coldest-key
    requests are worth the least under overload (most compute for the
    least-repeated key). Every decision lands in
    ``rlc_admission_requests{decision}`` / ``rlc_admission_shed{reason}``,
    and the recovering EWMA means shedding *stops* once queue waits
    drain back under the threshold.
    """

    ALPHA = 0.2

    def __init__(self, registry, sketch: FrequencySketch,
                 max_pending: Optional[int] = None,
                 backpressure_s: Optional[float] = None):
        if max_pending is not None and max_pending < 1:
            raise ValueError(
                f"admission_max_pending must be >= 1, got {max_pending}")
        self.sketch = sketch
        self.max_pending = max_pending
        self.backpressure_s = backpressure_s
        self.wait_ewma: Optional[float] = None
        self.admitted = 0
        self.shed = 0
        reg = registry if registry is not None else NULL_OBS.registry
        dec = reg.counter("rlc_admission_requests",
                          desc="admission decisions",
                          labelnames=("decision",))
        self._m_admit = dec.labels(decision="admitted")
        self._m_shed = dec.labels(decision="shed")
        why = reg.counter("rlc_admission_shed",
                          desc="requests shed, by trigger",
                          labelnames=("reason",))
        self._m_why = {r: why.labels(reason=r)
                       for r in ("queue_full", "backpressure", "evicted")}
        self._m_pending = reg.gauge(
            "rlc_admission_pending",
            desc="scheduler pending depth at the last admission").labels()

    # ------------------------------------------------------------------ #
    def priority(self, key: Key, mr_len: int) -> float:
        """Higher = more worth serving under overload."""
        return self.sketch.estimate(key) / max(int(mr_len), 1)

    def observe_wait(self, wait_s: float) -> None:
        """Feed one request's realized queue wait (admission -> flush) —
        the control-loop reading of ``rlc_batcher_queue_wait_seconds``."""
        self.wait_ewma = _ewma(self.wait_ewma, float(wait_s), self.ALPHA)

    @property
    def backpressured(self) -> bool:
        return (self.backpressure_s is not None
                and self.wait_ewma is not None
                and self.wait_ewma > self.backpressure_s)

    def decide(self, key: Key, mr_len: int, batcher
               ) -> Tuple[str, Optional[object]]:
        """One of ``("admit", None)`` / ``("shed", None)`` /
        ``("evict", victim_request)``; see the class docstring."""
        pending = batcher.pending()
        self._m_pending.set(pending)
        prio = self.priority(key, mr_len)
        if self.max_pending is not None and pending >= self.max_pending:
            victim = batcher.lowest_priority_pending(
                lambda r: self.priority((r.s, r.t, r.mr_id), r.mr_len))
            if victim is not None and prio > self.priority(
                    (victim.s, victim.t, victim.mr_id), victim.mr_len):
                self.shed += 1
                self._m_shed.inc()
                self._m_why["evicted"].inc()
                self._m_admit.inc()
                self.admitted += 1
                return "evict", victim
            self.shed += 1
            self._m_shed.inc()
            self._m_why["queue_full"].inc()
            return "shed", None
        if self.backpressured:
            median = batcher.median_pending_priority(
                lambda r: self.priority((r.s, r.t, r.mr_id), r.mr_len))
            if median is None or prio <= median:
                self.shed += 1
                self._m_shed.inc()
                self._m_why["backpressure"].inc()
                return "shed", None
        self.admitted += 1
        self._m_admit.inc()
        return "admit", None

    def stats(self) -> dict:
        total = self.admitted + self.shed
        return dict(
            admitted=self.admitted, shed=self.shed,
            shed_ratio=self.shed / total if total else 0.0,
            max_pending=self.max_pending,
            backpressure_ms=(None if self.backpressure_s is None
                             else self.backpressure_s * 1e3),
            wait_ewma_ms=(None if self.wait_ewma is None
                          else round(self.wait_ewma * 1e3, 4)),
            backpressured=self.backpressured,
        )


# --------------------------------------------------------------------- #
# Prioritized cache warming
# --------------------------------------------------------------------- #
class CacheWarmer:
    """Re-materialize the hot Zipf head after an invalidation event.

    ``warm(trigger)`` takes the sketch's top candidates, drops those
    still cached (``cache.peek`` — non-mutating), ranks the rest by
    ``frequency x (1 + miss_rate(mr_len))`` (the per-MR-length hit-rate
    breakdown the cache now exposes: lengths that miss more benefit more
    from pre-materialization), and re-executes them in MR-length-grouped
    chunks through ``execute_fn`` — the *service's* serving path, so a
    sharded stack warms through the same fan-out its queries take.

    Budgets: ``budget_bytes`` caps the cache footprint written
    (``ENTRY_BYTES`` per answer, the LRU's dict-node estimate) and
    ``budget_s`` the wall time; whichever exhausts first stops the pass,
    with the remainder counted as ``skipped_budget``.

    Epoch fencing mirrors the PR 8 shadow verifier: ``bump_epoch()`` is
    called at the *start* of every ``apply_delta``/``hot_swap``; a warm
    pass checks the epoch before every chunk's ``cache.put`` and aborts
    (``stale`` counter) if a newer mutation landed, so answers computed
    against a dead index never enter the cache.
    """

    #: LRU footprint estimate per cached answer: OrderedDict node + key
    #: tuple of 3 ints + (bool, stamp) value tuple.
    ENTRY_BYTES = 160

    def __init__(self, cache, sketch: FrequencySketch,
                 execute_fn: Callable[[np.ndarray, np.ndarray, np.ndarray,
                                       int], np.ndarray],
                 budget_bytes: int = 1 << 20, budget_s: float = 0.25,
                 chunk: int = 64, obs=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.cache = cache
        self.sketch = sketch
        self.execute_fn = execute_fn
        self.budget_bytes = int(budget_bytes)
        self.budget_s = float(budget_s)
        self.chunk = max(1, int(chunk))
        self.clock = clock
        self.epoch = 0
        self.runs = 0
        self.warmed = 0
        self.obs = obs or NULL_OBS
        reg = self.obs.registry
        self._m_runs = reg.counter("rlc_warm_runs",
                                   desc="warm passes, by trigger",
                                   labelnames=("trigger",))
        keys = reg.counter("rlc_warm_keys",
                           desc="warm candidates, by outcome",
                           labelnames=("outcome",))
        self._m_keys = {o: keys.labels(outcome=o)
                        for o in ("warmed", "already_cached",
                                  "skipped_budget", "stale")}
        self._m_bytes = reg.counter(
            "rlc_warm_bytes",
            desc="estimated cache bytes written by warming",
            unit="By").labels()
        self._m_secs = reg.histogram(
            "rlc_warm_seconds", desc="wall time of one warm pass",
            unit="s").labels()

    def bump_epoch(self) -> int:
        """Invalidate in-flight warm work (call at mutation start)."""
        self.epoch += 1
        return self.epoch

    # ------------------------------------------------------------------ #
    def candidates(self) -> List[Tuple[float, int, Key]]:
        """Uncached hot keys as ``(score, mr_len, key)``, best first."""
        by_len = getattr(self.cache, "hit_rate_by_mr_len", lambda: {})()
        out = []
        for est, mr_len, key in self.sketch.hot():
            if self.cache.peek(key) is not None:
                self._m_keys["already_cached"].inc()
                continue
            miss_rate = 1.0 - by_len.get(mr_len, 0.0)
            out.append((est * (1.0 + miss_rate), mr_len, key))
        out.sort(key=lambda it: (-it[0], it[1]))
        return out

    def warm(self, trigger: str = "manual") -> dict:
        """One budgeted warm pass; returns its accounting dict."""
        t0 = self.clock()
        epoch = self.epoch
        self.runs += 1
        self._m_runs.labels(trigger=trigger).inc()
        cands = self.candidates()
        budget_keys = self.budget_bytes // self.ENTRY_BYTES
        warmed = skipped = stale = 0
        bytes_written = 0
        # group by MR length so warm batches mirror serving batches
        by_len: Dict[int, List[Key]] = {}
        for _score, mr_len, key in cands:
            if warmed + sum(len(v) for v in by_len.values()) >= budget_keys:
                skipped += 1
                continue
            by_len.setdefault(mr_len, []).append(key)
        aborted = False
        for mr_len, keys in sorted(by_len.items()):
            for i in range(0, len(keys), self.chunk):
                part = keys[i:i + self.chunk]
                if aborted or self.clock() - t0 > self.budget_s:
                    skipped += len(part)
                    aborted = aborted or True
                    continue
                s = np.fromiter((k[0] for k in part), np.int32, len(part))
                t = np.fromiter((k[1] for k in part), np.int32, len(part))
                mr = np.fromiter((k[2] for k in part), np.int32, len(part))
                ans = self.execute_fn(s, t, mr, mr_len)
                if self.epoch != epoch:
                    # a mutation landed while we executed: these answers
                    # belong to a dead index — drop them all
                    stale += len(part)
                    aborted = True
                    continue
                for k, a in zip(part, ans):
                    self.cache.put(k, bool(a), mr_len=mr_len)
                warmed += len(part)
                bytes_written += len(part) * self.ENTRY_BYTES
        dt = self.clock() - t0
        self.warmed += warmed
        self._m_keys["warmed"].inc(warmed)
        self._m_keys["skipped_budget"].inc(skipped)
        self._m_keys["stale"].inc(stale)
        self._m_bytes.inc(bytes_written)
        self._m_secs.observe(dt)
        return dict(trigger=trigger, epoch=epoch, warmed=warmed,
                    skipped_budget=skipped, stale=stale,
                    bytes=bytes_written, seconds=dt)

    def stats(self) -> dict:
        return dict(runs=self.runs, warmed=self.warmed, epoch=self.epoch,
                    budget_bytes=self.budget_bytes,
                    budget_s=self.budget_s,
                    sketch=self.sketch.stats())


# --------------------------------------------------------------------- #
# Assembly
# --------------------------------------------------------------------- #
class ControlPlane:
    """The per-service bundle of control loops (each independently
    optional): built by :meth:`from_config`, threaded through the
    services' admission/execute/mutation paths. ``None`` members mean
    that loop is off and its call sites stay branch-cheap."""

    def __init__(self, sketch: Optional[FrequencySketch] = None,
                 slo: Optional[SLOBatchController] = None,
                 admission: Optional[AdmissionController] = None,
                 warmer: Optional[CacheWarmer] = None):
        self.sketch = sketch
        self.slo = slo
        self.admission = admission
        self.warmer = warmer

    @classmethod
    def from_config(cls, config, obs, cache, execute_fn,
                    clock: Callable[[], float]) -> "ControlPlane":
        """Wire the loops a :class:`ServiceConfig` asks for.

        ``target_p99_ms`` enables the SLO batch controller;
        ``admission_max_pending`` / ``admission_backpressure_ms`` the
        admission controller (back-pressure defaults to ``2 x
        target_p99_ms`` when an SLO is set); ``warm_capacity > 0`` the
        warmer. The frequency sketch exists whenever admission or
        warming needs it.
        """
        registry = obs.registry
        target_s = (None if config.target_p99_ms is None
                    else config.target_p99_ms * 1e-3)
        backpressure_s = (config.admission_backpressure_ms * 1e-3
                          if config.admission_backpressure_ms is not None
                          else (2.0 * target_s
                                if target_s is not None else None))
        admission_on = (config.admission_max_pending is not None
                        or (backpressure_s is not None
                            and target_s is not None))
        warming_on = config.warm_capacity > 0
        sketch = None
        if admission_on or warming_on:
            sketch = FrequencySketch(
                hot_capacity=max(config.warm_capacity, 256))
        slo = None
        if target_s is not None:
            slo = SLOBatchController(
                registry, target_s, base_batch=config.batch_size,
                base_wait_s=config.max_wait_ms * 1e-3,
                max_batch=config.max_batch_size,
                interval_s=config.control_interval_s, clock=clock)
        admission = None
        if admission_on:
            admission = AdmissionController(
                registry, sketch,
                max_pending=config.admission_max_pending,
                backpressure_s=backpressure_s)
        warmer = None
        if warming_on:
            warmer = CacheWarmer(
                cache, sketch, execute_fn,
                budget_bytes=config.warm_budget_bytes,
                budget_s=config.warm_budget_s, obs=obs)
        return cls(sketch, slo, admission, warmer)

    @property
    def active(self) -> bool:
        return (self.sketch is not None or self.slo is not None
                or self.admission is not None or self.warmer is not None)

    # -- hooks the serving loop calls ----------------------------------- #
    def observe_admit(self, key: Key, mr_len: int) -> None:
        if self.sketch is not None:
            self.sketch.observe(key, mr_len)

    def on_batch_executed(self, batch, exec_s: float) -> None:
        """Feed one executed batch into the loops (queue waits into the
        admission back-pressure EWMA, exec time into the SLO EWMAs)."""
        if self.admission is not None:
            for r in batch.requests:
                self.admission.observe_wait(
                    max(batch.flushed_at - r.enqueued_at, 0.0))
        if self.slo is not None:
            self.slo.observe_batch(batch.mr_len, batch.n_real, exec_s)

    def bump_epoch(self) -> None:
        if self.warmer is not None:
            self.warmer.bump_epoch()

    def warm(self, trigger: str) -> Optional[dict]:
        if self.warmer is None:
            return None
        return self.warmer.warm(trigger)

    def stats(self) -> Optional[dict]:
        if not self.active:
            return None
        return dict(
            slo=self.slo.stats() if self.slo is not None else None,
            admission=(self.admission.stats()
                       if self.admission is not None else None),
            warmer=self.warmer.stats() if self.warmer is not None else None,
            sketch=(self.sketch.stats()
                    if self.sketch is not None else None),
        )
