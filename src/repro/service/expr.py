"""Path-expression parser for recursive label-concatenated constraints.

Grammar (whitespace- or comma-separated labels, one ``+``-starred group):

    expr   := group '+'
    group  := '(' body ')' | body
    body   := '"' tokens '"' | "'" tokens "'" | tokens
    tokens := token (sep token)*

Accepted spellings of the paper's ``(debits credits)+``::

    (debits credits)+    ("debits credits")+    (2 3)+    2,3+    (1)+

Tokens are either non-negative integer label ids or label names resolved
through an optional name map. The parsed sequence is validated against the
graph's label alphabet and the index's ``k`` bound, then canonicalized to
its minimum repeat via :func:`repro.core.minimum_repeat.minimum_repeat`
(``(a b a b)+`` and ``(a b)+`` denote the same query, Lemma 1), so every
expression maps onto exactly one indexed MR id.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional, Sequence

from repro.core.minimum_repeat import LabelSeq, minimum_repeat


class ExpressionError(ValueError):
    """Raised for malformed, unknown-label or over-``k`` expressions."""


@dataclass(frozen=True)
class PathExpression:
    """A validated, canonicalized ``L^+`` constraint."""

    raw: str            # original text
    labels: LabelSeq    # label ids exactly as written
    mr: LabelSeq        # minimum repeat of ``labels`` (what the index stores)

    def __len__(self) -> int:
        return len(self.mr)


_QUOTES = {'"': '"', "'": "'"}


def _strip_group(text: str) -> str:
    """Peel ``( ... )+`` / ``...+`` down to the token body."""
    body = text.strip()
    if not body:
        raise ExpressionError("empty expression")
    if not body.endswith("+"):
        raise ExpressionError(
            f"expression must end with '+' (recursive concatenation): "
            f"{text!r}")
    body = body[:-1].strip()
    if body.startswith("(") or body.endswith(")"):
        if not (body.startswith("(") and body.endswith(")")):
            raise ExpressionError(f"unbalanced parentheses in {text!r}")
        body = body[1:-1].strip()
    if body[:1] in _QUOTES:
        if len(body) < 2 or body[-1] != _QUOTES[body[0]]:
            raise ExpressionError(f"unbalanced quotes in {text!r}")
        body = body[1:-1].strip()
    if not body:
        raise ExpressionError(f"empty label group in {text!r}")
    if any(ch in body for ch in "()+\"'"):
        raise ExpressionError(
            f"nested groups / stray '+' are not supported: {text!r}")
    return body


def parse_expression(text: str, *, num_labels: int, k: int,
                     label_names: Optional[Dict[str, int]] = None
                     ) -> PathExpression:
    """Parse and validate one textual constraint into a :class:`PathExpression`.

    Raises :class:`ExpressionError` with an actionable message when the
    expression is malformed, uses an unknown label, or its minimum repeat
    is longer than the index's ``k``.
    """
    if not isinstance(text, str):
        raise ExpressionError(f"expression must be a string, got "
                              f"{type(text).__name__}")
    body = _strip_group(text)
    tokens = [t for t in re.split(r"[\s,]+", body) if t]
    labels = []
    for tok in tokens:
        if re.fullmatch(r"\d+", tok):
            lab = int(tok)
        elif label_names is not None and tok in label_names:
            lab = int(label_names[tok])
        else:
            known = (f"; known names: {sorted(label_names)}"
                     if label_names else "")
            raise ExpressionError(
                f"unknown label {tok!r} in {text!r}{known}")
        if not 0 <= lab < num_labels:
            raise ExpressionError(
                f"label id {lab} out of range [0, {num_labels}) in {text!r}")
        labels.append(lab)
    seq: LabelSeq = tuple(labels)
    mr = minimum_repeat(seq)
    if len(mr) > k:
        raise ExpressionError(
            f"minimum repeat {mr} of {text!r} has length {len(mr)} > k={k}; "
            f"the index cannot answer it (rebuild with a larger k)")
    return PathExpression(raw=text, labels=seq, mr=mr)


def canonicalize(labels: Sequence[int], *, num_labels: int, k: int
                 ) -> PathExpression:
    """Same validation/canonicalization for programmatic (tuple) input."""
    seq = tuple(int(l) for l in labels)
    if not seq:
        raise ExpressionError("empty label sequence")
    for lab in seq:
        if not 0 <= lab < num_labels:
            raise ExpressionError(
                f"label id {lab} out of range [0, {num_labels})")
    mr = minimum_repeat(seq)
    if len(mr) > k:
        raise ExpressionError(
            f"minimum repeat {mr} has length {len(mr)} > k={k}")
    return PathExpression(raw=repr(seq), labels=seq, mr=mr)
