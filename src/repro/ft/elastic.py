"""Fault tolerance: straggler detection, elastic re-mesh, resilient loop.

At 1000+ nodes failures are routine; the machinery here is the
single-process implementation of the policies DESIGN §5/§8 describes:

* **StragglerMonitor** — per-step wall times; a step slower than
  ``factor x`` the rolling median flags a straggler. On real pods the
  flag triggers data re-sharding away from the slow host (here: recorded
  + surfaced in metrics; the drill test injects delays).
* **ElasticMeshManager** — on device-loss, rebuild the largest valid
  mesh from survivors (shrink the ``data`` axis, keep ``model`` intact —
  TP groups must stay whole), re-shard the train state via device_put,
  and replay from the last checkpoint if the failure hit mid-step.
* **resilient_loop** — checkpoint/restart driver: runs ``train_step``,
  checkpoints every N steps (async), restores after injected failures;
  tests assert bit-identical continuation vs an uninterrupted run.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager

PyTree = Any


class StragglerMonitor:
    def __init__(self, window: int = 16, factor: float = 2.0):
        self.window = window
        self.factor = factor
        self.times: deque = deque(maxlen=window)
        self.flagged: List[Tuple[int, float]] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        is_out = False
        if len(self.times) >= max(4, self.window // 2):
            med = float(np.median(self.times))
            if seconds > self.factor * med:
                self.flagged.append((step, seconds))
                is_out = True
        self.times.append(seconds)
        return is_out


class ElasticMeshManager:
    """Builds the largest (data, model) mesh from surviving devices."""

    def __init__(self, model_parallel: int = 1, axis_names=("data", "model")):
        self.model_parallel = model_parallel
        self.axis_names = axis_names

    def build(self, devices: Optional[List] = None) -> Mesh:
        devices = devices if devices is not None else jax.devices()
        mp = self.model_parallel
        usable = (len(devices) // mp) * mp
        if usable == 0:
            raise RuntimeError(
                f"need >= {mp} devices for a whole TP group; "
                f"have {len(devices)}")
        arr = np.asarray(devices[:usable]).reshape(usable // mp, mp)
        return Mesh(arr, self.axis_names)

    def shrink(self, mesh: Mesh, lost: int) -> Mesh:
        """Simulate losing ``lost`` devices: drop whole data rows."""
        devs = mesh.devices.reshape(-1)
        survivors = list(devs[:len(devs) - lost])
        return self.build(survivors)

    def reshard(self, tree: PyTree, shardings: PyTree) -> PyTree:
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)


@dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_steps: List[int] = field(default_factory=list)
    final_metrics: Dict = field(default_factory=dict)


def resilient_loop(train_step: Callable, state: PyTree,
                   batch_at: Callable[[int], Dict], num_steps: int,
                   ckpt_dir: str, ckpt_every: int = 10,
                   fail_at: Optional[Dict[int, BaseException]] = None,
                   monitor: Optional[StragglerMonitor] = None
                   ) -> Tuple[PyTree, LoopReport]:
    """Checkpoint/restart training driver.

    ``fail_at``: {step: exception} injected AFTER the step computes but
    BEFORE its checkpoint would land — the worst-case window; restart
    resumes from the last durable checkpoint and replays.
    """
    fail_at = dict(fail_at or {})
    mgr = CheckpointManager(ckpt_dir)
    monitor = monitor or StragglerMonitor()
    report = LoopReport()

    restored = mgr.restore_latest(state)
    start = 0
    if restored is not None:
        start, state, _ = restored

    step = start
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            state, metrics = train_step(state, batch_at(step))
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.perf_counter() - t0
            if monitor.record(step, dt):
                report.straggler_steps.append(step)
            if step in fail_at:
                raise fail_at.pop(step)
            step += 1
            report.steps_run += 1
            if step % ckpt_every == 0 or step == num_steps:
                mgr.save_async(step, state, extra={"step": step})
            report.final_metrics = jax.tree.map(
                lambda x: float(np.asarray(x)), metrics)
        except Exception:
            # restart path: restore last durable step and replay
            report.restarts += 1
            mgr.wait()
            restored = mgr.restore_latest(state)
            if restored is None:
                step = 0
            else:
                step, state, _ = restored
                state = jax.tree.map(
                    lambda t, x: jax.numpy.asarray(x, t.dtype)
                    if hasattr(t, "dtype") else x, state, state)
    mgr.wait()
    return state, report
