from .elastic import ElasticMeshManager, StragglerMonitor, resilient_loop

__all__ = ["StragglerMonitor", "ElasticMeshManager", "resilient_loop"]
