"""Telemetry exporters: versioned JSON snapshot + Prometheus text format.

The snapshot schema is a stable contract (``SCHEMA``): benchmark
artifacts embed it, CI parses it, and ``tests/test_obs.py`` freezes its
shape — bump the version string when the shape changes, never mutate it
silently. :func:`validate_snapshot` is the one validator every consumer
(tier-1 guard, ``benchmarks/run.py`` smoke, tests) shares.

Snapshot shape (``repro.obs/1``)::

    {
      "schema": "repro.obs/1",
      "metrics": {
        "<name>": {
          "type": "counter" | "gauge" | "histogram",
          "desc": str, "unit": str, "labels": [str, ...],
          "series": [
            {"labels": {...}, "value": float}                  # counter/gauge
            {"labels": {...}, "count": int, "sum": float,      # histogram
             "min": float, "max": float, "p50": float,
             "p90": float, "p99": float,
             "stored": int, "exact": bool}
          ]
        }, ...
      },
      "tracing": {"sample_rate": float, "traces": int, "skipped": int,
                  "events": int, "dropped": int},      # optional section
      "extra": {...}                                   # optional, free-form
    }

The Prometheus dump follows the text exposition format: counters get a
``_total`` suffix, histograms export as summaries (``{quantile="..."}``
plus ``_sum`` / ``_count``).
"""
from __future__ import annotations

import math
import re
from typing import Optional

__all__ = ["SCHEMA", "snapshot", "validate_snapshot", "to_prometheus",
           "snapshot_to_prometheus"]

SCHEMA = "repro.obs/1"

_HIST_KEYS = {"count", "sum", "min", "max", "p50", "p90", "p99",
              "stored", "exact"}
_TYPES = {"counter", "gauge", "histogram"}


def snapshot(registry, tracer=None, extra: Optional[dict] = None) -> dict:
    """The registry (and optionally tracer/extra) as a schema-versioned,
    JSON-serializable dict."""
    out = dict(schema=SCHEMA, metrics=registry.as_dict())
    if tracer is not None:
        out["tracing"] = tracer.stats()
    if extra is not None:
        out["extra"] = extra
    return out


def validate_snapshot(doc: dict) -> dict:
    """Validate ``doc`` against the ``repro.obs/1`` schema.

    Returns the doc on success; raises ``ValueError`` naming the first
    offending path otherwise. Shared by the tier-1 contract test and the
    benchmark smoke validation — one validator, one truth.
    """
    def fail(path: str, why: str):
        raise ValueError(f"telemetry snapshot invalid at {path}: {why}")

    if not isinstance(doc, dict):
        fail("$", f"expected object, got {type(doc).__name__}")
    if doc.get("schema") != SCHEMA:
        fail("$.schema", f"expected {SCHEMA!r}, got {doc.get('schema')!r}")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        fail("$.metrics", "expected object")
    for name, m in metrics.items():
        p = f"$.metrics[{name!r}]"
        if not isinstance(m, dict):
            fail(p, "expected object")
        if m.get("type") not in _TYPES:
            fail(f"{p}.type", f"expected one of {sorted(_TYPES)}, "
                 f"got {m.get('type')!r}")
        labels = m.get("labels")
        if not isinstance(labels, list) or not all(
                isinstance(x, str) for x in labels):
            fail(f"{p}.labels", "expected list of strings")
        series = m.get("series")
        if not isinstance(series, list):
            fail(f"{p}.series", "expected list")
        for i, s in enumerate(series):
            sp = f"{p}.series[{i}]"
            if not isinstance(s, dict):
                fail(sp, "expected object")
            slab = s.get("labels")
            if not isinstance(slab, dict) or set(slab) != set(labels):
                fail(f"{sp}.labels",
                     f"expected keys {sorted(labels)}, "
                     f"got {sorted(slab) if isinstance(slab, dict) else slab}")
            if m["type"] == "histogram":
                missing = _HIST_KEYS - set(s)
                if missing:
                    fail(sp, f"histogram series missing {sorted(missing)}")
                for k in ("sum", "min", "max", "p50", "p90", "p99"):
                    v = s[k]
                    # bools are ints in python; NaN/inf serialize to
                    # invalid JSON and poison downstream aggregation —
                    # reject both, not just non-numbers
                    if isinstance(v, bool) or not isinstance(
                            v, (int, float)) or not math.isfinite(v):
                        fail(f"{sp}.{k}", f"expected finite number, "
                             f"got {v!r}")
                for k in ("count", "stored"):
                    v = s[k]
                    if isinstance(v, bool) or not isinstance(v, int) \
                            or v < 0:
                        fail(f"{sp}.{k}",
                             f"expected non-negative int, got {v!r}")
                if s["stored"] > s["count"]:
                    fail(f"{sp}.stored", "stored exceeds count")
                if s["count"] >= 1 and s["stored"] == 0:
                    # a reservoir that observed anything keeps at least
                    # one sample; count>0/stored==0 means the series was
                    # assembled by hand or the reservoir was clobbered
                    fail(f"{sp}.stored",
                         "count >= 1 but no stored samples")
            else:
                v = s.get("value")
                if isinstance(v, bool) or not isinstance(
                        v, (int, float)) or not math.isfinite(v):
                    fail(f"{sp}.value",
                         f"expected finite number, got {v!r}")
    tracing = doc.get("tracing")
    if tracing is not None:
        if not isinstance(tracing, dict):
            fail("$.tracing", "expected object")
        for k in ("sample_rate", "traces", "events", "dropped"):
            if not isinstance(tracing.get(k), (int, float)):
                fail(f"$.tracing.{k}", "expected number")
    return doc


# --------------------------------------------------------------------- #
_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    parts = []
    for k, v in sorted(items.items()):
        val = str(v).replace("\\", "\\\\").replace('"', '\\"')
        parts.append(f'{_prom_name(k)}="{val}"')
    return "{" + ",".join(parts) + "}"


def to_prometheus(registry) -> str:
    """The registry in the Prometheus text exposition format."""
    lines = []
    for name, m in sorted(registry.metrics().items()):
        pname = _prom_name(name)
        if m.kind == "counter":
            pname += "_total"
        ptype = "summary" if m.kind == "histogram" else m.kind
        if m.desc:
            lines.append(f"# HELP {pname} {m.desc}")
        lines.append(f"# TYPE {pname} {ptype}")
        for key, cell in sorted(m.series()):
            lab = dict(zip(m.labelnames, key))
            if m.kind == "histogram":
                r = cell.reservoir
                for q in ("0.5", "0.9", "0.99"):
                    v = r.percentile(float(q) * 100)
                    lines.append(
                        f"{pname}{_prom_labels(lab, {'quantile': q})} {v:g}")
                lines.append(f"{pname}_sum{_prom_labels(lab)} {r.total:g}")
                lines.append(f"{pname}_count{_prom_labels(lab)} {r.count}")
            else:
                lines.append(f"{pname}{_prom_labels(lab)} {cell.value:g}")
    return "\n".join(lines) + ("\n" if lines else "")


def snapshot_to_prometheus(doc: dict) -> str:
    """Render a ``repro.obs/1`` snapshot *document* as Prometheus text.

    The offline twin of :func:`to_prometheus`: same exposition rules
    (counters get ``_total``, histograms export as summaries), but fed
    from a serialized snapshot instead of a live registry — the
    ``python -m repro.obs prom`` path that converts an archived bench
    artifact without rebuilding the service that produced it. The doc
    is validated first, so malformed artifacts fail loudly.
    """
    validate_snapshot(doc)
    lines = []
    for name, m in sorted(doc["metrics"].items()):
        pname = _prom_name(name)
        if m["type"] == "counter":
            pname += "_total"
        ptype = "summary" if m["type"] == "histogram" else m["type"]
        if m.get("desc"):
            lines.append(f"# HELP {pname} {m['desc']}")
        lines.append(f"# TYPE {pname} {ptype}")
        for s in m["series"]:
            lab = s["labels"]
            if m["type"] == "histogram":
                for q, k in (("0.5", "p50"), ("0.9", "p90"),
                             ("0.99", "p99")):
                    lines.append(
                        f"{pname}{_prom_labels(lab, {'quantile': q})} "
                        f"{s[k]:g}")
                lines.append(f"{pname}_sum{_prom_labels(lab)} "
                             f"{s['sum']:g}")
                lines.append(f"{pname}_count{_prom_labels(lab)} "
                             f"{s['count']}")
            else:
                lines.append(
                    f"{pname}{_prom_labels(lab)} {s['value']:g}")
    return "\n".join(lines) + ("\n" if lines else "")
