"""``python -m repro.obs`` — offline telemetry artifact tooling.

Benchmark runs leave ``repro.obs/1`` snapshots (embedded in bench JSON
under ``telemetry`` keys or standalone), Chrome ``trace_event`` dumps,
and ``repro.obs.audit/1`` health reports on disk; this CLI inspects
them without rebuilding the service that produced them::

    python -m repro.obs validate benchmarks/artifacts/service.json
    python -m repro.obs dump     snapshot.json
    python -m repro.obs prom     snapshot.json > metrics.prom
    python -m repro.obs chrome   benchmarks/artifacts/service_trace.json
    python -m repro.obs audit    benchmarks/artifacts/sharded.json

``validate`` walks the whole document for embedded snapshots and audit
reports and validates each (exit 0 all valid / 1 any invalid), so one
invocation covers a raw snapshot, a bench artifact, or an audit report.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Tuple

from .audit import AUDIT_SCHEMA, validate_audit_report
from .export import SCHEMA, snapshot_to_prometheus, validate_snapshot

_USAGE_EXIT = 2


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _find_docs(doc, path: str = "$") -> List[Tuple[str, str, dict]]:
    """Every embedded versioned document: ``(json_path, schema, doc)``.

    Bench artifacts nest snapshots several levels deep (e.g.
    ``results.telemetry.snapshot``); walking by schema string finds them
    wherever the artifact shape puts them.
    """
    found = []
    if isinstance(doc, dict):
        schema = doc.get("schema")
        if schema in (SCHEMA, AUDIT_SCHEMA):
            found.append((path, schema, doc))
        for k, v in doc.items():
            found.extend(_find_docs(v, f"{path}.{k}"))
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            found.extend(_find_docs(v, f"{path}[{i}]"))
    return found


def _is_chrome_trace(doc) -> bool:
    return isinstance(doc, dict) and isinstance(
        doc.get("traceEvents"), list)


def _validate_one(path: str, schema: str, doc: dict) -> Optional[str]:
    try:
        if schema == SCHEMA:
            validate_snapshot(doc)
        else:
            validate_audit_report(doc)
        return None
    except ValueError as e:
        return str(e)


def cmd_validate(args) -> int:
    doc = _load(args.file)
    if _is_chrome_trace(doc):
        bad = [e for e in doc["traceEvents"]
               if not isinstance(e, dict) or "ph" not in e]
        if bad:
            print(f"INVALID chrome trace: {len(bad)} malformed events")
            return 1
        print(f"OK chrome trace: {len(doc['traceEvents'])} events")
        return 0
    docs = _find_docs(doc)
    if not docs:
        print(f"no {SCHEMA!r} snapshots or {AUDIT_SCHEMA!r} reports "
              f"found in {args.file}")
        return 1
    failures = 0
    for path, schema, d in docs:
        err = _validate_one(path, schema, d)
        if err is None:
            print(f"OK {schema} at {path}")
        else:
            failures += 1
            print(f"INVALID {schema} at {path}: {err}")
    return 1 if failures else 0


def _first_snapshot(doc, path: str):
    for p, schema, d in _find_docs(doc):
        if schema == SCHEMA:
            return p, d
    print(f"no {SCHEMA!r} snapshot found in {path}")
    return None, None


def cmd_dump(args) -> int:
    p, snap = _first_snapshot(_load(args.file), args.file)
    if snap is None:
        return 1
    validate_snapshot(snap)
    print(f"snapshot at {p}")
    for name, m in sorted(snap["metrics"].items()):
        print(f"  {name} ({m['type']}, {len(m['series'])} series)")
        for s in m["series"]:
            lab = ",".join(f"{k}={v}" for k, v in
                           sorted(s["labels"].items())) or "-"
            if m["type"] == "histogram":
                print(f"    [{lab}] count={s['count']} sum={s['sum']:g} "
                      f"p50={s['p50']:g} p99={s['p99']:g}")
            else:
                print(f"    [{lab}] value={s['value']:g}")
    tracing = snap.get("tracing")
    if tracing:
        print(f"  tracing: {tracing}")
    extra = snap.get("extra")
    if extra:
        print(f"  extra keys: {sorted(extra)}")
    return 0


def cmd_prom(args) -> int:
    _, snap = _first_snapshot(_load(args.file), args.file)
    if snap is None:
        return 1
    sys.stdout.write(snapshot_to_prometheus(snap))
    return 0


def cmd_chrome(args) -> int:
    doc = _load(args.file)
    if not _is_chrome_trace(doc):
        print(f"{args.file} is not a Chrome trace_event document")
        return 1
    events = doc["traceEvents"]
    cats: dict = {}
    for e in events:
        if e.get("ph") == "X":
            cats[e.get("cat", "-")] = cats.get(e.get("cat", "-"), 0) + 1
    print(f"chrome trace: {len(events)} events")
    for cat, n in sorted(cats.items()):
        print(f"  {cat}: {n} spans")
    return 0


def cmd_audit(args) -> int:
    reports = [(p, d) for p, schema, d in _find_docs(_load(args.file))
               if schema == AUDIT_SCHEMA]
    if not reports:
        print(f"no {AUDIT_SCHEMA!r} report found in {args.file}")
        return 1
    rc = 0
    for p, rep in reports:
        err = _validate_one(p, AUDIT_SCHEMA, rep)
        if err is not None:
            print(f"INVALID audit report at {p}: {err}")
            rc = 1
            continue
        ident = rep["identity"]
        print(f"audit report at {p}")
        print(f"  index: V={ident['num_vertices']} k={ident['k']} "
              f"entries={ident['entries']} "
              f"(out={ident['entries_out']} in={ident['entries_in']}) "
              f"max_row={ident['max_row']}")
        red = rep["redundancy"]
        print(f"  redundancy: {red['violations']}/{red['sampled']} "
              f"violations")
        snd = rep.get("soundness")
        if snd is not None:
            print(f"  soundness: {snd['violations']}/{snd['sampled']} "
                  f"violations")
        by = rep["bytes"]
        parts = ", ".join(f"{k}={v}" for k, v in by.items()
                          if v is not None)
        print(f"  bytes: {parts}")
        print(f"  fingerprint: {rep['fingerprint']['combined']}")
        for sh in rep.get("shards", []):
            print(f"  shard {sh['shard']}: rows [{sh['lo']}, "
                  f"{sh['hi']}) entries={sh['entries']} "
                  f"frozen={sh['frozen_bytes']}B")
    return rc


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="inspect/convert repro.obs telemetry artifacts")
    sub = ap.add_subparsers(dest="cmd")
    for name, fn, help_ in (
            ("validate", cmd_validate,
             "validate every embedded snapshot/audit report"),
            ("dump", cmd_dump, "pretty-print a snapshot's metrics"),
            ("prom", cmd_prom,
             "convert a snapshot to Prometheus text format"),
            ("chrome", cmd_chrome, "summarize a Chrome trace dump"),
            ("audit", cmd_audit, "pretty-print embedded audit reports")):
        p = sub.add_parser(name, help=help_)
        p.add_argument("file", help="JSON artifact to read")
        p.set_defaults(fn=fn)
    args = ap.parse_args(argv)
    if not getattr(args, "fn", None):
        ap.print_help()
        return _USAGE_EXIT
    try:
        return args.fn(args)
    except FileNotFoundError as e:
        print(f"error: {e}")
        return _USAGE_EXIT
    except (json.JSONDecodeError, ValueError) as e:
        print(f"INVALID: {e}")
        return 1


if __name__ == "__main__":
    sys.exit(main())
