"""Metrics registry: counters, gauges, bounded-reservoir histograms.

One registry per serving/build stack (see :class:`repro.obs.Observability`)
is the single sink every layer reports into — ``RLCService``,
``ShardedRLCService``, ``BatchExecutor``, ``ResultCache``,
``MicroBatcher``, the router/fanout/replica layers, and the build/delta
engines. The design constraints, in order:

* **off-hot-path cheap** — the read/serve path takes no locks: all
  mutation is single ``+=`` / list-append operations on pre-bound cells
  (GIL-atomic for our counters; the only writers that can interleave are
  the deadline-ticker thread and the caller, and a lost increment under
  that interleaving is an acceptable telemetry error, never a serving
  error). Call sites bind their label cells once at construction time
  (:meth:`Metric.labels`), so the per-event cost is one attribute add —
  no dict lookup, no string formatting.
* **bounded memory** — histograms store at most ``reservoir_cap``
  samples (:class:`Reservoir`): exact percentiles below the cap,
  uniform reservoir sampling (Algorithm R, deterministically seeded)
  above it, while ``count``/``sum``/``min``/``max`` stay exact forever.
  This is what replaces the grow-forever ``samples_s`` list the old
  ``LatencyRecorder`` kept.
* **labeled series** — a metric is a family; concrete series carry
  label values (backend, shard, cache outcome, MR-length bucket, ...)
  fixed per call site.

Naming convention (see ``src/repro/obs/README.md`` for the taxonomy):
``rlc_<layer>_<what>[_<unit>]``, snake_case, Prometheus-safe as-is.
"""
from __future__ import annotations

import random
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Metric", "MetricsRegistry",
    "NullRegistry", "Reservoir", "NULL_REGISTRY",
]

_QUANTILES = (0.5, 0.9, 0.99)


class Reservoir:
    """Bounded sample store with exact-below-cap percentiles.

    Up to ``cap`` observations are stored verbatim, so percentiles are
    exact. Past the cap, Algorithm-R uniform reservoir sampling keeps a
    statistically representative ``cap``-sized subset (percentiles become
    estimates); ``count`` / ``total`` / ``vmin`` / ``vmax`` are always
    exact. The RNG is seeded deterministically so two identical runs
    produce identical snapshots.
    """

    __slots__ = ("cap", "count", "total", "vmin", "vmax", "samples", "_rng")

    def __init__(self, cap: int = 2048, seed: int = 0):
        if cap < 1:
            raise ValueError(f"reservoir cap must be >= 1, got {cap}")
        self.cap = int(cap)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.samples: List[float] = []
        self._rng = random.Random(seed)

    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if len(self.samples) < self.cap:
            self.samples.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self.samples[j] = v

    @property
    def exact(self) -> bool:
        """True while no observation has been dropped (percentiles exact)."""
        return self.count <= self.cap

    def percentile(self, p: float) -> float:
        """p in [0, 100]; 0.0 when empty (matching the old recorder)."""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        if len(xs) == 1:
            return xs[0]
        # linear interpolation between closest ranks (numpy default)
        rank = (p / 100.0) * (len(xs) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> dict:
        empty = self.count == 0
        return dict(
            count=self.count,
            sum=self.total,
            min=0.0 if empty else self.vmin,
            max=0.0 if empty else self.vmax,
            p50=self.percentile(50),
            p90=self.percentile(90),
            p99=self.percentile(99),
            stored=len(self.samples),
            exact=self.exact,
        )


# --------------------------------------------------------------------- #
# Cells: the pre-bound per-series handles call sites mutate.
# --------------------------------------------------------------------- #
class CounterCell:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class GaugeCell:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class HistogramCell:
    __slots__ = ("reservoir",)

    def __init__(self, cap: int, seed: int):
        self.reservoir = Reservoir(cap, seed)

    def observe(self, v: float) -> None:
        self.reservoir.add(v)


_CELL_FACTORY = {
    "counter": lambda m: CounterCell(),
    "gauge": lambda m: GaugeCell(),
    "histogram": lambda m: HistogramCell(
        m._reservoir_cap, len(m._series)),
}


class Metric:
    """One named metric family; concrete series are keyed by label values.

    ``labels(**kv)`` binds (get-or-create) the cell for one label
    combination — call it once at construction time and keep the cell.
    The label-free shorthand mutators (:meth:`inc` / :meth:`set` /
    :meth:`observe`) accept inline labels for cold paths.
    """

    __slots__ = ("name", "kind", "desc", "unit", "labelnames", "_series",
                 "_reservoir_cap", "_default")

    def __init__(self, name: str, kind: str, desc: str = "",
                 unit: str = "", labelnames: Tuple[str, ...] = (),
                 reservoir_cap: int = 2048):
        self.name = name
        self.kind = kind
        self.desc = desc
        self.unit = unit
        self.labelnames = tuple(labelnames)
        self._series: Dict[Tuple[str, ...], object] = {}
        self._reservoir_cap = reservoir_cap
        self._default = None        # cached cell for the no-label case

    def labels(self, **kv):
        """The cell for one label-value combination (created on first use).
        Every declared label name must be supplied, no extras."""
        if tuple(kv) != self.labelnames and set(kv) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(kv)}")
        key = tuple(str(kv[n]) for n in self.labelnames)
        cell = self._series.get(key)
        if cell is None:
            cell = self._series[key] = _CELL_FACTORY[self.kind](self)
        return cell

    def _cell(self, kv: dict):
        if not kv and not self.labelnames:
            if self._default is None:
                self._default = self.labels()
            return self._default
        return self.labels(**kv)

    # -- cold-path conveniences ----------------------------------------- #
    def inc(self, n: float = 1.0, **kv) -> None:
        self._cell(kv).inc(n)

    def set(self, v: float, **kv) -> None:
        self._cell(kv).set(v)

    def observe(self, v: float, **kv) -> None:
        self._cell(kv).observe(v)

    # -- introspection --------------------------------------------------- #
    def series(self) -> Iterable[Tuple[Tuple[str, ...], object]]:
        return self._series.items()

    def value(self, **kv) -> float:
        """Counter/gauge read-back (0.0 for a never-touched series)."""
        key = tuple(str(kv[n]) for n in self.labelnames)
        cell = self._series.get(key)
        return cell.value if cell is not None else 0.0

    def as_dict(self) -> dict:
        out = dict(type=self.kind, desc=self.desc, unit=self.unit,
                   labels=list(self.labelnames), series=[])
        for key, cell in sorted(self._series.items()):
            lab = dict(zip(self.labelnames, key))
            if self.kind == "histogram":
                out["series"].append(dict(labels=lab,
                                          **cell.reservoir.summary()))
            else:
                out["series"].append(dict(labels=lab, value=cell.value))
        return out


# kind-specific aliases so registrations read naturally
class Counter(Metric):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)


class Gauge(Metric):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)


class Histogram(Metric):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)


class MetricsRegistry:
    """Name -> :class:`Metric` with idempotent get-or-create registration.

    Re-registering an existing name returns the existing metric when kind
    and labels agree, and raises otherwise (two call sites silently
    writing incompatible series to one name is how taxonomies rot).
    """

    def __init__(self, reservoir_cap: int = 2048):
        self.reservoir_cap = int(reservoir_cap)
        self._metrics: Dict[str, Metric] = {}

    def _register(self, name: str, kind: str, desc: str, unit: str,
                  labelnames: Tuple[str, ...]) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind} with "
                    f"labels {m.labelnames}; cannot re-register as {kind} "
                    f"with {tuple(labelnames)}")
            return m
        m = Metric(name, kind, desc, unit, tuple(labelnames),
                   self.reservoir_cap)
        self._metrics[name] = m
        return m

    def counter(self, name: str, desc: str = "", unit: str = "1",
                labelnames: Tuple[str, ...] = ()) -> Metric:
        return self._register(name, "counter", desc, unit, labelnames)

    def gauge(self, name: str, desc: str = "", unit: str = "1",
              labelnames: Tuple[str, ...] = ()) -> Metric:
        return self._register(name, "gauge", desc, unit, labelnames)

    def histogram(self, name: str, desc: str = "", unit: str = "s",
                  labelnames: Tuple[str, ...] = ()) -> Metric:
        return self._register(name, "histogram", desc, unit, labelnames)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def metrics(self) -> Dict[str, Metric]:
        return dict(self._metrics)

    def as_dict(self) -> dict:
        return {name: m.as_dict()
                for name, m in sorted(self._metrics.items())}


# --------------------------------------------------------------------- #
# Null objects: telemetry-off mode keeps every call site branch-free.
# --------------------------------------------------------------------- #
class _NullCell:
    __slots__ = ()
    value = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL_CELL = _NullCell()


class _NullMetric:
    __slots__ = ()
    name = ""
    kind = "null"
    labelnames = ()

    def labels(self, **kv):
        return _NULL_CELL

    def inc(self, n: float = 1.0, **kv) -> None:
        pass

    def set(self, v: float, **kv) -> None:
        pass

    def observe(self, v: float, **kv) -> None:
        pass

    def value(self, **kv) -> float:
        return 0.0

    def series(self):
        return ()

    def as_dict(self) -> dict:
        return dict(type="null", series=[])


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Accepts every registration, records nothing."""

    reservoir_cap = 0

    def counter(self, name, desc="", unit="1", labelnames=()):
        return _NULL_METRIC

    def gauge(self, name, desc="", unit="1", labelnames=()):
        return _NULL_METRIC

    def histogram(self, name, desc="", unit="s", labelnames=()):
        return _NULL_METRIC

    def get(self, name):
        return None

    def metrics(self):
        return {}

    def as_dict(self) -> dict:
        return {}


NULL_REGISTRY = NullRegistry()
