"""Build/delta instrumentation: per-(hub, direction) phase accounting.

Algorithm 2 runs one phase per ``(hub, direction)``; the existing
:class:`repro.build.base.PhaseProbe` records each phase's traversal
*footprint* — this module adds the missing *cost* axis: wall time and
pruning-counter deltas per phase, aggregated into registry series (raw
per-phase lists would be O(2V) memory) plus an exact top-N of the
slowest phases, which is where "why did this build take 40s" answers
live.

The observer attaches to any :class:`repro.build.base.BuildBackend` via
``set_observer`` (or ``build_rlc_index_with_stats(..., observer=...)``);
the batched backends call it from :meth:`PhaseRunner.run`, the python
reference from its own hub loop, and the delta engine from both its
traced full builds and its dirty-phase re-runs — so delta re-run phases
land in the same series as full-build phases, labeled apart.
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

__all__ = ["BuildPhaseObserver"]

#: order must match repro.build.base.BuildStats._COUNTERS
_COUNTER_NAMES = ("kernel_search_states", "kernel_bfs_states", "inserted",
                  "pruned_pr1", "pruned_pr2", "pr3_cuts")


class BuildPhaseObserver:
    """Sink for per-phase build telemetry.

    ``context`` labels where the phases came from: ``"full"`` for a
    from-scratch build, ``"delta"`` for dirty-phase re-runs inside an
    incremental apply. Memory is bounded: aggregates + a ``top_n`` heap.
    """

    def __init__(self, registry, context: str = "full", top_n: int = 8):
        self.registry = registry
        self.context = context
        self.top_n = int(top_n)
        self._slowest: List[Tuple[float, int, str]] = []   # min-heap
        hist = registry.histogram(
            "rlc_build_phase_seconds",
            desc="wall time of one (hub, direction) Algorithm 2 phase",
            unit="s", labelnames=("context", "direction"))
        self._phase_s = {True: hist.labels(context=context, direction="in"),
                         False: hist.labels(context=context,
                                            direction="out")}
        phases = registry.counter(
            "rlc_build_phases", desc="Algorithm 2 phases executed",
            labelnames=("context", "direction"))
        self._phases = {True: phases.labels(context=context, direction="in"),
                        False: phases.labels(context=context,
                                             direction="out")}
        ctr = registry.counter(
            "rlc_build_counter_deltas",
            desc="per-phase BuildStats counter shares",
            labelnames=("context", "counter"))
        self._counters = [ctr.labels(context=context, counter=n)
                          for n in _COUNTER_NAMES]
        self._builds = registry.counter(
            "rlc_build_runs", desc="completed index builds",
            labelnames=("context", "backend"))
        self._build_s = registry.histogram(
            "rlc_build_seconds", desc="end-to-end index build wall time",
            unit="s", labelnames=("context", "backend"))

    # -- called per phase (hot during builds, never during serving) ----- #
    def phase(self, hub: int, backward: bool, seconds: float,
              counter_delta: Optional[Tuple[int, ...]] = None) -> None:
        self._phase_s[backward].observe(seconds)
        self._phases[backward].inc()
        if counter_delta is not None:
            for cell, d in zip(self._counters, counter_delta):
                if d:
                    cell.inc(d)
        direction = "in" if backward else "out"
        item = (seconds, int(hub), direction)
        if len(self._slowest) < self.top_n:
            heapq.heappush(self._slowest, item)
        elif item > self._slowest[0]:
            heapq.heapreplace(self._slowest, item)

    # -- parallel-build series (created lazily: they only exist when the
    # -- parallel backend actually ran, so sequential snapshots stay
    # -- unchanged) ------------------------------------------------------ #
    def _parallel_cells(self):
        cells = getattr(self, "_par", None)
        if cells is None:
            r, ctx = self.registry, self.context
            cells = self._par = dict(
                epochs=r.counter(
                    "rlc_build_epochs",
                    desc="parallel build epoch/merge rounds",
                    labelnames=("context",)).labels(context=ctx),
                stale=r.counter(
                    "rlc_build_stale_reruns",
                    desc="phases re-run after a stale snapshot "
                         "fingerprint",
                    labelnames=("context",)).labels(context=ctx),
                epoch_s=r.histogram(
                    "rlc_build_epoch_seconds",
                    desc="wall time of one dispatch+merge epoch",
                    unit="s", labelnames=("context",)).labels(
                        context=ctx),
                worker_s=r.histogram(
                    "rlc_build_worker_phase_seconds",
                    desc="committed phase wall time, by the worker "
                         "that ran it (parent = stale re-run)",
                    unit="s", labelnames=("context", "worker")),
                worker_cells={})
        return cells

    def epoch(self, seconds: float, phases: int = 0,
              stale_reruns: int = 0) -> None:
        """One parallel-build epoch boundary: the merged-in view of the
        per-worker registries (workers report raw phase data; this
        parent registry is the single snapshot surface)."""
        cells = self._parallel_cells()
        cells["epochs"].inc()
        cells["epoch_s"].observe(seconds)
        if stale_reruns:
            cells["stale"].inc(stale_reruns)

    def worker_phase(self, worker: str, seconds: float) -> None:
        """A committed phase's wall time attributed to the worker that
        produced it (``"parent"`` for coordinator stale re-runs)."""
        cells = self._parallel_cells()
        cell = cells["worker_cells"].get(worker)
        if cell is None:
            cell = cells["worker_cells"][worker] = cells[
                "worker_s"].labels(context=self.context, worker=worker)
        cell.observe(seconds)

    # -- called once per completed build -------------------------------- #
    def build_done(self, backend: str, wall_time_s: float) -> None:
        self._builds.inc(1, context=self.context, backend=backend)
        self._build_s.observe(wall_time_s, context=self.context,
                              backend=backend)

    def slowest_phases(self) -> List[dict]:
        """The top-N slowest phases, slowest first (snapshot ``extra``)."""
        return [dict(hub=h, direction=d, seconds=round(s, 6))
                for s, h, d in sorted(self._slowest, reverse=True)]
