"""Continuous shadow verification: sampled oracle re-execution.

"Bit-identical in tests" is a build-time claim; the shadow verifier
turns it into a *continuously monitored serving invariant*. A configured
fraction of answered queries (``ServiceConfig.shadow_sample_rate``) is
banked on the hot path — one RNG draw and a bounded deque append — and
re-executed later against the BiBFS product-automaton oracle
(:func:`repro.core.baselines.bibfs_rlc`), off the serving path:

* synchronously at the drain points (``service.drain_shadow()``,
  ``telemetry_snapshot()``), or
* on a daemon thread (``ServiceConfig.shadow_background``) that chips
  away at the pending queue between queries.

Every check lands in the ``rlc_shadow_checked`` / ``rlc_shadow_divergent``
counters; a divergence additionally captures a full EXPLAIN bundle
(:meth:`RLCService.explain` — backend, cache disposition, witness, plus
the oracle's answer) so the first diverging query arrives with its own
debugging record attached (see ``src/repro/obs/README.md``,
"debugging a divergence").

Mutations invalidate pending work: ``apply_delta`` / ``hot_swap`` call
:meth:`ShadowVerifier.discard_pending`, because an answer that was
correct against the pre-delta graph may legitimately differ from the
post-delta oracle — verifying across the mutation would manufacture
false divergences.
"""
from __future__ import annotations

import random
import threading
from collections import deque
from typing import Deque, List, Optional, Tuple

__all__ = ["ShadowVerifier", "attach_shadow"]


class ShadowVerifier:
    """Sampling re-verifier bound to one serving stack.

    ``service`` is duck-typed: it must expose ``graph``, ``_id_to_mr``,
    and ``explain(s, t, constraint)`` — both :class:`RLCService` and
    :class:`ShardedRLCService` qualify.
    """

    def __init__(self, service, sample_rate: float,
                 max_pending: int = 1024, max_bundles: int = 8,
                 seed: int = 0, obs=None):
        if not (0.0 <= sample_rate <= 1.0):
            raise ValueError(
                f"sample_rate must be in [0, 1], got {sample_rate}")
        self.service = service
        self.sample_rate = float(sample_rate)
        self.max_pending = int(max_pending)
        self.max_bundles = int(max_bundles)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        # (epoch, s, t, mr_id, served_answer)
        self._pending: Deque[Tuple[int, int, int, int, bool]] = deque()
        self._epoch = 0
        self.offered = 0
        self.checked = 0
        self.divergent = 0
        self.dropped = 0
        self.discarded = 0
        self.divergences: List[dict] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        from repro.obs import NULL_OBS
        reg = (obs or NULL_OBS).registry
        self._m_offered = reg.counter(
            "rlc_shadow_offered",
            desc="answered queries sampled into the shadow queue").labels()
        self._m_checked = reg.counter(
            "rlc_shadow_checked",
            desc="shadow queries re-executed against the BiBFS "
                 "oracle").labels()
        self._m_divergent = reg.counter(
            "rlc_shadow_divergent",
            desc="shadow checks where the served answer disagreed with "
                 "the oracle").labels()
        self._m_dropped = reg.counter(
            "rlc_shadow_dropped",
            desc="sampled queries dropped because the pending queue was "
                 "full").labels()
        self._m_pending = reg.gauge(
            "rlc_shadow_pending",
            desc="shadow checks awaiting verification").labels()

    # -- hot path ------------------------------------------------------- #
    def offer(self, s: int, t: int, mr_id: int, answer: bool) -> bool:
        """Maybe bank one answered query for later verification.

        Cheap enough for the serve loop: one RNG draw, and on a sampled
        query a locked deque append (bounded — the oldest pending entry
        is dropped, and counted, rather than growing without bound)."""
        if self._rng.random() >= self.sample_rate:
            return False
        with self._lock:
            self.offered += 1
            self._m_offered.inc()
            if len(self._pending) >= self.max_pending:
                self._pending.popleft()
                self.dropped += 1
                self._m_dropped.inc()
            self._pending.append(
                (self._epoch, int(s), int(t), int(mr_id), bool(answer)))
            self._m_pending.set(len(self._pending))
        return True

    # -- mutation fence ------------------------------------------------- #
    def discard_pending(self) -> int:
        """Drop every pending check and advance the epoch — called around
        graph/index mutations so stale offers never verify against a
        graph they were not served from."""
        with self._lock:
            n = len(self._pending)
            self._pending.clear()
            self._epoch += 1
            self.discarded += n
            self._m_pending.set(0)
        return n

    # -- verification (off the hot path) -------------------------------- #
    def run_pending(self, limit: Optional[int] = None) -> int:
        """Verify up to ``limit`` pending checks (all when None);
        returns how many ran."""
        from repro.core.baselines import bibfs_rlc
        ran = 0
        while limit is None or ran < limit:
            with self._lock:
                if not self._pending:
                    break
                epoch, s, t, mr_id, answer = self._pending.popleft()
                self._m_pending.set(len(self._pending))
                stale = epoch != self._epoch
            if stale:
                continue
            mr = self.service._id_to_mr[mr_id]
            oracle = bool(bibfs_rlc(self.service.graph, s, t, mr))
            self.checked += 1
            self._m_checked.inc()
            if oracle != answer:
                self.divergent += 1
                self._m_divergent.inc()
                self._capture(s, t, mr, answer, oracle)
            ran += 1
        return ran

    def drain(self) -> int:
        """Verify everything pending now (the synchronous drain point)."""
        return self.run_pending(None)

    def _capture(self, s, t, mr, answer, oracle) -> None:
        if len(self.divergences) >= self.max_bundles:
            return
        try:
            bundle = self.service.explain(s, t, mr)
        except Exception as e:  # noqa: BLE001 — the capture must not
            # crash verification; record what we know instead
            bundle = dict(s=s, t=t, mr=list(mr), error=repr(e))
        bundle["served_answer"] = bool(answer)
        bundle["oracle"] = bool(oracle)
        self.divergences.append(bundle)

    # -- background mode ------------------------------------------------ #
    def start(self, interval_s: float = 0.02, chunk: int = 64) -> None:
        """Verify on a daemon thread: every ``interval_s`` it runs up to
        ``chunk`` pending checks, keeping oracle work off every caller."""
        if self._thread is not None:
            raise RuntimeError("shadow verifier already running")

        def loop():
            while not self._stop.wait(interval_s):
                self.run_pending(chunk)

        self._stop.clear()
        self._thread = threading.Thread(
            target=loop, name="shadow-verifier", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        thread, self._thread = self._thread, None
        if thread is None:
            return
        self._stop.set()
        thread.join()

    @property
    def running(self) -> bool:
        return self._thread is not None

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        with self._lock:
            pending = len(self._pending)
        return dict(sample_rate=self.sample_rate, offered=self.offered,
                    checked=self.checked, divergent=self.divergent,
                    dropped=self.dropped, discarded=self.discarded,
                    pending=pending, divergences=len(self.divergences),
                    background=self.running)


def attach_shadow(service) -> Optional[ShadowVerifier]:
    """Construct (and maybe start) the verifier a service's config asks
    for; ``None`` when ``shadow_sample_rate`` is 0 so the serve loop
    stays branch-predictable."""
    cfg = service.config
    if cfg.shadow_sample_rate <= 0.0:
        return None
    sv = ShadowVerifier(service, cfg.shadow_sample_rate,
                        max_pending=cfg.shadow_max_pending,
                        obs=service.obs)
    if cfg.shadow_background:
        sv.start()
    return sv
