"""Index-health auditor: a versioned report over a live RLC index.

The paper's offline guarantees — soundness of every entry (an entry *is*
a reachability fact), non-redundancy under PR1-PR3 (Definition 5,
"condensed"), and the frozen/device layouts mirroring the dict index
bit-for-bit — are proven at build time and then silently assumed while
deltas, hot swaps, and parallel rebuilds mutate the serving state. The
auditor re-measures them on demand:

* **entry histograms** — entries per hub-rank decile (aid order), per
  MR length, per label, per direction: the shape the shard planner and
  the ROADMAP item-5 cache warmers read;
* **redundancy re-verification** — Definition 5 re-checked on a sample
  of frozen rows (a violation means a pruning rule was bypassed, e.g.
  by a buggy delta replay);
* **soundness probes** — entry-derived queries replayed against the
  BiBFS oracle when a graph is supplied;
* **byte accounting** — dict index / frozen CSR / bit mirror / device
  layout, the memory story of one serving stack;
* **drift fingerprints** — a CRC over the frozen layout plus a 64-way
  row-bucket sketch, so "delta-applied equals rebuilt" becomes a
  comparable artifact instead of a test-only assertion, and a drifting
  bucket localizes *which* rows diverged.

Reports are versioned (:data:`AUDIT_SCHEMA`), validated by
:func:`validate_audit_report` (tests, the benchmark smoke gate, and the
``python -m repro.obs audit`` CLI all share it), surfaced through the
``repro.obs/1`` snapshot ``extra`` section, and banked as gauges for
the Prometheus export (:func:`bank_audit_metrics`).
"""
from __future__ import annotations

import zlib
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["AUDIT_SCHEMA", "audit_index", "bank_audit_metrics",
           "device_nbytes", "fingerprint", "frozen_nbytes",
           "validate_audit_report"]

AUDIT_SCHEMA = "repro.obs.audit/1"

_N_BUCKETS = 64     # row-fingerprint sketch width
_N_DECILES = 10


# --------------------------------------------------------------------- #
# byte accounting helpers
# --------------------------------------------------------------------- #
def frozen_nbytes(frozen) -> int:
    """Real allocation of a frozen CSR layout (vs the paper-comparable
    ``size_bytes`` which counts 4 + k bytes per logical entry)."""
    return int(sum(a.nbytes for a in (
        frozen.out_indptr, frozen.out_hub, frozen.out_mr,
        frozen.in_indptr, frozen.in_hub, frozen.in_mr)))


def device_nbytes(device_index) -> Optional[int]:
    """Padded device-layout allocation (hub/mr/sorted-key arrays)."""
    if device_index is None:
        return None
    total = 0
    for name in ("out_hub", "out_mr", "in_hub", "in_mr",
                 "out_key", "in_key"):
        arr = getattr(device_index, name, None)
        if arr is not None:
            total += int(arr.nbytes)
    return total


# --------------------------------------------------------------------- #
# drift fingerprint
# --------------------------------------------------------------------- #
def fingerprint(frozen) -> dict:
    """CRC fingerprint of a frozen layout, with a per-row bucket sketch.

    ``combined`` hashes every entry array (hubs, MR ids, row boundaries
    — byte-identical layouts, and only those, fingerprint equal, which
    is exactly the delta-vs-rebuild bit-identical guarantee). The
    ``row_buckets_*`` sketches XOR each vertex row's CRC into bucket
    ``v % 64``: when two fingerprints drift, the differing buckets name
    the residue classes of the diverging rows, narrowing a full-index
    diff ~64x before anyone has to walk entries.
    """
    def row_crcs(indptr, hub, mr):
        buckets = [0] * _N_BUCKETS
        for v in range(len(indptr) - 1):
            lo, hi = int(indptr[v]), int(indptr[v + 1])
            c = zlib.crc32(hub[lo:hi].tobytes())
            c = zlib.crc32(mr[lo:hi].tobytes(), c)
            buckets[v % _N_BUCKETS] ^= c
        return buckets

    combined = 0
    for a in (frozen.out_indptr, frozen.out_hub, frozen.out_mr,
              frozen.in_indptr, frozen.in_hub, frozen.in_mr):
        combined = zlib.crc32(np.ascontiguousarray(a).tobytes(), combined)
    return dict(
        combined=f"{combined:08x}",
        entries=int(frozen.num_entries()),
        row_buckets_out=row_crcs(frozen.out_indptr, frozen.out_hub,
                                 frozen.out_mr),
        row_buckets_in=row_crcs(frozen.in_indptr, frozen.in_hub,
                                frozen.in_mr),
    )


# --------------------------------------------------------------------- #
# the auditor
# --------------------------------------------------------------------- #
def _hub_rank_deciles(hub: np.ndarray, aid: np.ndarray,
                      num_vertices: int) -> List[int]:
    """Entry counts per aid-rank decile of the entry's hub — the
    hub-concentration profile (paper §V: high-rank hubs should carry
    most entries; a flat profile means the access order degraded)."""
    if len(hub) == 0:
        return [0] * _N_DECILES
    # aid is 1-based; decile by rank fraction of the vertex space
    rank = (np.asarray(aid)[hub] - 1).astype(np.float64)
    dec = np.minimum((rank * _N_DECILES // max(num_vertices, 1)),
                     _N_DECILES - 1).astype(np.int64)
    return np.bincount(dec, minlength=_N_DECILES).tolist()


def _redundant(frozen, s: int, t: int, mr_id: int) -> bool:
    """Definition 5 on frozen rows: the direct fact ``s ~mr+~> t`` is
    also derivable through a third hub."""
    oh, om = frozen.row_out(s)
    ih, im = frozen.row_in(t)
    o = set(oh[om == mr_id].tolist()) - {s, t}
    i = set(ih[im == mr_id].tolist()) - {s, t}
    return bool(o & i)


def audit_index(frozen, id_to_mr: Sequence, index=None, graph=None,
                device_index=None, sample: int = 128,
                seed: int = 0) -> dict:
    """Audit one serving index; returns an :data:`AUDIT_SCHEMA` report.

    ``frozen`` drives everything; ``index`` (dict layout) adds mirror
    byte accounting, ``device_index`` adds device bytes, ``graph`` turns
    on the oracle soundness probes. ``sample`` bounds both the
    redundancy re-check (entries examined) and the soundness probes
    (oracle replays) so an audit stays cheap on big indexes.
    """
    rng = np.random.default_rng(seed)
    n = frozen.num_vertices
    out_n, in_n = len(frozen.out_hub), len(frozen.in_hub)

    # -- histograms ---------------------------------------------------- #
    def mr_len_hist(mr: np.ndarray) -> dict:
        lens = np.array([len(id_to_mr[c]) for c in range(len(id_to_mr))],
                        dtype=np.int64)
        counts = np.bincount(mr, minlength=len(id_to_mr)) \
            if len(mr) else np.zeros(len(id_to_mr), np.int64)
        out = {}
        for ln in range(1, int(frozen.k) + 1):
            out[str(ln)] = int(counts[lens == ln].sum())
        return out

    label_counts: dict = {}
    all_mr = np.concatenate([frozen.out_mr, frozen.in_mr]) \
        if out_n + in_n else np.zeros(0, np.int64)
    mr_counts = np.bincount(all_mr, minlength=len(id_to_mr)) \
        if len(all_mr) else np.zeros(len(id_to_mr), np.int64)
    for c, mr in enumerate(id_to_mr):
        for lab in set(mr):
            key = str(int(lab))
            label_counts[key] = label_counts.get(key, 0) \
                + int(mr_counts[c])

    histograms = dict(
        hub_rank_decile=dict(
            out=_hub_rank_deciles(frozen.out_hub, frozen.aid, n),
            in_=_hub_rank_deciles(frozen.in_hub, frozen.aid, n)),
        mr_len=dict(out=mr_len_hist(frozen.out_mr),
                    in_=mr_len_hist(frozen.in_mr)),
        label=label_counts,
    )

    # -- redundancy re-verification (Definition 5, sampled) ------------- #
    checked = violations = 0
    examples: List[dict] = []
    for v in rng.permutation(n).tolist():
        if checked >= sample:
            break
        ih, im = frozen.row_in(v)
        for h, c in zip(ih.tolist(), im.tolist()):
            if checked >= sample:
                break
            if h == v:
                continue
            checked += 1
            if _redundant(frozen, h, v, c):
                violations += 1
                if len(examples) < 5:
                    examples.append(dict(s=int(h), t=int(v),
                                         mr_id=int(c),
                                         mr=list(id_to_mr[c])))
    redundancy = dict(sampled=checked, violations=violations,
                      examples=examples)

    # -- soundness probes (oracle replay of entry-derived queries) ------ #
    soundness = None
    if graph is not None:
        from repro.core.baselines import bibfs_rlc
        from repro.core.queries import sample_index_queries
        probes = sample_index_queries(frozen, id_to_mr,
                                      n=min(sample, 64), seed=seed)
        bad = [q for q in probes
               if not bibfs_rlc(graph, q[0], q[1], q[2])]
        soundness = dict(
            sampled=len(probes), violations=len(bad),
            examples=[dict(s=s, t=t, mr=list(L)) for s, t, L in bad[:5]])

    # -- byte accounting ------------------------------------------------ #
    mirror = getattr(index, "_mirror", None) if index is not None else None
    bytes_ = dict(
        index=(int(index.size_bytes()) if index is not None
               else int(frozen.size_bytes())),
        frozen=frozen_nbytes(frozen),
        mirror=(int(mirror.size_bytes()) if mirror is not None else None),
        device=device_nbytes(device_index),
    )

    return dict(
        schema=AUDIT_SCHEMA,
        identity=dict(num_vertices=int(n), k=int(frozen.k),
                      num_mrs=len(id_to_mr),
                      entries_out=int(out_n), entries_in=int(in_n),
                      entries=int(out_n + in_n),
                      max_row=int(frozen.max_row)),
        histograms=histograms,
        redundancy=redundancy,
        soundness=soundness,
        bytes=bytes_,
        fingerprint=fingerprint(frozen),
    )


# --------------------------------------------------------------------- #
# validation + metric banking
# --------------------------------------------------------------------- #
def validate_audit_report(doc: dict) -> dict:
    """Validate an audit report against :data:`AUDIT_SCHEMA`; returns the
    doc or raises ``ValueError`` naming the first offending path. The one
    validator tests, the smoke gate, and the CLI share."""
    def fail(path: str, why: str):
        raise ValueError(f"audit report invalid at {path}: {why}")

    def nonneg_int(path, v):
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            fail(path, f"expected non-negative int, got {v!r}")

    if not isinstance(doc, dict):
        fail("$", f"expected object, got {type(doc).__name__}")
    if doc.get("schema") != AUDIT_SCHEMA:
        fail("$.schema",
             f"expected {AUDIT_SCHEMA!r}, got {doc.get('schema')!r}")
    ident = doc.get("identity")
    if not isinstance(ident, dict):
        fail("$.identity", "expected object")
    for k in ("num_vertices", "k", "num_mrs", "entries_out",
              "entries_in", "entries", "max_row"):
        nonneg_int(f"$.identity.{k}", ident.get(k))
    if ident["entries"] != ident["entries_out"] + ident["entries_in"]:
        fail("$.identity.entries", "entries != entries_out + entries_in")
    hist = doc.get("histograms")
    if not isinstance(hist, dict):
        fail("$.histograms", "expected object")
    hrd = hist.get("hub_rank_decile")
    if not isinstance(hrd, dict):
        fail("$.histograms.hub_rank_decile", "expected object")
    for side in ("out", "in_"):
        row = hrd.get(side)
        if not isinstance(row, list) or len(row) != _N_DECILES:
            fail(f"$.histograms.hub_rank_decile.{side}",
                 f"expected list of {_N_DECILES} counts")
        for i, v in enumerate(row):
            nonneg_int(f"$.histograms.hub_rank_decile.{side}[{i}]", v)
    for sec in ("redundancy", "soundness"):
        r = doc.get(sec)
        if r is None and sec == "soundness":
            continue
        if not isinstance(r, dict):
            fail(f"$.{sec}", "expected object")
        nonneg_int(f"$.{sec}.sampled", r.get("sampled"))
        nonneg_int(f"$.{sec}.violations", r.get("violations"))
        if r["violations"] > r["sampled"]:
            fail(f"$.{sec}.violations", "violations exceed sampled")
        if not isinstance(r.get("examples"), list):
            fail(f"$.{sec}.examples", "expected list")
    b = doc.get("bytes")
    if not isinstance(b, dict):
        fail("$.bytes", "expected object")
    for k in ("index", "frozen", "mirror", "device"):
        v = b.get(k)
        if v is not None:
            nonneg_int(f"$.bytes.{k}", v)
    fp = doc.get("fingerprint")
    if not isinstance(fp, dict):
        fail("$.fingerprint", "expected object")
    comb = fp.get("combined")
    if not isinstance(comb, str) or len(comb) != 8 \
            or any(c not in "0123456789abcdef" for c in comb):
        fail("$.fingerprint.combined", f"expected 8-hex digest, got "
             f"{comb!r}")
    for side in ("row_buckets_out", "row_buckets_in"):
        row = fp.get(side)
        if not isinstance(row, list) or len(row) != _N_BUCKETS:
            fail(f"$.fingerprint.{side}",
                 f"expected list of {_N_BUCKETS} bucket CRCs")
    return doc


def bank_audit_metrics(registry, report: dict) -> None:
    """Mirror the latest audit into registry gauges so the Prometheus
    export carries an index-health block alongside the serving series."""
    ent = registry.gauge("rlc_audit_entries",
                         desc="index entries at the last audit",
                         labelnames=("direction",))
    ent.labels(direction="out").set(report["identity"]["entries_out"])
    ent.labels(direction="in").set(report["identity"]["entries_in"])
    registry.gauge(
        "rlc_audit_redundancy_sampled",
        desc="entries re-checked for Definition-5 redundancy "
             "at the last audit").labels().set(
        report["redundancy"]["sampled"])
    registry.gauge(
        "rlc_audit_redundancy_violations",
        desc="redundant entries found at the last audit").labels().set(
        report["redundancy"]["violations"])
    if report.get("soundness") is not None:
        registry.gauge(
            "rlc_audit_soundness_violations",
            desc="entry-derived queries the oracle refuted "
                 "at the last audit").labels().set(
            report["soundness"]["violations"])
    by = registry.gauge("rlc_audit_bytes",
                        desc="index byte accounting at the last audit",
                        unit="By", labelnames=("component",))
    for comp, v in report["bytes"].items():
        if v is not None:
            by.labels(component=comp).set(v)
