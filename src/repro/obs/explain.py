"""Query EXPLAIN: witness derivations for Algorithm 1 answers.

The serving stack can say *that* ``(s, t, MR+)`` is true or false; this
module makes it say *why*. A **witness** is a JSON-ready record of the
derivation Algorithm 1 actually performed over one pair of entry rows:

* positive answers cite the index facts used — the direct Case-2 entry
  (``(t, MR) in L_out(s)`` / ``(s, MR) in L_in(t)``) or the Case-1 join
  hubs ``x`` with ``(x, MR)`` on *both* sides (Theorem 3's certificate);
* negative answers cite the pruning-era facts that rule the path out:
  which side has no entries at all, which side carries no entry for the
  queried MR, or — when both sides have candidates — that the two
  aid-sorted candidate hub sets are disjoint (by Theorems 1-2 the index
  is complete for ``|MR| <= k``, so a failed join *is* a proof of
  non-reachability, not a heuristic miss).

:func:`explain_rows` works on any ``(hub, mr_id)`` row pair in the
frozen layout's vocabulary — zero-copy CSR rows
(:meth:`FrozenRLCIndex.explain`), PAD-filtered device digests
(:meth:`DeviceIndex.explain_batch`), or a cross-shard digest joined
against a remote in-row (``ShardedRLCService.explain``) — so one
witness shape covers every backend. :func:`replay_witness` re-runs the
claim under the BiBFS product-automaton oracle, and
:func:`verify_witness_entries` re-checks the cited entries against the
dict-layout index; the property tests drive both.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = ["WITNESS_SCHEMA", "build_witness", "explain_rows",
           "replay_witness", "verify_witness_entries"]

WITNESS_SCHEMA = "repro.obs.witness/1"

#: negative-witness reasons, in the order they are ruled out
NEGATIVE_REASONS = ("empty_out_row", "empty_in_row", "no_out_candidates",
                    "no_in_candidates", "disjoint_hub_sets")


def build_witness(s: int, t: int, mr_id: Optional[int], *,
                  case2_out: bool, case2_in: bool,
                  out_row: int, in_row: int,
                  out_candidates: Sequence[int],
                  in_candidates: Sequence[int],
                  aid: Optional[np.ndarray] = None,
                  max_hubs: int = 8) -> dict:
    """Assemble one witness from pre-extracted row facts.

    ``out_candidates`` / ``in_candidates``: the hub ids whose row entry
    carries the queried MR (the Case-1 join inputs). ``aid`` orders the
    join hubs by access id when available (the dict layout and device
    digests may not carry it — hubs then sort by vertex id and report
    ``aid: null``).
    """
    out_c = sorted(int(h) for h in set(out_candidates))
    in_c = sorted(int(h) for h in set(in_candidates))
    join = set(out_c) & set(in_c)
    if aid is not None:
        join = sorted(join, key=lambda h: int(aid[h]))
    else:
        join = sorted(join)
    answer = bool(case2_out or case2_in or join)
    kind = ("case2_out" if case2_out else
            "case2_in" if case2_in else
            "case1" if join else "negative")
    hubs = [dict(hub=int(h),
                 aid=(int(aid[h]) if aid is not None else None))
            for h in join[:max_hubs]]
    w = dict(
        schema=WITNESS_SCHEMA,
        s=int(s), t=int(t),
        mr_id=(int(mr_id) if mr_id is not None else None),
        answer=answer, kind=kind,
        case2={"out": bool(case2_out), "in": bool(case2_in)},
        out_row=int(out_row), in_row=int(in_row),
        out_candidates=len(out_c), in_candidates=len(in_c),
        join_hubs=len(join), hubs=hubs,
        truncated=len(join) > max_hubs,
    )
    if not answer:
        if out_row == 0:
            reason = "empty_out_row"
        elif in_row == 0:
            reason = "empty_in_row"
        elif not out_c:
            reason = "no_out_candidates"
        elif not in_c:
            reason = "no_in_candidates"
        else:
            reason = "disjoint_hub_sets"
        w["negative"] = dict(reason=reason,
                             out_candidate_hubs=out_c[:max_hubs],
                             in_candidate_hubs=in_c[:max_hubs])
    return w


def explain_rows(out_hub, out_mr, in_hub, in_mr, s: int, t: int,
                 mr_id: int, aid: Optional[np.ndarray] = None,
                 max_hubs: int = 8, pad: Optional[int] = None) -> dict:
    """Witness for Algorithm 1 over explicit ``(hub, mr_id)`` rows.

    The row-pair twin of :func:`repro.core.rlc_index.merge_join_rows`:
    same inputs (L_out(s) and L_in(t) in the frozen vocabulary), but it
    returns the derivation instead of a bool. ``pad``: hub id marking
    padding slots to drop first (the device layout's ``PAD``), so padded
    digests explain identically to exact CSR rows.
    """
    oh = np.asarray(out_hub)
    om = np.asarray(out_mr)
    ih = np.asarray(in_hub)
    im = np.asarray(in_mr)
    if pad is not None:
        keep = oh != pad
        oh, om = oh[keep], om[keep]
        keep = ih != pad
        ih, im = ih[keep], im[keep]
    case2_out = bool(np.any((oh == t) & (om == mr_id)))
    case2_in = bool(np.any((ih == s) & (im == mr_id)))
    return build_witness(
        s, t, mr_id,
        case2_out=case2_out, case2_in=case2_in,
        out_row=len(oh), in_row=len(ih),
        out_candidates=np.unique(oh[om == mr_id]).tolist(),
        in_candidates=np.unique(ih[im == mr_id]).tolist(),
        aid=aid, max_hubs=max_hubs)


def replay_witness(graph, witness: dict,
                   mr: Optional[Sequence[int]] = None) -> bool:
    """Re-run a witness's claim under the BiBFS product-automaton oracle.

    Accepts either a service EXPLAIN bundle (which carries ``mr``) or a
    raw witness plus an explicit ``mr``. The contract the property tests
    enforce: a positive witness replays to ``True``, a negative one to
    ``False`` (completeness for ``|MR| <= k``, Theorem 2).
    """
    from repro.core.baselines import bibfs_rlc
    L = tuple(mr if mr is not None else witness["mr"])
    return bibfs_rlc(graph, int(witness["s"]), int(witness["t"]), L)


def verify_witness_entries(index, witness: dict,
                           mr: Sequence[int]) -> bool:
    """Re-check the entries a witness cites against a dict-layout
    :class:`repro.core.rlc_index.RLCIndex` — every Case-2 direct entry
    and every listed Case-1 hub must exist on both required sides; a
    negative witness must agree with Algorithm 1."""
    L = tuple(mr)
    s, t = int(witness["s"]), int(witness["t"])
    kind = witness["kind"]
    if kind == "case2_out":
        return index.has_out(s, t, L)
    if kind == "case2_in":
        return index.has_in(t, s, L)
    if kind == "case1":
        hubs = witness["hubs"]
        return bool(hubs) and all(
            index.has_out(s, h["hub"], L) and index.has_in(t, h["hub"], L)
            for h in hubs)
    return not index.query(s, t, L)
