"""End-to-end telemetry for the RLC serving and build stack.

The paper (arXiv 2203.08606) evaluates a reachability index on three
axes — offline indexing cost, index size, query latency — and this
package is how the repo measures all three in one place instead of
ad-hoc ``stats()`` dicts:

* :mod:`repro.obs.metrics` — a metrics registry (counters / gauges /
  bounded-reservoir histograms with labeled series) that every serving
  and build layer reports into; no locks on the read path, bounded
  memory everywhere.
* :mod:`repro.obs.tracing` — sampling-controlled per-query span tracing
  (parse -> cache probe -> queue wait -> shard route -> digest hand-off
  -> executor backend -> fallback chain) with a Chrome ``trace_event``
  exporter.
* :mod:`repro.obs.export` — a versioned JSON snapshot schema (asserted
  by ``tests/test_obs.py`` and validated by the benchmark smoke run)
  plus a Prometheus text-format dump.
* :mod:`repro.obs.build_obs` — per-(hub, direction) phase timings and
  pruning-counter deltas for the Algorithm 2 backends and the delta
  engine.
* :mod:`repro.obs.explain` — witness-mode query derivations (the
  ``RLCService.explain`` EXPLAIN bundles) with oracle replay and
  entry re-verification helpers.
* :mod:`repro.obs.audit` — the index-health auditor: versioned reports
  over a live index (histograms, redundancy/soundness re-verification,
  byte accounting, drift fingerprints).
* :mod:`repro.obs.shadow` — continuous shadow verification: sampled
  re-execution of served answers against the BiBFS oracle.

:class:`Observability` bundles one registry + one tracer; services own
one instance (``RLCService.obs``) created from their config. Counters
are default-on (cheap), tracing is opt-in via ``trace_sample_rate``.

See ``src/repro/obs/README.md`` for the metric taxonomy.
"""
from __future__ import annotations

from typing import Optional

from .audit import (AUDIT_SCHEMA, audit_index, bank_audit_metrics,
                    fingerprint, validate_audit_report)
from .build_obs import BuildPhaseObserver
from .explain import (WITNESS_SCHEMA, build_witness, explain_rows,
                      replay_witness, verify_witness_entries)
from .export import (SCHEMA, snapshot, snapshot_to_prometheus,
                     to_prometheus, validate_snapshot)
from .metrics import (NULL_REGISTRY, Counter, Gauge, Histogram, Metric,
                      MetricsRegistry, NullRegistry, Reservoir)
from .shadow import ShadowVerifier, attach_shadow
from .tracing import SpanEvent, Trace, Tracer, span_tree

__all__ = [
    "AUDIT_SCHEMA", "SCHEMA", "WITNESS_SCHEMA", "BuildPhaseObserver",
    "Counter", "Gauge", "Histogram", "Metric", "MetricsRegistry",
    "NullRegistry", "NULL_REGISTRY", "Observability", "NULL_OBS",
    "Reservoir", "ShadowVerifier", "SpanEvent", "Trace", "Tracer",
    "attach_shadow", "audit_index", "bank_audit_metrics",
    "build_witness", "explain_rows", "fingerprint", "replay_witness",
    "snapshot", "snapshot_to_prometheus", "span_tree", "to_prometheus",
    "validate_snapshot", "validate_audit_report",
    "verify_witness_entries",
]


class Observability:
    """One registry + one tracer: the telemetry context of one stack.

    ``enabled=False`` swaps in the null registry and a zero-rate tracer
    so every instrumented call site stays branch-free and near-free.
    Counters/histograms are default-on; span tracing only activates at
    ``trace_sample_rate > 0``.
    """

    def __init__(self, enabled: bool = True,
                 trace_sample_rate: float = 0.0,
                 reservoir_cap: int = 2048,
                 max_trace_events: int = 50_000):
        self.enabled = bool(enabled)
        if self.enabled:
            self.registry = MetricsRegistry(reservoir_cap=reservoir_cap)
            self.tracer = Tracer(sample_rate=trace_sample_rate,
                                 max_events=max_trace_events)
        else:
            self.registry = NULL_REGISTRY
            self.tracer = Tracer(sample_rate=0.0, max_events=0)
        self._build_observer: Optional[BuildPhaseObserver] = None

    # ------------------------------------------------------------------ #
    def build_observer(self, context: str = "full") -> \
            Optional[BuildPhaseObserver]:
        """A :class:`BuildPhaseObserver` bound to this registry (None in
        disabled mode — build loops skip the per-phase timing entirely
        rather than timing into a null sink)."""
        if not self.enabled:
            return None
        if context == "full":
            if self._build_observer is None:
                self._build_observer = BuildPhaseObserver(
                    self.registry, context=context)
            return self._build_observer
        return BuildPhaseObserver(self.registry, context=context)

    # -- exporters ------------------------------------------------------ #
    def snapshot(self, extra: Optional[dict] = None) -> dict:
        ex = dict(extra) if extra else {}
        if self._build_observer is not None:
            ex.setdefault("slowest_build_phases",
                          self._build_observer.slowest_phases())
        return snapshot(self.registry, tracer=self.tracer,
                        extra=ex or None)

    def prometheus(self) -> str:
        return to_prometheus(self.registry)

    def chrome_trace(self, process_name: str = "rlc-service") -> dict:
        return self.tracer.chrome_trace(process_name)


#: shared inert instance for call sites constructed without telemetry
NULL_OBS = Observability(enabled=False)
