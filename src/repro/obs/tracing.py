"""Per-query span tracing with sampling and a Chrome ``trace_event`` export.

A :class:`Tracer` hands out :class:`Trace` handles — one per sampled unit
of work (a ``query_batch`` admission on the serving path). Call sites ask
``tracer.maybe_trace()`` once and get ``None`` when the unit is not
sampled, so the un-sampled hot path pays a single comparison; every span
call is guarded by ``if tr is not None``.

Spans are flat records ``(name, cat, tid, ts, dur, args)`` — the tree
structure is implied by interval containment on one ``tid`` (exactly the
Chrome ``trace_event`` model, so the export is a direct mapping and
``chrome://tracing`` / Perfetto render the timeline without any
massaging). :func:`span_tree` rebuilds the nesting for tests and
programmatic analysis.

The event buffer is bounded: past ``max_events`` new spans are dropped
and counted (``tracer.dropped``) — tracing must never become the memory
leak it exists to diagnose.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

__all__ = ["SpanEvent", "Trace", "Tracer", "span_tree"]


@dataclass(frozen=True)
class SpanEvent:
    """One completed span, timestamps in seconds since the tracer epoch."""

    name: str
    cat: str
    tid: int
    ts: float
    dur: float
    args: Optional[dict] = None


class _SpanCtx:
    """Context manager recording one span on exit."""

    __slots__ = ("_trace", "_name", "_cat", "_args", "_t0")

    def __init__(self, trace: "Trace", name: str, cat: str, args):
        self._trace = trace
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_SpanCtx":
        self._t0 = self._trace.tracer._now()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._trace
        t1 = tr.tracer._now()
        if exc_type is not None:
            args = dict(self._args or ())
            args["error"] = exc_type.__name__
            self._args = args
        tr.tracer._emit(SpanEvent(self._name, self._cat, tr.tid,
                                  self._t0, t1 - self._t0, self._args))
        return False


class Trace:
    """Handle for one sampled unit of work (one ``tid`` in the export)."""

    __slots__ = ("tracer", "tid")

    def __init__(self, tracer: "Tracer", tid: int):
        self.tracer = tracer
        self.tid = tid

    def span(self, name: str, cat: str = "", **args) -> _SpanCtx:
        """``with tr.span("execute", backend="numpy"): ...``"""
        return _SpanCtx(self, name, cat, args or None)

    def add(self, name: str, ts: float, dur: float, cat: str = "",
            **args) -> None:
        """Record a span with explicit (tracer-epoch) timestamps."""
        self.tracer._emit(SpanEvent(name, cat, self.tid, ts, dur,
                                    args or None))

    def add_ending_now(self, name: str, dur: float, cat: str = "",
                       **args) -> None:
        """Record a span of ``dur`` seconds that ends at the current
        instant — for waits measured on a different clock (e.g. the
        micro-batcher's queue wait), where only the duration is
        trustworthy across clocks."""
        now = self.tracer._now()
        self.tracer._emit(SpanEvent(name, cat, self.tid,
                                    now - dur, dur, args or None))


class Tracer:
    """Sampling span recorder.

    ``sample_rate`` in [0, 1] decides per :meth:`maybe_trace` call
    whether the unit of work records spans (0 = tracing off, the
    default; 1 = trace everything). The RNG is deterministically seeded
    so replayed workloads sample identically.
    """

    def __init__(self, sample_rate: float = 0.0, max_events: int = 50_000,
                 clock: Callable[[], float] = time.perf_counter,
                 seed: int = 0):
        if not (0.0 <= sample_rate <= 1.0):
            raise ValueError(
                f"sample_rate must be in [0, 1], got {sample_rate}")
        self.sample_rate = float(sample_rate)
        self.max_events = int(max_events)
        self.clock = clock
        self.epoch = clock()
        self.events: List[SpanEvent] = []
        self.dropped = 0
        self.traces_started = 0
        self.traces_skipped = 0
        self._rng = random.Random(seed)
        self._next_tid = 0

    # ------------------------------------------------------------------ #
    @property
    def enabled(self) -> bool:
        return self.sample_rate > 0.0

    def _now(self) -> float:
        return self.clock() - self.epoch

    def _emit(self, ev: SpanEvent) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def maybe_trace(self) -> Optional[Trace]:
        """A :class:`Trace` when this unit of work is sampled, else None."""
        if self.sample_rate <= 0.0:
            return None
        if self.sample_rate < 1.0 and self._rng.random() >= self.sample_rate:
            self.traces_skipped += 1
            return None
        self.traces_started += 1
        self._next_tid += 1
        return Trace(self, self._next_tid)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0

    # ------------------------------------------------------------------ #
    def chrome_trace(self, process_name: str = "rlc-service") -> dict:
        """The buffer as a Chrome ``trace_event`` JSON object.

        Each span becomes one complete ("X") event; ``ts``/``dur`` are
        microseconds per the spec. Load the dump in ``chrome://tracing``
        or https://ui.perfetto.dev to inspect the timeline.
        """
        events: List[dict] = [dict(
            name="process_name", ph="M", pid=0, tid=0,
            args=dict(name=process_name))]
        for ev in sorted(self.events, key=lambda e: (e.ts, -e.dur)):
            rec = dict(name=ev.name, cat=ev.cat or "rlc", ph="X", pid=0,
                       tid=ev.tid, ts=round(ev.ts * 1e6, 3),
                       dur=round(ev.dur * 1e6, 3))
            if ev.args:
                rec["args"] = dict(ev.args)
            events.append(rec)
        return dict(traceEvents=events, displayTimeUnit="ms",
                    otherData=dict(dropped=self.dropped,
                                   traces=self.traces_started))

    def stats(self) -> dict:
        return dict(sample_rate=self.sample_rate,
                    traces=self.traces_started,
                    skipped=self.traces_skipped,
                    events=len(self.events),
                    dropped=self.dropped)


# --------------------------------------------------------------------- #
@dataclass
class SpanNode:
    """One node of a rebuilt span tree (tests / programmatic analysis)."""

    event: SpanEvent
    children: List["SpanNode"] = field(default_factory=list)


def span_tree(events: List[SpanEvent], tid: int) -> List[SpanNode]:
    """Rebuild the nesting of one ``tid``'s spans by interval containment.

    Returns the forest of top-level spans. Spans on one tid are expected
    to be properly nested (a child's interval inside its parent's) — the
    well-formedness property the test suite asserts; a span that
    partially overlaps a sibling is attached at top level, never
    silently clipped.
    """
    spans = sorted((e for e in events if e.tid == tid),
                   key=lambda e: (e.ts, -e.dur))
    roots: List[SpanNode] = []
    stack: List[SpanNode] = []
    eps = 1e-9
    for ev in spans:
        node = SpanNode(ev)
        while stack:
            top = stack[-1].event
            if (ev.ts >= top.ts - eps
                    and ev.ts + ev.dur <= top.ts + top.dur + eps):
                stack[-1].children.append(node)
                break
            stack.pop()
        else:
            roots.append(node)
        stack.append(node)
    return roots
