"""Batched serving engine: prefill + greedy decode with jit'd steps.

Minimal continuous-batching shape: fixed batch slots, one shared cache,
prompts padded to a common length per batch. The decode step is the
function the ``decode_*`` dry-run cells lower on the production mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import decode_step, init_cache, prefill


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int,
                 batch_slots: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_slots = batch_slots
        self._prefill = jax.jit(
            lambda p, t, c, fe: prefill(p, cfg, t, c, fe))
        self._prefill_nofe = jax.jit(
            lambda p, t, c: prefill(p, cfg, t, c))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos),
            donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, steps: int,
                 frontend: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, S0) int32. Greedy-decodes ``steps`` tokens."""
        B, S0 = prompts.shape
        assert B == self.batch_slots
        cache, _ = init_cache(self.cfg, B, self.max_len)
        if frontend is not None:
            logits, cache = self._prefill(self.params,
                                          jnp.asarray(prompts), cache,
                                          jnp.asarray(frontend))
        else:
            logits, cache = self._prefill_nofe(self.params,
                                               jnp.asarray(prompts), cache)
        n_prefix = (self.cfg.frontend_len
                    if (self.cfg.frontend != "none"
                        and not self.cfg.encoder_layers) else 0)
        outs = []
        tok = jnp.argmax(logits[:, -1:, :self.cfg.vocab_size], axis=-1
                         ).astype(jnp.int32)
        outs.append(np.asarray(tok))
        for i in range(steps - 1):
            pos = jnp.int32(S0 + n_prefix + i)
            logits, cache = self._decode(self.params, cache, tok, pos)
            tok = jnp.argmax(logits[:, :, :self.cfg.vocab_size], axis=-1
                             ).astype(jnp.int32)
            outs.append(np.asarray(tok))
        return np.concatenate(outs, axis=1)
