"""Attention variants: GQA (+qk-norm, sliding window) and MLA (DeepSeek
latent attention with absorbed decode), plus cross-attention for enc-dec.

Cache layouts (serve path):
  GQA   : {"k": (B, T, K, dh), "v": (B, T, K, dh)}         T = max seq
  MLA   : {"ckv": (B, T, kv_lora), "krope": (B, T, dr)}    latent cache
Sequence dim of caches is sharded over the ``model`` axis for long-context
decode (sharding/partition.py ``cache_seq``); softmax over the sharded
length is handled by XLA's partitioner.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.partition import constrain
from .builder import Builder
from .layers import (apply_linear, apply_rope, init_linear, rms_norm_heads,
                     rope_angles)

NEG = -1e30


# ------------------------------------------------------------------ #
# GQA
# ------------------------------------------------------------------ #
def init_gqa(b: Builder, cfg: ArchConfig, stack: Optional[int] = None,
             name: str = "attn", cross: bool = False):
    d, H, K, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    st = (stack,) if stack else ()
    sta = ("layers",) if stack else ()
    with b.scope(name):
        init_linear(b, cfg, "wq", d, H * dh, ("fsdp", "heads"), stack)
        init_linear(b, cfg, "wk", d, K * dh, ("fsdp", "kv"), stack)
        init_linear(b, cfg, "wv", d, K * dh, ("fsdp", "kv"), stack)
        init_linear(b, cfg, "wo", H * dh, d, ("heads", "fsdp"), stack)
        if cfg.qk_norm and not cross:
            b.param("q_norm", st + (dh,), sta + (None,), init="ones")
            b.param("k_norm", st + (dh,), sta + (None,), init="ones")


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _attend_mha(q, k, v, mask):
    """Head-sharded full attention (train/prefill). q/k/v: (B,S|T,H,dh) —
    KV already repeated to H heads so the ``heads`` dim shards cleanly
    over the ``model`` axis (the grouped 5D form forces the partitioner
    into involuntary resharding when K < tp; see EXPERIMENTS.md §Perf)."""
    dh = q.shape[-1]
    q = constrain(q, ("act_batch", None, "act_heads", None))
    k = constrain(k, ("act_batch", None, "act_heads", None))
    v = constrain(v, ("act_batch", None, "act_heads", None))
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    scores = constrain(scores, ("act_batch", "act_heads", None, None))
    w = jax.nn.softmax(jnp.where(mask, scores, NEG).astype(jnp.float32),
                       axis=-1)
    ctx = jnp.einsum("bhst,bthd->bshd", w.astype(q.dtype), v)
    return constrain(ctx, ("act_batch", None, "act_heads", None))


def _attend_mha_chunked(q, k, v, chunk: int, window: int,
                        q_offset: int = 0):
    """Flash-style attention: KV streamed in chunks with an online
    softmax; peak score memory is (B, H, S, chunk) instead of
    (B, H, S, T). Pure JAX (lax.scan) so it lowers on any backend; the
    Pallas VMEM-tiled version is the TPU deploy path (future kernel).

    Causality from position math (q_pos = q_offset + i) — no (S, T)
    mask tensor exists anywhere."""
    B, S, H, dh = q.shape
    T = k.shape[1]
    nc = T // chunk
    assert T % chunk == 0, (T, chunk)
    f32 = jnp.float32
    q = constrain(q, ("act_batch", None, "act_heads", None))
    scale = 1.0 / jnp.sqrt(f32(dh))
    kc = jnp.moveaxis(k.reshape(B, nc, chunk, H, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, H, dh), 1, 0)
    qpos = q_offset + jnp.arange(S)[:, None]            # (S, 1)

    def body(carry, xs):
        m, l, acc = carry                               # (B,H,S), ., (B,S,H,dh)
        j, (kj, vj) = xs
        kpos = j * chunk + jnp.arange(chunk)[None, :]   # (1, chunk)
        ok = kpos <= qpos                               # (S, chunk)
        if window:
            ok &= kpos > qpos - window
        s_j = jnp.einsum("bshd,bthd->bhst", q, kj,
                         preferred_element_type=f32) * scale
        s_j = jnp.where(ok[None, None], s_j, NEG)
        m_new = jnp.maximum(m, s_j.max(-1))             # (B,H,S)
        p = jnp.exp(s_j - m_new[..., None])             # (B,H,S,chunk)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        pv = jnp.einsum("bhst,bthd->bshd", p.astype(q.dtype), vj,
                        preferred_element_type=f32)
        acc = acc * jnp.moveaxis(corr, 1, 2)[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), NEG, f32)
    l0 = jnp.zeros((B, H, S), f32)
    a0 = jnp.zeros((B, S, H, dh), f32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nc), (kc, vc)))
    out = acc / jnp.maximum(jnp.moveaxis(l, 1, 2)[..., None], 1e-30)
    return constrain(out.astype(q.dtype),
                     ("act_batch", None, "act_heads", None))


def _attend_grouped(q, k, v, mask):
    """Grouped decode attention: q (B,S,K,G,dh) vs the K-head cache
    (B,T,K,dh); T (cache_seq) is the sharded dim."""
    dh = q.shape[-1]
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    w = jax.nn.softmax(jnp.where(mask, scores, NEG).astype(jnp.float32),
                       axis=-1)
    ctx = jnp.einsum("bkgst,btkd->bskgd", w.astype(q.dtype), v)
    return ctx


def _causal_mask(S, T, offset, window):
    """(S, T) bool: query i (at absolute pos offset+i) sees key j<=pos and
    within the sliding window when set."""
    qpos = offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def apply_gqa(p, x: jax.Array, cfg: ArchConfig, positions: jax.Array,
              cache: Optional[Dict] = None, pos=None,
              update_cache: bool = False
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """Full (train/prefill) when ``cache is None`` or ``update_cache``;
    single-step decode when ``cache`` is given with scalar ``pos``."""
    B, S, d = x.shape
    H, K, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    G = H // K
    q = _split_heads(apply_linear(p["wq"], x, cfg), H, dh)
    k = _split_heads(apply_linear(p["wk"], x, cfg), K, dh)
    v = _split_heads(apply_linear(p["wv"], x, cfg), K, dh)
    if cfg.qk_norm:
        q = rms_norm_heads(p["q_norm"], q)
        k = rms_norm_heads(p["k_norm"], k)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    def _full(qh, kh, vh, T):
        if cfg.attn_chunk and T > cfg.attn_chunk and T % cfg.attn_chunk == 0:
            return _attend_mha_chunked(qh, kh, vh, cfg.attn_chunk,
                                       cfg.sliding_window)
        mask = _causal_mask(S, T, 0, cfg.sliding_window)[None, None]
        return _attend_mha(qh, kh, vh, mask)

    new_cache = None
    if cache is None:
        ctx = _full(q, jnp.repeat(k, G, axis=2),
                    jnp.repeat(v, G, axis=2), S)
    elif pos is None:
        # prefill into a fresh cache of length T >= S
        T = cache["k"].shape[1]
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        ctx = _full(q, jnp.repeat(kc, G, axis=2),
                    jnp.repeat(vc, G, axis=2), T)
        new_cache = {"k": kc, "v": vc}
    else:
        # decode: S == 1, absolute position ``pos`` (scalar int array);
        # grouped form — cache keeps K heads, T shards over "model".
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, pos.astype(jnp.int32), 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, pos.astype(jnp.int32), 0, 0))
        T = kc.shape[1]
        kpos = jnp.arange(T)[None, :]
        m = kpos <= pos
        if cfg.sliding_window:
            m &= kpos > pos - cfg.sliding_window
        mask = m[None, None, None]  # (1,1,1,1,T): broadcasts over S=1
        ctx = _attend_grouped(q.reshape(B, S, K, G, dh), kc, vc, mask)
        ctx = ctx.reshape(B, S, H, dh)
        new_cache = {"k": kc, "v": vc}
    out = apply_linear(p["wo"], ctx.reshape(B, S, H * dh), cfg)
    return out, new_cache


# ------------------------------------------------------------------ #
# Cross-attention (enc-dec)
# ------------------------------------------------------------------ #
def apply_cross_attn(p, x: jax.Array, cfg: ArchConfig,
                     enc_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    """x: (B,S,d) decoder; enc_kv: precomputed (k, v) (B,T,K,dh)."""
    B, S, _ = x.shape
    H, K, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = _split_heads(apply_linear(p["wq"], x, cfg), H, dh)
    k, v = enc_kv
    G = H // K
    mask = jnp.ones((1, 1, 1, k.shape[1]), bool)
    ctx = _attend_mha(q, jnp.repeat(k, G, axis=2),
                      jnp.repeat(v, G, axis=2), mask)
    return apply_linear(p["wo"], ctx.reshape(B, S, H * dh), cfg)


def encoder_kv(p, enc_out: jax.Array, cfg: ArchConfig):
    K, dh = cfg.num_kv_heads, cfg.head_dim_
    k = _split_heads(apply_linear(p["wk"], enc_out, cfg), K, dh)
    v = _split_heads(apply_linear(p["wv"], enc_out, cfg), K, dh)
    return k, v


# ------------------------------------------------------------------ #
# MLA (DeepSeek-V3)
# ------------------------------------------------------------------ #
def init_mla(b: Builder, cfg: ArchConfig, stack: Optional[int] = None,
             name: str = "attn"):
    d, H = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    st = (stack,) if stack else ()
    sta = ("layers",) if stack else ()
    with b.scope(name):
        init_linear(b, cfg, "wq_a", d, ql, ("fsdp", "lora"), stack)
        b.param("q_ln", st + (ql,), sta + (None,), init="ones")
        init_linear(b, cfg, "wq_b", ql, H * (dn + dr), ("lora", "heads"),
                    stack)
        init_linear(b, cfg, "wkv_a", d, kl + dr, ("fsdp", "lora"), stack)
        b.param("kv_ln", st + (kl,), sta + (None,), init="ones")
        b.param("wk_b", st + (kl, H, dn), sta + ("lora", "heads", None))
        b.param("wv_b", st + (kl, H, dv), sta + ("lora", "heads", None))
        init_linear(b, cfg, "wo", H * dv, d, ("heads", "fsdp"), stack)


def _mla_qkv(p, x, cfg, positions):
    """Shared q / latent computation. Returns q_nope (B,S,H,dn),
    q_rope (B,S,H,dr), ckv (B,S,kl), krope (B,S,dr)."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    kl = cfg.kv_lora_rank
    cq = apply_linear(p["wq_a"], x, cfg)
    cq = rms_norm_heads(p["q_ln"], cq)
    q = apply_linear(p["wq_b"], cq, cfg).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = apply_linear(p["wkv_a"], x, cfg)
    ckv, krope = kv[..., :kl], kv[..., kl:]
    ckv = rms_norm_heads(p["kv_ln"], ckv)
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    krope = apply_rope(krope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, ckv, krope


def apply_mla(p, x: jax.Array, cfg: ArchConfig, positions: jax.Array,
              cache: Optional[Dict] = None, pos=None
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """Prefill/train: materialized K/V per head. Decode: absorbed scores
    against the latent cache (the MLA serving win — cache is
    (kv_lora + rope_dim) per token instead of 2*H*dh)."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, ckv, krope = _mla_qkv(p, x, cfg, positions)
    scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
    new_cache = None

    if cache is not None and pos is not None:
        # ---- absorbed decode ----
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv, (0, pos.astype(jnp.int32), 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["krope"], krope, (0, pos.astype(jnp.int32), 0))
        # q absorbed into latent space: (B,S,H,dn) x (kl,H,dn) -> (B,S,H,kl)
        q_abs = jnp.einsum("bshn,khn->bshk", q_nope,
                           p["wk_b"].astype(x.dtype))
        s_nope = jnp.einsum("bshk,btk->bhst", q_abs, ckv_c,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope, kr_c,
                            preferred_element_type=jnp.float32)
        T = ckv_c.shape[1]
        mask = (jnp.arange(T)[None, :] <= pos)[None, None]
        scores = jnp.where(mask, (s_nope + s_rope) * scale, NEG)
        w = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhst,btk->bshk", w, ckv_c)
        ctx = jnp.einsum("bshk,khv->bshv", ctx_lat,
                         p["wv_b"].astype(x.dtype))
        new_cache = {"ckv": ckv_c, "krope": kr_c}
    else:
        # ---- train / prefill: materialize per-head K, V ----
        q_nope = constrain(q_nope, ("act_batch", None, "act_heads", None))
        k_nope = jnp.einsum("btk,khn->bthn", ckv, p["wk_b"].astype(x.dtype))
        v = jnp.einsum("btk,khv->bthv", ckv, p["wv_b"].astype(x.dtype))
        k_nope = constrain(k_nope, ("act_batch", None, "act_heads", None))
        v = constrain(v, ("act_batch", None, "act_heads", None))
        s_nope = jnp.einsum("bshn,bthn->bhst", q_nope, k_nope,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope, krope,
                            preferred_element_type=jnp.float32)
        mask = _causal_mask(S, S, 0, 0)[None, None]
        scores = jnp.where(mask, (s_nope + s_rope) * scale, NEG)
        scores = constrain(scores, ("act_batch", "act_heads", None, None))
        w = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        ctx = jnp.einsum("bhst,bthv->bshv", w, v)
        ctx = constrain(ctx, ("act_batch", None, "act_heads", None))
        if cache is not None:
            T = cache["ckv"].shape[1]
            ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv,
                                                 (0, 0, 0))
            kr_c = jax.lax.dynamic_update_slice(cache["krope"], krope,
                                                (0, 0, 0))
            new_cache = {"ckv": ckv_c, "krope": kr_c}
    out = apply_linear(p["wo"], ctx.reshape(B, S, H * dv), cfg)
    return out, new_cache
