"""Mamba2 block — SSD (state-space duality) with chunked scan.

Train/prefill: the sequence is split into chunks of length Q; the
intra-chunk term is a masked (Q x Q) attention-like einsum (MXU work),
the inter-chunk term a ``lax.scan`` carrying the (H, P, N) state — O(S)
total, the sub-quadratic path that qualifies ssm/hybrid archs for the
``long_500k`` cell. Decode: O(1) recurrent state update.

State layout: x heads (B,S,H,P) with P = headdim; B/C projections per
group (B,S,G,N) broadcast over H//G heads; scalar decay per head.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .builder import Builder


def _dims(cfg: ArchConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_headdim
    G = cfg.ssm_groups
    N = cfg.ssm_state
    return di, H, P, G, N


def init_mamba2(b: Builder, cfg: ArchConfig, stack: Optional[int] = None,
                name: str = "ssm"):
    d = cfg.d_model
    di, H, P, G, N = _dims(cfg)
    dconv = di + 2 * G * N
    st = (stack,) if stack else ()
    sta = ("layers",) if stack else ()
    with b.scope(name):
        b.param("in_proj", st + (d, 2 * di + 2 * G * N + H),
                sta + ("fsdp", "ff"))
        b.param("conv_w", st + (cfg.ssm_conv, dconv), sta + (None, "ff"))
        b.param("conv_b", st + (dconv,), sta + ("ff",), init="zeros")
        b.param("dt_bias", st + (H,), sta + (None,), init="zeros")
        b.param("A_log", st + (H,), sta + (None,), init="normal", scale=0.5)
        b.param("D", st + (H,), sta + (None,), init="ones")
        b.param("norm_w", st + (di,), sta + (None,), init="ones")
        b.param("out_proj", st + (di, d), sta + ("ff", "fsdp"))


def _split_in(zxbcdt, cfg: ArchConfig):
    di, H, P, G, N = _dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * G * N]
    dt = zxbcdt[..., -H:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, width W. xbc: (B,S,C); w: (W,C).
    Returns (out, new_state) with state = last W-1 inputs."""
    W = w.shape[0]
    B, S, C = xbc.shape
    if state is None:
        state = jnp.zeros((B, W - 1, C), xbc.dtype)
    xext = jnp.concatenate([state, xbc], axis=1)       # (B, S+W-1, C)
    out = jnp.zeros((B, S, C), xbc.dtype)
    for i in range(W):
        out = out + xext[:, i:i + S, :] * w[i][None, None, :]
    out = out + bias[None, None, :]
    new_state = xext[:, -(W - 1):, :] if W > 1 else state
    return jax.nn.silu(out), new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int,
                 mm_dtype=jnp.float32):
    """SSD over chunks. xh: (b,S,H,P); dt: (b,S,H) (post-softplus);
    A: (H,) negative; Bm/Cm: (b,S,G,N). Returns (y, final_state).

    ``mm_dtype``: dtype of the intra-chunk matmuls and their (Q x Q)
    intermediates (§Perf, zamba2 prefill cell — bf16 halves the dominant
    HBM traffic; decay cumsums stay f32 for stability, accumulation is
    f32 via preferred_element_type)."""
    b, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = chunk
    nc = S // Q
    assert S % Q == 0, (S, Q)

    f32 = jnp.float32
    xc = xh.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H).astype(f32)
    Bh = jnp.repeat(Bm.reshape(b, nc, Q, G, N), rep, axis=3)  # (b,nc,Q,H,N)
    Ch = jnp.repeat(Cm.reshape(b, nc, Q, G, N), rep, axis=3)

    dA = dtc * A.astype(f32)[None, None, None, :]       # (b,nc,Q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                        # inclusive

    # intra-chunk (quadratic within Q only)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,Q,Q,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    # mask BEFORE the exp: above-diagonal diff is positive and can overflow
    # to +inf, and where(mask, inf, 0) back-propagates 0 * inf = NaN.
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    LL = jnp.exp(diff).astype(mm_dtype)
    scores = jnp.einsum("bnqhi,bnkhi->bnqkh", Ch.astype(mm_dtype),
                        Bh.astype(mm_dtype),
                        preferred_element_type=f32).astype(mm_dtype)
    M = scores * LL * dtc[:, :, None, :, :].astype(mm_dtype)
    y_intra = jnp.einsum("bnqkh,bnkhp->bnqhp", M, xc.astype(mm_dtype),
                         preferred_element_type=f32)

    # per-chunk end states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (b,nc,Q,H)
    wgt = (dtc * decay_end).astype(mm_dtype)            # (b,nc,Q,H)
    state_c = jnp.einsum("bnkh,bnkhi,bnkhp->bnhpi", wgt,
                         Bh.astype(mm_dtype), xc.astype(mm_dtype),
                         preferred_element_type=f32)    # (b,nc,H,P,N)

    # inter-chunk scan
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (b,nc,H)

    def scan_fn(h, inp):
        s_c, dec = inp                                  # (b,H,P,N), (b,H)
        h_prev = h
        h = h * dec[:, :, None, None] + s_c
        return h, h_prev

    h0 = jnp.zeros((b, H, P, N), f32)
    hT, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)               # (b,nc,H,P,N)

    y_inter = jnp.einsum(
        "bnqhi,bnhpi->bnqhp",
        (Ch.astype(f32) * jnp.exp(cum)[..., None]).astype(mm_dtype),
        h_prevs.astype(mm_dtype), preferred_element_type=f32)
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y.astype(xh.dtype), hT


def apply_mamba2(p, x: jax.Array, cfg: ArchConfig,
                 cache: Optional[Dict] = None, pos=None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """cache = {"conv": (B, W-1, dconv), "state": (B,H,P,N)}; decode when
    ``pos`` is given (S must be 1)."""
    B, S, d = x.shape
    di, H, P, G, N = _dims(cfg)
    cdt = x.dtype
    zxbcdt = jnp.matmul(x, p["in_proj"].astype(cdt))
    z, xbc, dt = _split_in(zxbcdt, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    new_cache = None
    if cache is not None and pos is not None:
        # ---- decode: O(1) state update ----
        xbc_act, conv_state = _causal_conv(
            xbc, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt),
            cache["conv"])
        xh = xbc_act[..., :di].reshape(B, 1, H, P).astype(jnp.float32)
        Bm = xbc_act[..., di:di + G * N].reshape(B, 1, G, N)
        Cm = xbc_act[..., di + G * N:].reshape(B, 1, G, N)
        rep = H // G
        Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # (B,1,H,N)
        Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
        dA = (dt[:, 0] * A[None, :])                    # (B,H)
        h = cache["state"]                              # (B,H,P,N) f32
        h = h * jnp.exp(dA)[:, :, None, None] + \
            jnp.einsum("bh,bhi,bhp->bhpi", dt[:, 0], Bh[:, 0], xh[:, 0])
        y = jnp.einsum("bhi,bhpi->bhp", Ch[:, 0], h)[:, None]  # (B,1,H,P)
        new_cache = {"conv": conv_state, "state": h}
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
    else:
        xbc_act, conv_state = _causal_conv(
            xbc, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
        xh = xbc_act[..., :di].reshape(B, S, H, P)
        Bm = xbc_act[..., di:di + G * N].reshape(B, S, G, N)
        Cm = xbc_act[..., di + G * N:].reshape(B, S, G, N)
        y, hT = _ssd_chunked(xh, dt.astype(jnp.float32), A, Bm, Cm,
                             min(cfg.ssm_chunk, S),
                             mm_dtype=cfg.dtype("compute"))
        y = y.astype(jnp.float32) + \
            p["D"].astype(jnp.float32)[None, None, :, None] * \
            xh.astype(jnp.float32)
        if cache is not None:
            new_cache = {"conv": conv_state, "state": hT}

    # gated RMSNorm + out projection
    yf = y.reshape(B, S, di)
    gated = yf * jax.nn.silu(z.astype(jnp.float32))
    var = (gated ** 2).mean(-1, keepdims=True)
    yn = gated * jax.lax.rsqrt(var + 1e-6) * p["norm_w"].astype(jnp.float32)
    out = jnp.matmul(yn.astype(cdt), p["out_proj"].astype(cdt))
    return out, new_cache
