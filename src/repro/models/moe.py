"""Mixture-of-Experts layer: top-k router, shared + routed experts, EP.

Dispatch is *gather-based* (zero-FLOP data movement instead of the
(T, E, C) one-hot einsum, which would double the compiled FLOPs of the
671B cell — see EXPERIMENTS.md §Perf): tokens are grouped (one group per
sequence for train/prefill; one group for decode), each group scatters
its top-k slot assignments into per-expert capacity buffers, experts run
as one batched einsum sharded over the ``model`` axis (EP), and results
gather back with router weights. Capacity overflow drops (standard
token-dropping MoE); aux load-balance + router-z losses are returned.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.partition import constrain
from .builder import Builder
from .layers import apply_mlp, init_mlp


def init_moe(b: Builder, cfg: ArchConfig, stack: Optional[int] = None,
             name: str = "moe"):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    st = (stack,) if stack else ()
    sta = ("layers",) if stack else ()
    with b.scope(name):
        b.param("router", st + (d, E), sta + (None, None),
                dtype=jnp.float32)
        b.param("w_gate", st + (E, d, f), sta + ("experts", "fsdp", None))
        b.param("w_up", st + (E, d, f), sta + ("experts", "fsdp", None))
        b.param("w_down", st + (E, f, d), sta + ("experts", None, "fsdp"))
        if cfg.num_shared_experts:
            init_mlp(b, cfg, cfg.moe_d_ff * cfg.num_shared_experts,
                     stack, name="shared")


def _topk_with_slots(gates: jax.Array, top_k: int, capacity: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per group: gates (T, E) -> (expert_id, slot, weight) each (T, k).

    Slot = position within the expert's capacity buffer, computed by a
    cumulative count over the flattened (k, T) assignment order (slot
    >= capacity drops the token for that expert).
    """
    T, E = gates.shape
    w, idx = jax.lax.top_k(gates, top_k)            # (T, k)
    # assignment order: slot priority by k first (primary routes win),
    # then token order — matches standard dropping semantics.
    flat = idx.T.reshape(-1)                        # (k*T,)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)   # (k*T, E)
    pos = jnp.cumsum(onehot, axis=0) - 1            # (k*T, E)
    slot_flat = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    slot = slot_flat.reshape(top_k, T).T            # (T, k)
    return idx, slot, w


def apply_moe(p, x: jax.Array, cfg: ArchConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (out, aux_loss)."""
    B, S, d = x.shape
    E, k, f = cfg.num_experts, cfg.top_k, cfg.moe_d_ff
    xg = x.reshape(B, S, d)                          # groups = sequences
    G, T = B, S
    cap = max(4, int((T * k / E) * cfg.moe_capacity_factor))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)          # (G, T, E)
    idx, slot, w = jax.vmap(
        lambda g: _topk_with_slots(g, k, cap))(gates)  # (G, T, k) each
    w = w / (w.sum(-1, keepdims=True) + 1e-9)        # renormalize top-k

    keep = slot < cap                                # (G, T, k)
    # scatter token rows into (G, E*cap) dispatch buffers
    flat_slot = idx * cap + slot                     # (G, T, k)
    flat_slot = jnp.where(keep, flat_slot, E * cap)  # overflow bin
    token_of_slot = jnp.full((G, E * cap + 1), T, jnp.int32)

    def scatter_g(tos, fs):
        src = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                               fs.shape).reshape(-1)
        return tos.at[fs.reshape(-1)].set(src, mode="drop")

    token_of_slot = jax.vmap(scatter_g)(token_of_slot, flat_slot)
    token_of_slot = token_of_slot[:, :E * cap]       # (G, E*cap)
    # gather token activations into expert buffers (pad row = zeros)
    xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    x_e = jnp.take_along_axis(
        xg_pad, token_of_slot[:, :, None].astype(jnp.int32), axis=1)
    x_e = x_e.reshape(G, E, cap, d)
    x_e = constrain(x_e, (None, "act_experts", None, None))

    # expert FFN (SwiGLU), EP-sharded over E
    cdt = x.dtype
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x_e,
                               p["w_gate"].astype(cdt))) * \
        jnp.einsum("gecd,edf->gecf", x_e, p["w_up"].astype(cdt))
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cdt))
    y_e = y_e.reshape(G, E * cap, d)

    if cfg.moe_combine == "gather":
        # gather back: token t takes its k slots, weighted. Crosses the
        # EP shard boundary per token -> XLA all-gathers y_e (G, E*cap, d)
        # (measured: dominates the 671B train cell's collective term).
        safe_slot = jnp.where(keep, idx * cap + slot, 0)
        y_tok = jnp.take_along_axis(
            y_e, safe_slot.reshape(G, T * k)[:, :, None].astype(jnp.int32),
            axis=1).reshape(G, T, k, d)
        y = (y_tok * (w * keep)[..., None].astype(cdt)).sum(axis=2)
    else:
        # scatter-add combine (§Perf): each EP shard scatter-adds its own
        # experts' outputs into (G, T, d) partials; the cross-shard sum is
        # an all-reduce of (G, T, d) — E*cap/T smaller on the wire.
        w_slot = jnp.zeros((G, E * cap + 1), jnp.float32)
        w_flat = (w * keep).astype(jnp.float32)

        def scatter_w(ws, fs, vals):
            return ws.at[fs.reshape(-1)].set(vals.reshape(-1), mode="drop")

        w_slot = jax.vmap(scatter_w)(w_slot, flat_slot, w_flat)
        w_slot = w_slot[:, :E * cap]

        def combine_g(ys, idxs, ws):
            acc = jnp.zeros((T + 1, d), cdt)
            return acc.at[idxs].add(ys * ws[:, None].astype(cdt),
                                    mode="drop")[:T]

        y = jax.vmap(combine_g)(y_e, token_of_slot, w_slot)
    out = y.reshape(B, S, d)

    if cfg.num_shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg)

    # aux losses (computed over all tokens)
    me = gates.mean(axis=(0, 1))                          # (E,)
    onehot_primary = jax.nn.one_hot(idx[..., 0], E)       # (G, T, E)
    ce = onehot_primary.mean(axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)
    zl = cfg.router_z_weight * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, aux + zl
