"""Parameter builder: define each parameter once, get (params, logical axes).

Model code calls ``b.param(name, shape, axes)`` inside nested scopes; the
builder produces either real initialized arrays or ShapeDtypeStructs
(``abstract=True`` — the dry-run path allocates nothing), plus a matching
pytree of logical axis tuples consumed by :mod:`repro.sharding`.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class Builder:
    def __init__(self, key: Optional[jax.Array], abstract: bool = False,
                 dtype=jnp.float32):
        self._key = key
        self.abstract = abstract
        self.default_dtype = dtype
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}
        self._scopes: list = []

    # ---------------------------------------------------------------- #
    @contextlib.contextmanager
    def scope(self, name: str):
        self._scopes.append(str(name))
        try:
            yield self
        finally:
            self._scopes.pop()

    def _place(self, tree: Dict, name: str, value) -> None:
        d = tree
        for s in self._scopes:
            d = d.setdefault(s, {})
        assert name not in d, f"duplicate param {'/'.join(self._scopes + [name])}"
        d[name] = value

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # ---------------------------------------------------------------- #
    def param(self, name: str, shape: Sequence[int], axes: Sequence,
              init: str = "fan_in", fan_axis: int = -2,
              dtype=None, scale: float = 1.0):
        """Register one parameter.

        init: 'fan_in' (normal, std=scale/sqrt(fan_in)), 'normal'
        (std=scale), 'zeros', 'ones'. ``fan_axis`` picks the fan-in dim
        for stacked (layers-first) params.
        """
        shape = tuple(int(s) for s in shape)
        axes = tuple(axes)
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.default_dtype
        if self.abstract:
            value = jax.ShapeDtypeStruct(shape, dtype)
        elif init == "zeros":
            value = jnp.zeros(shape, dtype)
        elif init == "ones":
            value = jnp.ones(shape, dtype)
        else:
            if init == "fan_in":
                fan = shape[fan_axis] if len(shape) >= 2 else shape[0]
                std = scale / math.sqrt(max(fan, 1))
            else:
                std = scale
            value = (jax.random.normal(self._next_key(), shape, jnp.float32)
                     * std).astype(dtype)
        self._place(self.params, name, value)
        self._place(self.axes, name, axes)
        return value

    def build(self) -> Tuple[PyTree, PyTree]:
        return self.params, self.axes


def count_params(params: PyTree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        params, is_leaf=lambda l: hasattr(l, "shape")))


def param_bytes(params: PyTree) -> int:
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(
                   params, is_leaf=lambda l: hasattr(l, "shape")))
