from .builder import Builder, count_params, param_bytes
from .lm import (decode_step, forward, init_cache, init_model, loss_fn,
                 prefill)

__all__ = ["Builder", "count_params", "param_bytes", "init_model",
           "forward", "loss_fn", "init_cache", "prefill", "decode_step"]
