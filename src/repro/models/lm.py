"""Model assembly: decoder-only LMs, MoE, SSM/hybrid, enc-dec, VLM prefix.

One runtime for all 10 assigned architectures. A model is a sequence of
*stages* (run-length-encoded block pattern); each stage's layer params are
stacked on a leading ``layers`` axis and applied with ``lax.scan`` (+
``jax.checkpoint`` remat). Zamba2's ``hybrid_attn`` blocks share ONE param
set across occurrences (its defining trick) while keeping per-occurrence
KV caches.

Block kinds:
  attn        pre-norm GQA/MLA + SwiGLU MLP           (dense archs)
  moe         pre-norm GQA/MLA + MoE FFN              (llama4, deepseek)
  ssm         pre-norm Mamba2 (no MLP)                (mamba2, zamba2)
  hybrid_attn shared attention+MLP block              (zamba2)
  xattn       self-attn + cross-attn + MLP            (whisper decoder)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.partition import constrain
from .attention import (apply_cross_attn, apply_gqa, apply_mla, encoder_kv,
                        init_gqa, init_mla)
from .builder import Builder
from .layers import (apply_mlp, apply_norm, embed_tokens, init_embeddings,
                     init_mlp, init_norm, unembed)
from .moe import apply_moe, init_moe
from .ssm import apply_mamba2, init_mamba2

PyTree = Any


# ------------------------------------------------------------------ #
# Init
# ------------------------------------------------------------------ #
def _init_attn_any(b: Builder, cfg: ArchConfig, stack):
    if cfg.attention == "mla":
        init_mla(b, cfg, stack)
    else:
        init_gqa(b, cfg, stack)


def _init_block(b: Builder, cfg: ArchConfig, kind: str, stack: int):
    st = stack if stack > 1 else None
    if kind in ("attn", "moe", "xattn"):
        init_norm(b, cfg, "norm1", cfg.d_model, st)
        _init_attn_any(b, cfg, st)
        if kind == "xattn":
            init_norm(b, cfg, "norm_x", cfg.d_model, st)
            init_gqa(b, cfg, st, name="xattn", cross=True)
        init_norm(b, cfg, "norm2", cfg.d_model, st)
        if kind == "moe":
            init_moe(b, cfg, st)
        else:
            init_mlp(b, cfg, cfg.d_ff, st)
    elif kind == "ssm":
        init_norm(b, cfg, "norm", cfg.d_model, st)
        init_mamba2(b, cfg, st)
    else:
        raise ValueError(kind)


def init_model(cfg: ArchConfig, key: Optional[jax.Array] = None,
               abstract: bool = False) -> Tuple[PyTree, PyTree]:
    """Returns (params, logical-axes) pytrees."""
    if key is None:
        key = jax.random.PRNGKey(0)
    b = Builder(key, abstract=abstract, dtype=cfg.dtype("param"))
    init_embeddings(b, cfg)
    init_norm(b, cfg, "final_norm", cfg.d_model)
    has_hybrid = any(k == "hybrid_attn" for k, _ in cfg.stages)
    if has_hybrid:
        with b.scope("shared_attn"):
            init_norm(b, cfg, "norm1", cfg.d_model, None)
            init_gqa(b, cfg, None)
            init_norm(b, cfg, "norm2", cfg.d_model, None)
            init_mlp(b, cfg, cfg.d_ff, None)
    with b.scope("stages"):
        for si, (kind, n) in enumerate(cfg.stages):
            if kind == "hybrid_attn":
                continue  # shared params above
            with b.scope(f"s{si}"):
                _init_block(b, cfg, kind, n)
    if cfg.encoder_layers:
        with b.scope("encoder"):
            with b.scope("blocks"):
                init_norm(b, cfg, "norm1", cfg.d_model, cfg.encoder_layers)
                init_gqa(b, cfg, cfg.encoder_layers)
                init_norm(b, cfg, "norm2", cfg.d_model, cfg.encoder_layers)
                init_mlp(b, cfg, cfg.d_ff, cfg.encoder_layers)
            init_norm(b, cfg, "final_norm", cfg.d_model)
    return b.build()


# ------------------------------------------------------------------ #
# Blocks (apply)
# ------------------------------------------------------------------ #
def _apply_attn_any(p, x, cfg, positions, cache, pos):
    if cfg.attention == "mla":
        return apply_mla(p["attn"], x, cfg, positions, cache, pos)
    return apply_gqa(p["attn"], x, cfg, positions, cache, pos)


def _block_apply(kind: str, p, x, cfg: ArchConfig, positions,
                 cache: Optional[Dict], pos, enc_kv=None):
    """Returns (x_out, new_cache_dict)."""
    new_cache: Dict = {}
    if kind in ("attn", "moe", "hybrid_attn", "xattn"):
        h = apply_norm(p["norm1"], x, cfg)
        attn_cache = cache.get("attn") if cache else None
        a, nc = _apply_attn_any(p, h, cfg, positions, attn_cache, pos)
        if nc is not None:
            new_cache["attn"] = nc
        x = x + a
        if kind == "xattn":
            h = apply_norm(p["norm_x"], x, cfg)
            x = x + apply_cross_attn(p["xattn"], h, cfg, enc_kv)
        h = apply_norm(p["norm2"], x, cfg)
        if kind == "moe":
            f, aux = apply_moe(p["moe"], h, cfg)
        else:
            f, aux = apply_mlp(p["mlp"], h, cfg), jnp.float32(0)
        x = x + f
        return x, new_cache, aux
    elif kind == "ssm":
        h = apply_norm(p["norm"], x, cfg)
        ssm_cache = cache.get("ssm") if cache else None
        s, nc = apply_mamba2(p["ssm"], h, cfg, ssm_cache, pos)
        if nc is not None:
            new_cache["ssm"] = nc
        return x + s, new_cache, jnp.float32(0)
    raise ValueError(kind)


def _run_stages(params, cfg: ArchConfig, x, positions,
                cache: Optional[Dict], pos, enc_kv_tree=None,
                with_cache: bool = False):
    """Apply all stages; returns (x, new_cache, aux_total)."""
    aux_total = jnp.float32(0)
    new_cache: Dict = {}
    remat_policy = None
    if cfg.remat == "dots":
        remat_policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable

    for si, (kind, n) in enumerate(cfg.stages):
        key = f"s{si}"
        stage_cache = (cache or {}).get(key)
        enc_kv = (enc_kv_tree or {}).get(key) if kind == "xattn" else None
        if kind == "hybrid_attn":
            p = params["shared_attn"]
            assert n == 1, "hybrid stages are single occurrences"
            x, nc, aux = _block_apply(kind, p, x, cfg, positions,
                                      stage_cache, pos)
            aux_total += aux
            if with_cache:
                new_cache[key] = nc
            continue
        p_stack = params["stages"][key]
        if n == 1:
            if kind == "xattn":
                x, nc, aux = _block_apply(kind, p_stack, x, cfg, positions,
                                          stage_cache, pos, enc_kv)
            else:
                x, nc, aux = _block_apply(kind, p_stack, x, cfg, positions,
                                          stage_cache, pos)
            aux_total += aux
            if with_cache:
                new_cache[key] = nc
            continue

        # scan over the stacked layers of this stage
        def body(carry, xs):
            h, aux_c = carry
            p_layer, cache_layer, ekv_layer = xs
            h2, nc, aux = _block_apply(kind, p_layer, h, cfg, positions,
                                       cache_layer, pos, ekv_layer)
            return (h2, aux_c + aux), nc

        if cfg.remat != "none":
            body = jax.checkpoint(body, policy=remat_policy,
                                  prevent_cse=False)
        # params define the scan length; cache/enc_kv thread through as
        # stacked pytrees, or leafless {} when absent.
        xs = (p_stack,
              stage_cache if stage_cache is not None else {},
              enc_kv if enc_kv is not None else {})
        if cfg.scan_stages:
            (x, aux_s), ncs = jax.lax.scan(body, (x, jnp.float32(0)), xs)
        else:
            # unrolled (dry-run/roofline path): identical math, flat HLO
            ncs_list = []
            aux_s = jnp.float32(0)
            for i in range(n):
                xs_i = jax.tree.map(lambda a: a[i], xs)
                (x, aux_s), nc_i = body((x, aux_s), xs_i)
                ncs_list.append(nc_i)
            ncs = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs_list) \
                if ncs_list and jax.tree.leaves(ncs_list[0]) else {}
        aux_total += aux_s
        if with_cache:
            new_cache[key] = ncs
        x = constrain(x, ("act_batch", "act_seq", None))
    return x, new_cache, aux_total


# ------------------------------------------------------------------ #
# Encoder (whisper) + frontend fusion
# ------------------------------------------------------------------ #
def _run_encoder(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d_model) stub frame embeddings (already projected if
    frontend_dim == d_model, else projected by frontend_proj)."""
    x = frames
    B, F, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
    enc = params["encoder"]

    def body(h, p_layer):
        a = apply_norm(p_layer["norm1"], h, cfg)
        # non-causal self attention: reuse GQA with full mask via window=0
        # and causal disabled by giving every query the final position.
        out, _ = apply_gqa(p_layer["attn"], a, cfg,
                           positions, None, None)
        h = h + out
        m = apply_norm(p_layer["norm2"], h, cfg)
        h = h + apply_mlp(p_layer["mlp"], m, cfg)
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_stages:
        x, _ = jax.lax.scan(body, x, enc["blocks"])
    else:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], enc["blocks"]))
    return apply_norm(enc["final_norm"], x, cfg)


def _fuse_frontend(params, cfg: ArchConfig, tok_embeds: jax.Array,
                   frontend: Optional[jax.Array]):
    """VLM early fusion: project patch embeddings and prepend."""
    if frontend is None or cfg.frontend == "none":
        return tok_embeds, 0
    from .layers import apply_linear
    fe = apply_linear(params["frontend_proj"], frontend.astype(
        tok_embeds.dtype), cfg)
    return jnp.concatenate([fe, tok_embeds], axis=1), fe.shape[1]


# ------------------------------------------------------------------ #
# Public entry points
# ------------------------------------------------------------------ #
def forward(params, cfg: ArchConfig, tokens: jax.Array,
            frontend: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Full causal forward. Returns (logits, aux_loss). For enc-dec archs
    ``frontend`` feeds the encoder; for VLM it prepends to the sequence."""
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    enc_kv_tree = None
    n_prefix = 0
    if cfg.encoder_layers:
        frames = frontend.astype(x.dtype)
        enc_out = _run_encoder(params, cfg, frames)
        enc_kv_tree = _enc_kv_tree(params, cfg, enc_out)
    else:
        x, n_prefix = _fuse_frontend(params, cfg, x, frontend)
    Sp = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Sp)[None], (B, Sp))
    x, _, aux = _run_stages(params, cfg, x, positions, None, None,
                            enc_kv_tree)
    x = apply_norm(params["final_norm"], x, cfg)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = unembed(params, x, cfg)
    return logits, aux


def _enc_kv_tree(params, cfg: ArchConfig, enc_out: jax.Array) -> Dict:
    """Precompute per-stage cross-attention K/V from encoder output."""
    tree = {}
    for si, (kind, n) in enumerate(cfg.stages):
        if kind != "xattn":
            continue
        p = params["stages"][f"s{si}"]
        if n == 1:
            tree[f"s{si}"] = encoder_kv(p["xattn"], enc_out, cfg)
        else:
            tree[f"s{si}"] = jax.vmap(
                lambda pl: encoder_kv(pl["xattn"], enc_out, cfg))(p)
    return tree


def loss_fn(params, cfg: ArchConfig, batch: Dict) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy (+ MoE aux). batch: tokens, labels
    [, frontend]."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("frontend"))
    labels = batch["labels"]
    valid = (labels >= 0)
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    xent = -(ll * valid).sum() / denom
    loss = xent + aux
    return loss, {"loss": loss, "xent": xent, "aux": aux,
                  "tokens": denom}


# ------------------------------------------------------------------ #
# Serving: cache init / prefill / decode
# ------------------------------------------------------------------ #
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               abstract: bool = False) -> Tuple[PyTree, PyTree]:
    """Returns (cache, logical-axes). Layout per stage; stacked on layers
    for scanned stages."""
    dt = cfg.dtype("compute")
    H, K, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    di = cfg.d_inner
    P, N, Hs = cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_heads
    Wc = cfg.ssm_conv
    dconv = di + 2 * cfg.ssm_groups * N

    def mk(shape, dtype, axes):
        arr = (jax.ShapeDtypeStruct(shape, dtype) if abstract
               else jnp.zeros(shape, dtype))
        return arr, axes

    cache, axes = {}, {}
    for si, (kind, n) in enumerate(cfg.stages):
        key = f"s{si}"
        lead = (n,) if n > 1 else ()
        la = ("layers",) if n > 1 else ()
        if kind in ("attn", "moe", "hybrid_attn", "xattn"):
            if cfg.attention == "mla":
                c1, a1 = mk(lead + (batch, max_len, cfg.kv_lora_rank), dt,
                            la + ("act_batch", "cache_seq", None))
                c2, a2 = mk(lead + (batch, max_len, cfg.qk_rope_dim), dt,
                            la + ("act_batch", "cache_seq", None))
                cache[key] = {"attn": {"ckv": c1, "krope": c2}}
                axes[key] = {"attn": {"ckv": a1, "krope": a2}}
            else:
                ck, ak = mk(lead + (batch, max_len, K, dh), dt,
                            la + ("act_batch", "cache_seq", "kv", None))
                cv, av = mk(lead + (batch, max_len, K, dh), dt,
                            la + ("act_batch", "cache_seq", "kv", None))
                cache[key] = {"attn": {"k": ck, "v": cv}}
                axes[key] = {"attn": {"k": ak, "v": av}}
        elif kind == "ssm":
            cc, ac = mk(lead + (batch, Wc - 1, dconv), dt,
                        la + ("act_batch", None, "ff"))
            cs, as_ = mk(lead + (batch, Hs, P, N), jnp.float32,
                         la + ("act_batch", None, None, None))
            cache[key] = {"ssm": {"conv": cc, "state": cs}}
            axes[key] = {"ssm": {"conv": ac, "state": as_}}
    return cache, axes


def prefill(params, cfg: ArchConfig, tokens: jax.Array, cache: PyTree,
            frontend: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, PyTree]:
    """Run the full prompt, fill the cache. Returns (last-token logits,
    cache)."""
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    enc_kv_tree = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, frontend.astype(x.dtype))
        enc_kv_tree = _enc_kv_tree(params, cfg, enc_out)
    else:
        x, _ = _fuse_frontend(params, cfg, x, frontend)
    Sp = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Sp)[None], (B, Sp))
    x, new_cache, _ = _run_stages(params, cfg, x, positions, cache, None,
                                  enc_kv_tree, with_cache=True)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params, x[:, -1:], cfg)
    if enc_kv_tree is not None:
        new_cache["enc_kv"] = enc_kv_tree
    return logits, new_cache


def decode_step(params, cfg: ArchConfig, cache: PyTree, token: jax.Array,
                pos: jax.Array) -> Tuple[jax.Array, PyTree]:
    """One decode step. token: (B, 1) int32; pos: scalar int32 (current
    absolute position). Returns (logits (B,1,V), new cache)."""
    B = token.shape[0]
    x = embed_tokens(params, token, cfg)
    positions = jnp.full((B, 1), pos, jnp.int32)
    enc_kv_tree = cache.get("enc_kv") if isinstance(cache, dict) else None
    x, new_cache, _ = _run_stages(params, cfg, x, positions, cache, pos,
                                  enc_kv_tree, with_cache=True)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params, x, cfg)
    if enc_kv_tree is not None:
        new_cache["enc_kv"] = enc_kv_tree
    return logits, new_cache
