"""Shared neural layers: norms, RoPE, MLPs, embeddings."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.partition import constrain
from .builder import Builder


# ------------------------------------------------------------------ #
# Norms
# ------------------------------------------------------------------ #
def init_norm(b: Builder, cfg: ArchConfig, name: str, dim: int,
              stack: Optional[int] = None):
    st = (stack,) if stack else ()
    sta = ("layers",) if stack else ()
    with b.scope(name):
        b.param("scale", st + (dim,), sta + (None,), init="ones")
        if cfg.norm == "layernorm":
            b.param("bias", st + (dim,), sta + (None,), init="zeros")


def apply_norm(p, x: jax.Array, cfg: ArchConfig, eps: float = 1e-5
               ) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_heads(scale: jax.Array, x: jax.Array, eps: float = 1e-6
                   ) -> jax.Array:
    """qk-norm: RMSNorm over the head_dim of (B, S, H, dh)."""
    xf = x.astype(jnp.float32)
    var = (xf ** 2).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ------------------------------------------------------------------ #
# RoPE
# ------------------------------------------------------------------ #
def rope_angles(positions: jax.Array, dim: int, theta: float
                ) -> Tuple[jax.Array, jax.Array]:
    """(..., S) int positions -> cos/sin of shape (..., S, dim/2), f32."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, dh); cos/sin: (B, S, dh/2). Half-split convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ #
# Dense + MLP
# ------------------------------------------------------------------ #
def init_linear(b: Builder, cfg: ArchConfig, name: str, d_in: int,
                d_out: int, axes: Tuple, stack: Optional[int] = None,
                scale: float = 1.0):
    st = (stack,) if stack else ()
    sta = ("layers",) if stack else ()
    with b.scope(name):
        b.param("w", st + (d_in, d_out), sta + tuple(axes), scale=scale)
        if cfg.use_bias:
            bias_axes = (axes[-1],) if axes[-1] in ("heads", "kv", "ff",
                                                    "vocab") else (None,)
            b.param("b", st + (d_out,), sta + bias_axes, init="zeros")


def apply_linear(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    y = jnp.matmul(x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_mlp(b: Builder, cfg: ArchConfig, d_ff: int,
             stack: Optional[int] = None, name: str = "mlp"):
    """SwiGLU (gate/up/down)."""
    d = cfg.d_model
    with b.scope(name):
        init_linear(b, cfg, "gate", d, d_ff, ("fsdp", "ff"), stack)
        init_linear(b, cfg, "up", d, d_ff, ("fsdp", "ff"), stack)
        init_linear(b, cfg, "down", d_ff, d, ("ff", "fsdp"), stack)


def apply_mlp(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    g = jax.nn.silu(apply_linear(p["gate"], x, cfg))
    u = apply_linear(p["up"], x, cfg)
    return apply_linear(p["down"], g * u, cfg)


# ------------------------------------------------------------------ #
# Embeddings / unembedding
# ------------------------------------------------------------------ #
def init_embeddings(b: Builder, cfg: ArchConfig):
    V = cfg.padded_vocab
    b.param("embed", (V, cfg.d_model), ("vocab", "embed"), init="normal",
            scale=1.0)
    if not cfg.tie_embeddings:
        b.param("unembed", (cfg.d_model, V), ("embed", "vocab"))
    if cfg.frontend != "none":
        init_linear(b, cfg, "frontend_proj", cfg.frontend_dim, cfg.d_model,
                    ("fsdp", "embed"))
    if cfg.encoder_layers:
        # decoder cross-attends encoder output; encoder gets its own stack
        pass


def embed_tokens(params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = params["embed"].astype(cfg.dtype("compute"))[tokens]
    return constrain(x, ("act_batch", None, None))


def unembed(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    logits = jnp.matmul(x, w)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    # mask padded vocab tail
    V = cfg.padded_vocab
    if V != cfg.vocab_size:
        neg = jnp.finfo(logits.dtype).min
        mask = jnp.arange(V) < cfg.vocab_size
        logits = jnp.where(mask, logits, neg)
    return logits
