"""Live graph mutations through the incremental delta-build engine.

Stands up an :class:`RLCService` over a generated graph, then streams
edge insert/delete batches through :meth:`RLCService.apply_delta`: each
delta incrementally re-derives only the affected ``(hub, direction)``
phases (bit-identical to a full rebuild), re-freezes only the dirty row
ranges, and evicts only the cached answers whose ``(s, t)`` rows went
dirty. Every answer is cross-checked against the BiBFS oracle on the
mutated graph, and the replay/re-run accounting is printed per delta.

    PYTHONPATH=src python examples/delta_updates.py
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core.baselines import bibfs_rlc
from repro.core.minimum_repeat import enumerate_mrs
from repro.graphgen import erdos_renyi, random_delta
from repro.service import RLCService, ServiceConfig


def main():
    rng = np.random.default_rng(7)
    g = erdos_renyi(num_vertices=300, avg_degree=2.2, num_labels=4,
                    seed=42)
    print(f"graph: {g.summary()}")

    with RLCService.build(
            g, ServiceConfig(k=2, use_device=False, build_backend="numpy",
                             cache_capacity=2048,
                             delta_fallback_frac=0.5)) as svc:
        queries = [(int(rng.integers(300)), int(rng.integers(300)), mr)
                   for mr in enumerate_mrs(4, 2) for _ in range(4)]
        svc.query_batch(queries)          # warm the cache
        print(f"index: {svc.index.num_entries()} entries; "
              f"cache primed with {len(svc.cache)} answers")

        for step in range(5):
            delta = random_delta(svc.graph, 2, 2, rng)
            t0 = time.perf_counter()
            summary = svc.apply_delta(delta)
            dt = (time.perf_counter() - t0) * 1e3
            d = summary["delta"]
            print(f"delta {step}: +{len(delta.inserts)}/-"
                  f"{len(delta.deletes)} edges in {dt:.1f}ms — "
                  f"replayed {d['phases_replayed']}/{d['phases_total']} "
                  f"phases, re-ran {d['phases_rerun']} "
                  f"(causes {d['causes']}), {d['dirty_rows']} dirty rows, "
                  f"{summary['cache_evicted']} cache evictions"
                  + (" [fallback rebuild]" if d["fallback"] else ""))

            answers = svc.query_batch(queries)
            want = [bibfs_rlc(svc.graph, s, t, mr) for s, t, mr in queries]
            assert answers == want, "delta-served answers diverged!"
        st = svc.stats()
        print(f"done: {st['queries_served']} queries served, "
              f"{st['deltas_applied']} deltas applied, cache hit-rate "
              f"{st['cache']['hit_rate']:.2f}, invalidations "
              f"{st['cache']['invalidations']}")


if __name__ == "__main__":
    main()
