"""Distributed RLC index build + query serving on an 8-device CPU mesh
(the same code path the production (16,16)/(2,16,16) meshes run).

    PYTHONPATH=src python examples/distributed_index.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core.baselines import bfs_rlc  # noqa: E402
from repro.core.device_index import DeviceIndex  # noqa: E402
from repro.core.distributed import (distributed_build,  # noqa: E402
                                    distributed_query_batch, make_rlc_mesh)
from repro.core.minimum_repeat import mr_id_space  # noqa: E402
from repro.graphgen import erdos_renyi  # noqa: E402


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = make_rlc_mesh(data=4, pod=2)
    print(f"mesh: {dict(mesh.shape)}")

    g = erdos_renyi(num_vertices=64, avg_degree=3.0, num_labels=3, seed=5)
    k = 2
    idx, eng = distributed_build(g, k, mesh, hub_batch=8)
    print(f"distributed build: {idx.num_entries()} entries over "
          f"{len(eng.mrs)} minimum repeats")

    dev = DeviceIndex.from_index(idx, g.num_labels)
    ids = mr_id_space(g.num_labels, k)
    rng = np.random.default_rng(0)
    Q = 512
    s = rng.integers(0, g.num_vertices, Q).astype(np.int32)
    t = rng.integers(0, g.num_vertices, Q).astype(np.int32)
    mr_list = list(ids.items())
    pick = rng.integers(0, len(mr_list), Q)
    m = np.array([mr_list[i][1] for i in pick], np.int32)
    ans = distributed_query_batch(dev, s, t, m, mesh)
    # verify a sample against the oracle
    for i in range(0, Q, 37):
        L = mr_list[pick[i]][0]
        assert bool(ans[i]) == bfs_rlc(g, int(s[i]), int(t[i]), L)
    print(f"served {Q} queries on the mesh: {int(ans.sum())} true "
          f"(oracle-verified sample)")


if __name__ == "__main__":
    main()
