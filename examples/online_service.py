"""Online RLC query service, end to end on CPU.

Builds the RLC index for a generated graph, stands up :class:`RLCService`
(build -> freeze -> device layout -> serve), then answers a mixed
true/false query stream — textual ``(label ...)+`` expressions included —
through the result cache and micro-batching scheduler, checking every
answer against the BiBFS oracle. Prints per-backend latency and the cache
hit-rate.

    PYTHONPATH=src python examples/online_service.py
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.baselines import bibfs_rlc
from repro.core.queries import biased_true_queries
from repro.graphgen import erdos_renyi
from repro.service import ExpressionError, RLCService, ServiceConfig


def main():
    rng = np.random.default_rng(0)
    g = erdos_renyi(num_vertices=250, avg_degree=3.5, num_labels=4, seed=42)
    print(f"graph: {g.summary()}")

    svc = RLCService.build(
        g, ServiceConfig(k=2, batch_size=16, max_wait_ms=2.0,
                         cache_capacity=512,
                         label_names={"knows": 0, "worksFor": 1,
                                      "debits": 2, "credits": 3}))
    st = svc.stats()["index"]
    print(f"index: {st['entries']} entries, {st['size_bytes']} bytes, "
          f"C={st['num_mrs']} MRs, device={st['device']}")

    # -- a few single queries through the textual parser ---------------- #
    for expr in ["(knows)+", "(debits credits)+", "(0 1)+",
                 '("knows worksFor")+']:
        s, t = int(rng.integers(250)), int(rng.integers(250))
        print(f"  Q({s}, {t}, {expr}) = {svc.query(s, t, expr)}")
    try:
        svc.query(0, 1, "(knows worksFor debits)+")   # |MR| = 3 > k = 2
    except ExpressionError as e:
        print(f"  rejected as expected: {e}")

    # -- mixed true/false stream with Zipf popularity ------------------- #
    qs = biased_true_queries(g, k=2, n=150, seed=7)
    pool = qs.true_queries + qs.false_queries
    rng.shuffle(pool)
    w = np.arange(1, len(pool) + 1, dtype=np.float64) ** -1.0
    w /= w.sum()
    stream = [pool[i] for i in rng.choice(len(pool), size=1500, p=w)]
    print(f"\nserving {len(stream)} requests "
          f"({len(qs.true_queries)} true / {len(qs.false_queries)} false "
          f"distinct queries, Zipf popularity) ...")

    answers = []
    for i in range(0, len(stream), 50):   # arrivals in chunks of 50
        answers.extend(svc.query_batch(stream[i:i + 50]))

    # verify against the oracle
    wrong = sum(1 for (s, t, L), a in zip(stream, answers)
                if a != bibfs_rlc(g, s, t, L))
    n_true = sum(bool(a) for a in answers)
    print(f"answers: {n_true} true / {len(answers) - n_true} false, "
          f"{wrong} oracle mismatches")
    assert wrong == 0

    stats = svc.stats()
    c = stats["cache"]
    print(f"\ncache: {c['hits']} hits / {c['misses']} misses "
          f"(hit-rate {c['hit_rate']:.1%}, {c['evictions']} evictions)")
    sch = stats["scheduler"]
    print(f"scheduler: {sch['batches_full']} full, "
          f"{sch['batches_deadline']} deadline, "
          f"{sch['batches_drain']} drain flushes")
    print("backends:")
    for name, b in stats["executor"]["backends"].items():
        print(f"  {name:7s} {b['batches']:4d} batches "
              f"{b['queries']:5d} queries  p50 {b['p50_ms']:7.3f} ms  "
              f"p99 {b['p99_ms']:7.3f} ms  {b['qps']:9.0f} q/s")
    print(f"  fallbacks: {stats['executor']['fallbacks']}")


if __name__ == "__main__":
    main()
