"""Paper Example 1: fraud-pattern reachability on the Fig. 1 network.

Detects (debits, credits)+ money-movement chains between accounts with
the RLC index, then scales the same query workload up on a synthetic
transaction graph served by the batched device engine.

    PYTHONPATH=src python examples/fraud_detection.py
"""
import numpy as np

from repro.core.device_index import DeviceIndex
from repro.core.index_builder import build_rlc_index
from repro.core.queries import generate_queries
from repro.graphgen import fig1_graph, random_labeled_graph


def main():
    g, names, labels = fig1_graph()
    idx = build_rlc_index(g, k=3)
    D, C = labels["debits"], labels["credits"]
    K, W = labels["knows"], labels["worksFor"]

    q1 = idx.query(names["A14"], names["A19"], (D, C))
    q2 = idx.query(names["P10"], names["P13"], (K, K, W))
    print(f"Q1(A14, A19, (debits.credits)+) = {q1}   (paper: true)")
    print(f"Q2(P10, P13, (knows.knows.worksFor)+) = {q2}   (paper: false)")
    assert q1 is True and q2 is False

    # scale up: synthetic transaction network, batched screening
    print("\nScaled screening on a synthetic transaction graph:")
    big = random_labeled_graph(num_vertices=300, num_edges=1500,
                               num_labels=5, seed=13, self_loop_frac=0.02)
    bidx = build_rlc_index(big, k=2)
    dev = DeviceIndex.from_index(bidx, big.num_labels)
    qs = generate_queries(big, 2, n_true=128, n_false=128, seed=3)
    trips = qs.all()
    s = np.array([q[0] for q in trips], np.int32)
    t = np.array([q[1] for q in trips], np.int32)
    m = np.array([dev.mr_ids[q[2]] for q in trips], np.int32)
    ans = dev.query_batch(s, t, m)
    hits = int(ans.sum())
    print(f"  screened {len(trips)} account pairs in one device batch: "
          f"{hits} suspicious chains found")
    assert hits == len(qs.true_queries)


if __name__ == "__main__":
    main()
