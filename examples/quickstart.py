"""Quickstart: build an RLC index on the paper's Fig. 2 graph and answer
the Example 4 queries.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.index_builder import build_rlc_index_with_stats
from repro.core.baselines import bfs_rlc
from repro.graphgen import fig2_graph


def main():
    g, names = fig2_graph()
    print(f"Fig.2 graph: |V|={g.num_vertices} |E|={g.num_edges} "
          f"|L|={g.num_labels}")

    idx, stats = build_rlc_index_with_stats(g, k=2)
    print(f"RLC index built: {idx.num_entries()} entries "
          f"({idx.size_bytes()} bytes), condensed={idx.is_condensed()}")
    print(f"  pruned: PR1={stats.pruned_pr1} PR2={stats.pruned_pr2} "
          f"PR3 cuts={stats.pr3_cuts}")

    l1, l2 = 0, 1
    queries = [
        ("Q1 (v3 ->(l2.l1)+ v6)", names["v3"], names["v6"], (l2, l1)),
        ("Q2 (v1 ->(l2.l1)+ v2)", names["v1"], names["v2"], (l2, l1)),
        ("Q3 (v1 ->(l1)+    v3)", names["v1"], names["v3"], (l1,)),
    ]
    for label, s, t, L in queries:
        ans = idx.query(s, t, L)
        oracle = bfs_rlc(g, s, t, L)
        assert ans == oracle
        print(f"  {label}: {ans}   (oracle: {oracle})")

    # per-vertex index content, like the paper's Table II
    print("\nIndex entries (Table II layout):")
    for v in range(g.num_vertices):
        fmt = lambda d: ", ".join(
            f"(v{h+1},{'.'.join(f'l{x+1}' for x in mr)})"
            for h, mrs in sorted(d.items()) for mr in sorted(mrs))
        print(f"  v{v+1}: L_in=[{fmt(idx.l_in[v])}] "
              f"L_out=[{fmt(idx.l_out[v])}]")


if __name__ == "__main__":
    main()
