"""Serve a small LM with batched requests: prefill + greedy decode via
the same decode_step the decode_* dry-run cells lower.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

import jax

from repro.configs import get_config
from repro.models import init_model
from repro.serve import ServeEngine


def main():
    cfg = get_config("qwen3-0.6b-smoke")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    B, S0, steps = 4, 12, 16
    engine = ServeEngine(cfg, params, max_len=S0 + steps + 4,
                         batch_slots=B)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, S0)).astype(np.int32)
    out = engine.generate(prompts, steps=steps)
    print(f"prompts {prompts.shape} -> generated {out.shape}")
    for b in range(B):
        print(f"  req{b}: {prompts[b].tolist()} => {out[b].tolist()}")
    assert out.shape == (B, steps)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


if __name__ == "__main__":
    main()
