"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpoint/restart and straggler monitoring (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a 100M-param qwen3-family config (12L, d=768) on synthetic data;
prints the loss curve and survives an injected mid-run failure.
"""
import argparse
import os
import tempfile


from repro.configs.base import ArchConfig, dense_pattern, register
from repro.launch.train import run
from repro.models import count_params, init_model

CFG_100M = register(ArchConfig(
    name="examples-lm-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    block_pattern=dense_pattern(12),
    qk_norm=True,
    vocab_pad_multiple=128,
    param_dtype="float32",
    compute_dtype="float32",
))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    params, _ = init_model(CFG_100M, abstract=True)
    print(f"model: {count_params(params)/1e6:.1f}M params")

    ckpt = os.path.join(tempfile.gettempdir(), "repro_train_lm_ckpt")
    state, history, report = run(
        "examples-lm-100m", steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=ckpt, ckpt_every=50, lr=6e-4,
        log_every=20,
        fail_at={args.steps // 2: RuntimeError("injected node failure")})
    print(f"\nloss: {history[0]:.3f} -> {history[-1]:.3f} "
          f"({len(history)} effective steps)")
    print(f"restarts survived: {report.restarts}, "
          f"stragglers flagged: {len(report.straggler_steps)}")
    assert history[-1] < history[0]


if __name__ == "__main__":
    main()
