"""Sharded multi-host RLC serving, end to end on CPU.

Walks the whole distributed path: plan entry-balanced shards over the
frozen index, stand up :class:`ShardedRLCService` (4 shards x 2 replicas,
in-process shard workers), serve a Zipf stream through the two-sided
router — same-shard queries run locally, cross-shard queries ship s's
out-row digest to t's owning shard — then hot-swap a freshly rebuilt
index under the running service and keep serving. Every answer is checked
against the BiBFS oracle.

    PYTHONPATH=src python examples/sharded_service.py
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.baselines import bibfs_rlc
from repro.core.queries import biased_true_queries
from repro.graphgen import erdos_renyi
from repro.service import ShardedRLCService, ShardedServiceConfig


def main():
    rng = np.random.default_rng(0)
    n = 300
    g = erdos_renyi(num_vertices=n, avg_degree=3.5, num_labels=4, seed=42)
    print(f"graph: {g.summary()}")

    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, batch_size=16, max_wait_ms=2.0,
                                cache_capacity=512, num_shards=4,
                                num_replicas=2))
    print(f"plan: {svc.plan.as_dict()}")
    for sh in svc.stats()["shards"]:
        print(f"  shard {sh['shard']}: vertices [{sh['lo']}, {sh['hi']}) "
              f"{sh['entries']} entries {sh['size_bytes']} B "
              f"x{sh['replicas']} replicas device={sh['device']}")

    # -- Zipf stream through router + scatter/gather --------------------- #
    qs = biased_true_queries(g, k=2, n=150, seed=7)
    pool = qs.true_queries + qs.false_queries
    rng.shuffle(pool)
    w = np.arange(1, len(pool) + 1, dtype=np.float64) ** -1.0
    w /= w.sum()
    stream = [pool[i] for i in rng.choice(len(pool), size=1200, p=w)]
    print(f"\nserving {len(stream)} requests across 4 shards ...")

    answers = []
    for i in range(0, len(stream), 50):
        answers.extend(svc.query_batch(stream[i:i + 50]))
    wrong = sum(1 for (s, t, L), a in zip(stream, answers)
                if a != bibfs_rlc(g, s, t, L))
    n_true = sum(bool(a) for a in answers)
    print(f"answers: {n_true} true / {len(answers) - n_true} "
          f"false, {wrong} oracle mismatches")
    assert wrong == 0

    st = svc.stats()
    r = st["router"]
    print(f"router: {r['local']} local / {r['remote']} cross-shard "
          f"(local ratio {r['local_ratio']:.1%})")
    ex = st["executor"]
    print(f"fan-out: {ex['local']['batches']} local sub-batches, "
          f"{ex['remote']['batches']} remote "
          f"({ex['remote_joins_device']} device joins, "
          f"{ex['remote_joins_numpy']} numpy), "
          f"{ex['digest_bytes'] / 1024:.1f} KiB digests shipped")
    c = st["cache"]
    print(f"cache: hit-rate {c['hit_rate']:.1%}; "
          f"coalesced {st['scheduler']['coalesced']} duplicate in-flight")

    # -- hot swap under traffic ------------------------------------------ #
    g2 = erdos_renyi(num_vertices=n, avg_degree=5.0, num_labels=4, seed=43)
    print("\ngraph updated; rebuilding + rolling swap of every shard ...")
    gen = svc.hot_swap(graph=g2)
    print(f"now serving generation {gen}")
    answers2 = svc.query_batch(stream[:300])
    wrong2 = sum(1 for (s, t, L), a in zip(stream[:300], answers2)
                 if a != bibfs_rlc(g2, s, t, L))
    changed = sum(1 for a, b in zip(answers[:300], answers2) if a != b)
    print(f"post-swap: {wrong2} oracle mismatches, "
          f"{changed}/300 answers changed with the new graph")
    assert wrong2 == 0


if __name__ == "__main__":
    main()
