"""Chunked online-softmax attention == dense attention (bit-level within
tolerance), incl. sliding windows and prefill caches."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import forward, init_model
from repro.models.attention import (_attend_mha, _attend_mha_chunked,
                                    _causal_mask)


@pytest.mark.parametrize("S,chunk,window", [
    (64, 16, 0), (64, 8, 0), (128, 32, 48), (64, 64, 0), (96, 16, 24)])
def test_chunked_matches_dense(S, chunk, window):
    rng = np.random.default_rng(S + chunk + window)
    B, H, dh = 2, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    mask = _causal_mask(S, S, 0, window)[None, None]
    dense = _attend_mha(q, k, v, mask)
    chunked = _attend_mha_chunked(q, k, v, chunk, window)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_model_forward_with_chunked_attention():
    cfg = get_config("internlm2-1.8b-smoke").replace(attn_chunk=8)
    cfg_dense = get_config("internlm2-1.8b-smoke")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                         jnp.int32)
    lc, _ = forward(params, cfg, tokens)
    ld, _ = forward(params, cfg_dense, tokens)
    np.testing.assert_allclose(np.asarray(lc, np.float32),
                               np.asarray(ld, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_chunked_gradients_finite():
    cfg = get_config("qwen3-0.6b-smoke").replace(attn_chunk=8)
    from repro.models import loss_fn
    params, _ = init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (2, 16)), jnp.int32)}
    grads = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all()
