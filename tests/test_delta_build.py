"""Incremental (delta) build engine: the bit-identicality bar.

For random graphs and random insert/delete deltas, ``DeltaBuilder.apply``
must leave an index whose entries AND pruning counters are bit-identical
to a from-scratch build of the mutated graph — across chained deltas,
pruning-flag ablations, and dispatch modes (the no-mirror scalar path
included). Plus: GraphDelta validation, the fallback escape hatch, the
partial re-freeze, and the replay/dirty accounting.
"""
import os

import numpy as np
import pytest

from repro.build import (DeltaBuilder, GraphDelta, build_rlc_index_with_stats,
                         get_backend)
from repro.build.delta import BuildTrace
from repro.core.minimum_repeat import mr_id_space
from repro.graphgen import erdos_renyi, random_delta, random_labeled_graph


def entry_sets(idx):
    out = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_out)
                       for h, ms in d.items() for m in ms))
    inn = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_in)
                       for h, ms in d.items() for m in ms))
    return out, inn


def assert_delta_matches_rebuild(db: DeltaBuilder, flags=None):
    """Delta-applied state == fresh python AND numpy full rebuilds."""
    flags = flags or {}
    ref, ref_stats = build_rlc_index_with_stats(
        db.graph, db.k, backend="python", **flags)
    assert entry_sets(db.index) == entry_sets(ref)
    assert db.stats.counters() == ref_stats.counters()


# ------------------------------------------------------------------ #
# GraphDelta + LabeledGraph.apply_delta
# ------------------------------------------------------------------ #
def test_graph_delta_validation():
    g = random_labeled_graph(num_vertices=8, num_edges=20, num_labels=2,
                             seed=0)
    e0 = g.edges[0].tolist()
    missing = [[0, 0, 0]]
    if any((g.edges == np.array(missing[0])).all(axis=1)):
        missing = [[7, 1, 7]]
        assert not any((g.edges == np.array(missing[0])).all(axis=1))
    # deleting a present edge + inserting a fresh one: fine
    GraphDelta.of(missing, [e0]).validate(g)
    with pytest.raises(ValueError):   # inserting an existing edge
        GraphDelta.of([e0], []).validate(g)
    with pytest.raises(ValueError):   # deleting a missing edge
        GraphDelta.of([], missing).validate(g)
    with pytest.raises(ValueError):   # insert/delete overlap
        GraphDelta.of(missing, missing).validate(g)
    with pytest.raises(ValueError):   # vertex out of range
        GraphDelta.of([[99, 0, 0]], []).validate(g)
    with pytest.raises(ValueError):   # label out of range
        GraphDelta.of([[0, 9, 0]], []).validate(g)


def test_apply_delta_edge_set():
    g = random_labeled_graph(num_vertices=10, num_edges=30, num_labels=3,
                             seed=1)
    rng = np.random.default_rng(2)
    delta = random_delta(g, 3, 3, rng)
    g2 = g.apply_delta(delta)
    want = set(map(tuple, g.edges.tolist()))
    want -= set(map(tuple, delta.deletes.tolist()))
    want |= set(map(tuple, delta.inserts.tolist()))
    assert set(map(tuple, g2.edges.tolist())) == want
    assert g2.num_vertices == g.num_vertices
    assert g2.num_labels == g.num_labels
    # the original graph (and its cached CSRs) are untouched
    assert set(map(tuple, g.edges.tolist())) != want or not delta.num_changes


# ------------------------------------------------------------------ #
# The property sweep: bit-identical to full rebuilds
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("k,num_labels,loops", [
    (2, 2, 0.2), (2, 3, 0.0), (3, 2, 0.3)])
def test_delta_matches_rebuild_random(seed, k, num_labels, loops):
    g = random_labeled_graph(num_vertices=13, num_edges=45,
                             num_labels=num_labels, seed=seed,
                             self_loop_frac=loops)
    db = DeltaBuilder(g, k, fallback_frac=1.0)
    db.full()
    rng = np.random.default_rng(seed + 50)
    for _ in range(3):      # chained deltas reuse the carried state
        db.apply(random_delta(db.graph, 2, 2, rng))
        assert_delta_matches_rebuild(db)


@pytest.mark.parametrize("flags", [
    dict(use_pr1=False), dict(use_pr2=False), dict(use_pr3=False),
    dict(use_pr1=False, use_pr2=False, use_pr3=False)])
def test_delta_matches_rebuild_pruning_ablations(flags):
    g = random_labeled_graph(num_vertices=13, num_edges=45, num_labels=2,
                             seed=9, self_loop_frac=0.2)
    db = DeltaBuilder(g, 2, fallback_frac=1.0, **flags)
    db.full()
    rng = np.random.default_rng(77)
    for _ in range(2):
        db.apply(random_delta(db.graph, 2, 2, rng))
        ref, ref_stats = build_rlc_index_with_stats(
            db.graph, 2, backend="python", **flags)
        assert entry_sets(db.index) == entry_sets(ref)
        assert db.stats.counters() == ref_stats.counters()


@pytest.mark.parametrize("mode", ["scalar", "vector", "bits"])
def test_delta_matches_rebuild_modes(mode):
    """Every dispatch tier — including scalar, which runs without the
    packed mirror (the slow replay/diff paths)."""
    g = random_labeled_graph(num_vertices=12, num_edges=40, num_labels=2,
                             seed=3, self_loop_frac=0.15)
    db = DeltaBuilder(g, 2, fallback_frac=1.0, mode=mode)
    db.full()
    rng = np.random.default_rng(4)
    for _ in range(2):
        db.apply(random_delta(db.graph, 2, 2, rng))
        assert_delta_matches_rebuild(db)


def test_delta_insert_only_and_delete_only():
    g = random_labeled_graph(num_vertices=12, num_edges=40, num_labels=2,
                             seed=5, self_loop_frac=0.1)
    db = DeltaBuilder(g, 2, fallback_frac=1.0)
    db.full()
    rng = np.random.default_rng(6)
    db.apply(random_delta(db.graph, 3, 0, rng))
    assert_delta_matches_rebuild(db)
    db.apply(random_delta(db.graph, 0, 3, rng))
    assert_delta_matches_rebuild(db)


def test_empty_delta_is_identity():
    g = erdos_renyi(60, 2.5, 3, seed=8)
    db = DeltaBuilder(g, 2)
    db.full()
    before = entry_sets(db.index)
    counters = db.stats.counters()
    res = db.apply(GraphDelta.of([], []))
    assert res.phases_rerun == 0
    assert res.phases_replayed == res.phases_total
    assert not res.fallback
    assert len(res.dirty_out) == len(res.dirty_in) == 0
    assert entry_sets(db.index) == before
    assert db.stats.counters() == counters


def test_replay_actually_happens():
    """Guard against a vacuous always-rerun implementation: on a sparse
    graph a 2-edge delta must replay most phases."""
    g = erdos_renyi(200, 1.8, 4, seed=11)
    db = DeltaBuilder(g, 2, fallback_frac=1.0)
    db.full()
    rng = np.random.default_rng(12)
    res = db.apply(random_delta(db.graph, 1, 1, rng))
    assert not res.fallback
    assert res.phases_replayed > res.phases_total // 2
    assert res.phases_rerun + res.phases_replayed == res.phases_total
    assert sum(res.causes.values()) == res.phases_rerun
    assert_delta_matches_rebuild(db)


def test_fallback_threshold():
    """A tiny budget forces the escape hatch; results stay identical."""
    g = random_labeled_graph(num_vertices=16, num_edges=80, num_labels=2,
                             seed=13, self_loop_frac=0.2)
    db = DeltaBuilder(g, 2, fallback_frac=0.01)
    db.full()
    rng = np.random.default_rng(14)
    res = db.apply(random_delta(db.graph, 3, 3, rng))
    assert res.fallback
    assert db.fallbacks == 1
    assert_delta_matches_rebuild(db)
    # and the rebuilt state keeps chaining correctly
    res2 = db.apply(GraphDelta.of([], []))
    assert not res2.fallback
    assert_delta_matches_rebuild(db)


def test_rebuild_delta_escape_hatch():
    g = random_labeled_graph(num_vertices=12, num_edges=40, num_labels=2,
                             seed=15)
    db = DeltaBuilder(g, 2)
    db.full()
    rng = np.random.default_rng(16)
    delta = random_delta(db.graph, 2, 2, rng)
    res = db.rebuild_delta(delta)
    assert res.fallback
    assert_delta_matches_rebuild(db)


def test_delta_builder_rejects_bad_config():
    g = erdos_renyi(10, 2.0, 2, seed=0)
    with pytest.raises(ValueError):
        DeltaBuilder(g, 2, backend="python")      # not a batched backend
    with pytest.raises(ValueError):
        DeltaBuilder(g, 2, fallback_frac=0.0)
    with pytest.raises(RuntimeError):
        DeltaBuilder(g, 2).apply(GraphDelta.of([], []))   # before full()


# ------------------------------------------------------------------ #
# Dirty-row accounting + the partial re-freeze
# ------------------------------------------------------------------ #
def _check_patch(db: DeltaBuilder, old_frozen, res):
    mr_ids = mr_id_space(db.graph.num_labels, db.k)
    fresh = db.index.freeze(mr_ids)
    if res.fallback:
        return fresh
    patched = old_frozen.patch_rows(
        db.index, mr_ids,
        set(res.dirty_out.tolist()) | set(res.resort_out.tolist()),
        set(res.dirty_in.tolist()) | set(res.resort_in.tolist()))
    for fld in ("out_indptr", "out_hub", "out_mr",
                "in_indptr", "in_hub", "in_mr", "aid"):
        np.testing.assert_array_equal(
            getattr(patched, fld), getattr(fresh, fld), err_msg=fld)
    return fresh


@pytest.mark.parametrize("seed", range(4))
def test_dirty_rows_cover_changes_and_patch_refreeze(seed):
    """dirty_out/in must cover every changed row, and patch_rows over
    dirty+resort must reproduce a fresh freeze bit-for-bit."""
    g = random_labeled_graph(num_vertices=14, num_edges=50, num_labels=3,
                             seed=seed, self_loop_frac=0.15)
    db = DeltaBuilder(g, 2, fallback_frac=1.0)
    db.full()
    mr_ids = mr_id_space(g.num_labels, 2)
    frozen = db.index.freeze(mr_ids)
    rng = np.random.default_rng(seed + 30)
    for _ in range(3):
        old_rows = [dict((v, dict(d)) for v, d in enumerate(db.index.l_out)),
                    dict((v, dict(d)) for v, d in enumerate(db.index.l_in))]
        res = db.apply(random_delta(db.graph, 2, 2, rng))
        assert not res.fallback   # fallback_frac=1.0 disables the hatch
        dirty = (set(res.dirty_out.tolist()), set(res.dirty_in.tolist()))
        for side, (maps, old) in enumerate(
                ((db.index.l_out, old_rows[0]),
                 (db.index.l_in, old_rows[1]))):
            for v in range(db.graph.num_vertices):
                if {h: set(m) for h, m in maps[v].items()} != \
                        {h: set(m) for h, m in old[v].items()}:
                    assert v in dirty[side], (side, v)
        frozen = _check_patch(db, frozen, res)


def test_trace_chains_and_reports():
    g = erdos_renyi(80, 2.0, 3, seed=21)
    db = DeltaBuilder(g, 2, fallback_frac=1.0)
    db.full()
    assert isinstance(db.trace, BuildTrace)
    assert len(db.trace) == 2 * g.num_vertices
    assert db.trace.nbytes() > 0
    rng = np.random.default_rng(22)
    res = db.apply(random_delta(db.graph, 1, 1, rng))
    d = res.as_dict()
    assert d["phases_total"] == 2 * g.num_vertices
    assert d["build"]["backend"].startswith("delta[")
    assert db.deltas_applied == 1


def test_backend_registry_unchanged():
    # the engine rides on the registered batched backends
    assert get_backend("numpy").name == "numpy"


def test_delta_bench_artifact_holds_the_line():
    """The bench artifact (the one tracked file under
    benchmarks/artifacts/, so this runs on fresh CI checkouts too) must
    keep showing the acceptance headline: incremental >= 3x over the
    full numpy rebuild on a <=1%-edge delta workload (single-edge-pair
    stream on the sparse stand-in). Regenerate with
    `python benchmarks/run.py --only delta` on idle hardware if a
    legitimate change moves it."""
    import json
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "artifacts",
        "delta.json")
    if not os.path.exists(path):
        pytest.skip("delta artifact not generated")
    art = json.load(open(path))
    if art.get("smoke"):
        pytest.skip("smoke-mode artifact: numbers are not meaningful")
    assert art["best_single_speedup"] >= 3.0, art
    assert art["best_single_graph"] is not None
    rows = {r["graph"]: r for r in art["rows"]}
    assert rows[art["best_single_graph"]]["single_fallbacks"] == 0
