"""Deterministic stand-in for the tiny slice of the `hypothesis` API this
suite uses (``given``, ``settings``, ``strategies.integers/lists/.map``).

The container image does not ship hypothesis and nothing may be installed,
so ``conftest.py`` drops this module into ``sys.modules['hypothesis']``
when the real library is missing. Each property then runs against a fixed
number of samples from a per-test seeded RNG — weaker than real hypothesis
(no shrinking, no coverage-guided generation) but deterministic and enough
to keep the property tests meaningful.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)

    def map(self, fn) -> "_Strategy":
        return _Strategy(lambda rng: fn(self._sample(rng)))


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _lists(elements: _Strategy, min_size: int = 0,
           max_size: int = 10) -> _Strategy:
    def sample(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(sample)


class _StrategiesNamespace:
    integers = staticmethod(_integers)
    lists = staticmethod(_lists)


strategies = _StrategiesNamespace()

_DEFAULT_EXAMPLES = 25


def given(*strats: _Strategy):
    def deco(fn):
        # NB: no functools.wraps — copying fn's signature would make pytest
        # treat the strategy parameters as fixtures. The wrapper must look
        # zero-argument; all inputs come from the strategies.
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", _DEFAULT_EXAMPLES))
            # seeded per test name (crc32: stable across PYTHONHASHSEED)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                vals = [s.example(rng) for s in strats]
                fn(*vals)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.is_hypothesis_stub = True
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco
