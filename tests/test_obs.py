"""Telemetry contract tests (repro.obs): registry semantics, reservoir
percentile properties, the frozen ``repro.obs/1`` snapshot schema, span
tracing + Chrome export well-formedness, build/delta instrumentation,
and the end-to-end service integration (single-host and sharded,
including fallback attribution across replica hot-swaps)."""
import json
import os
import sys

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.build import (BuildStats, DeltaBuilder,
                         build_rlc_index_with_stats)
from repro.graphgen import erdos_renyi, random_delta
from repro.obs import (NULL_OBS, NULL_REGISTRY, SCHEMA, MetricsRegistry,
                       Observability, Reservoir, SpanEvent, Tracer,
                       snapshot, span_tree, to_prometheus,
                       validate_snapshot)
from repro.service import RLCService, ServiceConfig
from repro.service.metrics import LatencyRecorder
from repro.service.sharded import ShardedRLCService, ShardedServiceConfig


# ------------------------------------------------------------------ #
# Metrics registry
# ------------------------------------------------------------------ #
def test_registry_registration_idempotent():
    reg = MetricsRegistry()
    a = reg.counter("rlc_x", desc="first", labelnames=("backend",))
    b = reg.counter("rlc_x", desc="ignored", labelnames=("backend",))
    assert a is b
    assert reg.get("rlc_x") is a
    assert reg.get("nope") is None


def test_registry_conflicting_registration_raises():
    reg = MetricsRegistry()
    reg.counter("rlc_x", labelnames=("backend",))
    with pytest.raises(ValueError):
        reg.histogram("rlc_x", labelnames=("backend",))   # kind flip
    with pytest.raises(ValueError):
        reg.counter("rlc_x", labelnames=("shard",))       # label flip


def test_metric_labels_bind_cells():
    reg = MetricsRegistry()
    m = reg.counter("rlc_batches", labelnames=("backend",))
    cell = m.labels(backend="numpy")
    assert m.labels(backend="numpy") is cell        # get-or-create
    cell.inc()
    cell.inc(2.0)
    assert m.value(backend="numpy") == 3.0
    assert m.value(backend="pallas") == 0.0         # untouched series
    with pytest.raises(ValueError):
        m.labels(shard="0")                         # undeclared label
    with pytest.raises(ValueError):
        m.labels()                                  # missing label


def test_metric_conveniences_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("rlc_hits")
    c.inc()
    c.inc(4)
    assert c.value() == 5.0
    g = reg.gauge("rlc_size")
    g.set(7)
    assert g.value() == 7.0
    h = reg.histogram("rlc_lat", labelnames=("backend",))
    h.observe(0.5, backend="numpy")
    ((key, cell),) = h.series()
    assert key == ("numpy",)
    assert cell.reservoir.count == 1


def test_null_registry_records_nothing():
    m = NULL_REGISTRY.counter("rlc_x", labelnames=("a",))
    m.labels(a="1").inc()
    m.inc(5, a="2")
    assert NULL_REGISTRY.get("rlc_x") is None
    assert NULL_REGISTRY.as_dict() == {}
    assert list(m.series()) == []


# ------------------------------------------------------------------ #
# Reservoir
# ------------------------------------------------------------------ #
def test_reservoir_exact_below_cap():
    r = Reservoir(cap=256)
    rng = np.random.default_rng(3)
    xs = rng.normal(size=200)
    for x in xs:
        r.add(x)
    assert r.exact
    for p in (0, 25, 50, 90, 99, 100):
        assert r.percentile(p) == pytest.approx(
            float(np.percentile(xs, p)), abs=1e-12)


def test_reservoir_bounded_above_cap():
    r = Reservoir(cap=64)
    n = 64 * 20
    for i in range(n):
        r.add(float(i))
    assert len(r.samples) == 64                 # bounded memory
    assert not r.exact
    assert r.count == n                         # exact aggregates forever
    assert r.total == pytest.approx(sum(range(n)))
    assert r.vmin == 0.0 and r.vmax == float(n - 1)
    # the reservoir is a uniform subset, so the median estimate must land
    # well inside the value range (Algorithm R, deterministic seed)
    assert 0.2 * n < r.percentile(50) < 0.8 * n


def test_reservoir_summary_keys_frozen():
    r = Reservoir(cap=8)
    assert set(r.summary()) == {"count", "sum", "min", "max", "p50", "p90",
                                "p99", "stored", "exact"}
    assert r.summary()["count"] == 0
    assert r.summary()["min"] == 0.0            # empty-summary convention


@given(st.lists(st.integers(min_value=-1000, max_value=1000),
                min_size=1, max_size=100))
def test_reservoir_percentiles_match_numpy_below_cap(xs):
    r = Reservoir(cap=128)
    for x in xs:
        r.add(float(x))
    for p in (10, 50, 99):
        assert r.percentile(p) == pytest.approx(
            float(np.percentile(np.asarray(xs, float), p)), abs=1e-9)


def test_latency_recorder_bounded_with_stable_summary():
    rec = LatencyRecorder("numpy", sample_cap=32)
    for i in range(1000):
        rec.record(0.001 * (i % 10 + 1), n_queries=4)
    assert len(rec.samples_s) == 32             # the old list grew forever
    assert rec.batches == 1000 and rec.queries == 4000
    s = rec.summary()
    assert set(s) == {"batches", "queries", "total_s", "p50_ms", "p99_ms",
                      "qps"}
    assert s["qps"] == pytest.approx(4000 / rec.total_s)


# ------------------------------------------------------------------ #
# Tracing
# ------------------------------------------------------------------ #
def test_tracer_sampling_rates():
    assert Tracer(sample_rate=0.0).maybe_trace() is None
    t = Tracer(sample_rate=1.0)
    assert t.maybe_trace() is not None
    half = Tracer(sample_rate=0.5)
    got = sum(half.maybe_trace() is not None for _ in range(1000))
    assert half.traces_started + half.traces_skipped == 1000
    assert 350 < got < 650                      # seeded, loose band
    with pytest.raises(ValueError):
        Tracer(sample_rate=1.5)


def test_trace_span_records_and_propagates_errors():
    tracer = Tracer(sample_rate=1.0)
    tr = tracer.maybe_trace()
    with tr.span("outer", cat="service", n=3):
        with tr.span("inner"):
            pass
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    names = {e.name: e for e in tracer.events}
    assert set(names) == {"outer", "inner", "boom"}
    assert names["outer"].args == dict(n=3)
    assert names["boom"].args["error"] == "RuntimeError"
    # nesting by interval containment: inner sits inside outer
    roots = span_tree(tracer.events, tr.tid)
    by_name = {r.event.name: r for r in roots}
    assert by_name["outer"].children[0].event.name == "inner"
    assert not by_name["boom"].children


def test_trace_add_ending_now_backdates():
    tracer = Tracer(sample_rate=1.0)
    tr = tracer.maybe_trace()
    tr.add_ending_now("queue_wait", 0.25, cat="batcher")
    (ev,) = tracer.events
    assert ev.dur == pytest.approx(0.25)
    assert ev.ts + ev.dur == pytest.approx(tracer._now(), abs=0.05)


def test_tracer_event_buffer_bounded():
    tracer = Tracer(sample_rate=1.0, max_events=10)
    tr = tracer.maybe_trace()
    for i in range(25):
        tr.add(f"s{i}", 0.0, 0.001)
    assert len(tracer.events) == 10
    assert tracer.dropped == 15
    assert tracer.stats()["dropped"] == 15
    tracer.clear()
    assert not tracer.events and tracer.dropped == 0


def test_span_tree_partial_overlap_stays_top_level():
    a = SpanEvent("a", "", 1, ts=0.0, dur=1.0)
    b = SpanEvent("b", "", 1, ts=0.5, dur=1.0)     # overlaps, not nested
    roots = span_tree([a, b], tid=1)
    assert [r.event.name for r in roots] == ["a", "b"]


def test_chrome_trace_export_shape():
    tracer = Tracer(sample_rate=1.0)
    tr = tracer.maybe_trace()
    with tr.span("execute", cat="service"):
        pass
    doc = tracer.chrome_trace("unit-test")
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M"
    assert evs[0]["args"]["name"] == "unit-test"
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 1
    assert xs[0]["name"] == "execute"
    assert xs[0]["ts"] >= 0 and xs[0]["dur"] >= 0   # microseconds
    json.dumps(doc)                                 # serializable as-is


# ------------------------------------------------------------------ #
# Snapshot schema (frozen contract) + Prometheus export
# ------------------------------------------------------------------ #
def _populated_registry():
    reg = MetricsRegistry(reservoir_cap=16)
    reg.counter("rlc_cache_lookups", desc="lookups",
                labelnames=("outcome",)).inc(3, outcome="hit")
    reg.gauge("rlc_cache_size").set(2)
    h = reg.histogram("rlc_executor_batch_seconds", unit="s",
                      labelnames=("backend", "shard"))
    for v in (0.001, 0.002, 0.004):
        h.observe(v, backend="numpy", shard="-")
    return reg


def test_schema_version_is_frozen():
    # bump the version string when the shape changes — consumers (CI
    # smoke validation, bench artifacts) key on it
    assert SCHEMA == "repro.obs/1"


def test_snapshot_validates_and_serializes():
    reg = _populated_registry()
    tracer = Tracer(sample_rate=1.0)
    with tracer.maybe_trace().span("x"):
        pass
    doc = snapshot(reg, tracer=tracer, extra=dict(queries_served=3))
    assert validate_snapshot(doc) is doc
    doc2 = json.loads(json.dumps(doc))          # survives a JSON round-trip
    validate_snapshot(doc2)
    assert doc2["schema"] == SCHEMA
    assert doc2["extra"] == dict(queries_served=3)
    hist = doc2["metrics"]["rlc_executor_batch_seconds"]
    assert hist["series"][0]["labels"] == dict(backend="numpy", shard="-")
    assert hist["series"][0]["count"] == 3


@pytest.mark.parametrize("mutate, path_hint", [
    (lambda d: d.update(schema="repro.obs/0"), "schema"),
    (lambda d: d["metrics"]["rlc_cache_size"].update(type="blob"), "type"),
    (lambda d: d["metrics"]["rlc_executor_batch_seconds"]["series"][0]
        .pop("p99"), "missing"),
    (lambda d: d["metrics"]["rlc_executor_batch_seconds"]["series"][0]
        .update(stored=99), "stored"),
    (lambda d: d["metrics"]["rlc_cache_lookups"]["series"][0]
        .update(labels={}), "labels"),
    (lambda d: d["metrics"]["rlc_cache_size"]["series"][0]
        .update(value="two"), "value"),
    (lambda d: d.update(tracing=dict(sample_rate="high")), "tracing"),
    # a reservoir that observed anything keeps >= 1 sample: count>0 with
    # stored==0 means the series was assembled by hand or clobbered
    (lambda d: d["metrics"]["rlc_executor_batch_seconds"]["series"][0]
        .update(stored=0), "stored"),
    # NaN/inf percentiles serialize to invalid JSON and poison
    # aggregation downstream — the validator must reject, not pass,
    # non-finite floats
    (lambda d: d["metrics"]["rlc_executor_batch_seconds"]["series"][0]
        .update(p50=float("nan")), "p50"),
    (lambda d: d["metrics"]["rlc_executor_batch_seconds"]["series"][0]
        .update(sum=float("inf")), "sum"),
    (lambda d: d["metrics"]["rlc_cache_size"]["series"][0]
        .update(value=float("nan")), "value"),
    (lambda d: d["metrics"]["rlc_executor_batch_seconds"]["series"][0]
        .update(count=True), "count"),
])
def test_snapshot_rejects_malformed(mutate, path_hint):
    doc = snapshot(_populated_registry())
    mutate(doc)
    with pytest.raises(ValueError, match=path_hint):
        validate_snapshot(doc)


def test_prometheus_text_format():
    text = to_prometheus(_populated_registry())
    lines = text.splitlines()
    # counters get _total; histograms export as summaries
    assert 'rlc_cache_lookups_total{outcome="hit"} 3' in lines
    assert "# TYPE rlc_cache_lookups_total counter" in lines
    assert "# TYPE rlc_executor_batch_seconds summary" in lines
    assert ('rlc_executor_batch_seconds{backend="numpy",quantile="0.5",'
            'shard="-"} 0.002') in lines
    assert 'rlc_executor_batch_seconds_count{backend="numpy",shard="-"} 3' \
        in lines
    assert "rlc_cache_size 2" in lines


def test_prometheus_escapes_label_values():
    reg = MetricsRegistry()
    reg.counter("rlc_x", labelnames=("q",)).inc(1, q='say "hi" \\ there')
    text = to_prometheus(reg)
    assert r'{q="say \"hi\" \\ there"}' in text


# ------------------------------------------------------------------ #
# Observability facade
# ------------------------------------------------------------------ #
def test_observability_disabled_is_inert():
    obs = Observability(enabled=False)
    assert obs.registry is NULL_REGISTRY
    assert obs.tracer.maybe_trace() is None
    assert obs.build_observer() is None
    doc = validate_snapshot(obs.snapshot())
    assert doc["metrics"] == {}
    assert NULL_OBS.registry is NULL_REGISTRY


def test_observability_build_observer_contexts():
    obs = Observability()
    assert obs.build_observer() is obs.build_observer()     # "full" cached
    assert obs.build_observer("delta") is not obs.build_observer("delta")


# ------------------------------------------------------------------ #
# Build / delta instrumentation
# ------------------------------------------------------------------ #
def test_build_phase_observer_accounts_every_phase():
    g = erdos_renyi(80, 2.5, 3, seed=7)
    obs = Observability()
    index, stats = build_rlc_index_with_stats(
        g, 2, backend="numpy", observer=obs.build_observer())
    reg = obs.registry
    phases = reg.get("rlc_build_phases")
    n_phases = sum(c.value for _k, c in phases.series())
    assert 0 < n_phases <= 2 * g.num_vertices    # one per (hub, direction)
    # the per-phase counter deltas must sum back to the build totals
    deltas = reg.get("rlc_build_counter_deltas")
    for name, total in zip(BuildStats._COUNTERS, stats.counters()):
        assert deltas.value(context="full", counter=name) == total
    assert reg.get("rlc_build_runs").value(
        context="full", backend="numpy") == 1
    slowest = obs.build_observer().slowest_phases()
    assert slowest and slowest == sorted(
        slowest, key=lambda p: -p["seconds"])
    assert {"hub", "direction", "seconds"} <= set(slowest[0])
    # and the facade snapshot carries them in extra
    doc = validate_snapshot(obs.snapshot())
    assert doc["extra"]["slowest_build_phases"] == slowest


def test_delta_builder_reports_outcomes_and_fallback_reason():
    g = erdos_renyi(100, 2.2, 3, seed=11)
    obs = Observability()
    db = DeltaBuilder(g, 2, backend="numpy", fallback_frac=1.0, obs=obs)
    db.full()
    rng = np.random.default_rng(2)
    res = db.apply(random_delta(db.graph, 2, 2, rng))
    assert res.fallback_reason is None
    reg = obs.registry
    assert reg.get("rlc_delta_applies").value(outcome="incremental") == 1
    assert reg.get("rlc_delta_apply_seconds").labels().reservoir.count == 1
    # delta-context phases land labeled apart from full-build phases
    phase_ctx = {k[0] for k, _c in
                 reg.get("rlc_build_phases").series()}
    assert "delta_full" in phase_ctx            # the traced bootstrap
    # a second builder with a zero-work budget must bail to the rebuild
    # path and attribute why
    db2 = DeltaBuilder(g, 2, backend="numpy", fallback_frac=1e-9, obs=obs)
    db2.full()
    res2 = db2.apply(random_delta(db2.graph, 2, 2, rng))
    assert res2.fallback
    assert res2.fallback_reason in ("static_budget", "budget")
    assert reg.get("rlc_delta_fallbacks").value(
        reason=res2.fallback_reason) == 1
    assert reg.get("rlc_delta_applies").value(outcome="fallback") == 1


# ------------------------------------------------------------------ #
# Service integration (single-host)
# ------------------------------------------------------------------ #
def _service_queries(svc, n=40, seed=0):
    rng = np.random.default_rng(seed)
    V = svc.graph.num_vertices
    mrs = list(svc._id_to_mr)
    return [(int(rng.integers(V)), int(rng.integers(V)),
             mrs[int(rng.integers(len(mrs)))]) for _ in range(n)]


def test_service_telemetry_end_to_end():
    g = erdos_renyi(90, 2.5, 3, seed=13)
    svc = RLCService.build(g, ServiceConfig(
        k=2, batch_size=8, use_device=False, backend="numpy",
        build_backend="numpy", trace_sample_rate=1.0))
    queries = _service_queries(svc, n=40)
    svc.query_batch(queries)
    svc.query_batch(queries)        # second pass hits the result cache
    reg = svc.obs.registry
    # every admitted (non-cached) request got a queue-wait observation
    wait = sum(c.reservoir.count for _k, c in
               reg.get("rlc_batcher_queue_wait_seconds").series())
    st = svc.stats()
    assert wait == st["cache"]["misses"]
    assert reg.get("rlc_cache_lookups").value(outcome="hit") == \
        st["cache"]["hits"] > 0
    assert reg.get("rlc_executor_queries").value(
        backend="numpy", shard="-") == wait
    # sampled traces: every query_batch call traced at rate 1.0
    ts = svc.obs.tracer.stats()
    assert ts["traces"] == 2 and ts["events"] > 0
    # span tree: the execute span nests its executor attempt
    tids = {e.tid for e in svc.obs.tracer.events}
    execs = 0
    for tid in tids:
        for root in span_tree(svc.obs.tracer.events, tid):
            if root.event.name == "execute":
                assert any(c.event.name.startswith("exec:")
                           for c in root.children)
                execs += 1
    assert execs > 0
    # exporters: snapshot validates + prom text + chrome trace
    doc = validate_snapshot(svc.telemetry_snapshot())
    assert doc["extra"]["queries_served"] == 80
    assert "rlc_batcher_queue_wait_seconds" in svc.prometheus()
    trace = svc.chrome_trace()
    assert any(e["ph"] == "X" and e["name"] == "queue_wait"
               for e in trace["traceEvents"])
    assert st["telemetry"]["enabled"]
    assert st["telemetry"]["tracing"]["traces"] == 2


def test_service_telemetry_disabled_still_serves():
    g = erdos_renyi(60, 2.0, 3, seed=13)
    cfg_on = ServiceConfig(k=2, batch_size=8, use_device=False,
                           backend="numpy", build_backend="numpy")
    svc_on = RLCService.build(g, cfg_on)
    svc_off = RLCService.build(
        g, ServiceConfig(k=2, batch_size=8, use_device=False,
                         backend="numpy", build_backend="numpy",
                         telemetry=False), index=svc_on.index)
    queries = _service_queries(svc_on, n=30, seed=4)
    assert svc_off.query_batch(queries) == svc_on.query_batch(queries)
    assert not svc_off.stats()["telemetry"]["enabled"]
    doc = validate_snapshot(svc_off.telemetry_snapshot())
    assert doc["metrics"] == {}


# ------------------------------------------------------------------ #
# Sharded integration: fallback attribution across hot-swaps
# ------------------------------------------------------------------ #
def test_sharded_fallbacks_survive_hot_swap():
    g = erdos_renyi(90, 2.5, 3, seed=17)
    # pallas without a device layout can never serve: every batch falls
    # back pallas -> numpy, making fallback attribution deterministic
    svc = ShardedRLCService.build(g, ShardedServiceConfig(
        k=2, batch_size=8, num_shards=2, use_device=False,
        backend="pallas", build_backend="numpy"))
    queries = _service_queries(svc, n=40, seed=5)
    svc.query_batch(queries)
    before = [sh.fallbacks for sh in svc.shards]
    assert sum(before) > 0
    reg = svc.obs.registry
    fb = reg.get("rlc_executor_fallbacks")
    assert sum(c.value for _k, c in fb.series()) == sum(before)
    svc.hot_swap()                  # rebuild + atomic republish per shard
    svc.query_batch(_service_queries(svc, n=40, seed=6))
    after = [sh.fallbacks for sh in svc.shards]
    # new executors start at zero — the banked counts keep attribution
    # monotone across the generation, per shard
    assert all(a >= b for a, b in zip(after, before))
    assert sum(after) > sum(before)
    for sh, a in zip(svc.shards, after):
        assert sh.stats()["fallbacks"] == a
        assert fb.value(**{"from": "pallas", "to": "numpy",
                           "shard": str(sh.shard_id)}) == a
    # and the shard-labeled registry series agree with the banked totals
    totals = svc.shards[0].backend_totals()
    assert totals["numpy"]["batches"] > 0
    doc = validate_snapshot(svc.telemetry_snapshot())
    assert "rlc_router_routes" in doc["metrics"]


# ------------------------------------------------------------------ #
# Benchmark-side validation (the CI smoke gate)
# ------------------------------------------------------------------ #
def test_run_py_validates_telemetry_artifacts(tmp_path, monkeypatch):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import run as bench_run
    monkeypatch.setattr(bench_run, "ART", str(tmp_path))
    good = snapshot(_populated_registry())
    trace = dict(traceEvents=[
        dict(name="process_name", ph="M", pid=0, tid=0, args={}),
        dict(name="execute", ph="X", pid=0, tid=1, ts=1.0, dur=2.0)])

    def write(name, doc):
        with open(tmp_path / name, "w") as f:
            json.dump(doc, f)

    # a real audit report + clean shadow stats, as the serving suites
    # embed them via telemetry_snapshot's extra section
    svc = RLCService.build(erdos_renyi(40, 2.5, 3, seed=3),
                           ServiceConfig(k=2, use_device=False))
    audit = svc.audit_report(sample=16)
    good["extra"] = dict(audit=audit, shadow=dict(divergent=0, checked=4))

    # minimal rpc-transport stats doc satisfying run.py's rpc_stage_ok:
    # a real in-proc sharded stats document with the rpc section the
    # wire transport would add
    sh = ShardedRLCService.build(
        erdos_renyi(40, 2.5, 3, seed=3),
        ShardedServiceConfig(k=2, num_shards=2, use_device=False))
    rpc_stats = sh.stats()
    rpc_stats["transport"] = "rpc"
    rpc_stats["rpc"] = dict(
        live_workers=2, membership_epoch=1, joins=2, leaves=0,
        rejoins=0, retries=0, generation=0,
        wire_bytes=dict(sent=1000, received=500))
    # minimal control-plane + rpc stages satisfying run.py's
    # control_stages_ok / rpc_stage_ok / stats_schema_ok
    control = dict(
        slo=dict(shed=0, p99_over_p50=1.5),
        overload=dict(shed_ratio=0.1, underload_shed=0,
                      answers_match_oracle=True,
                      underload=dict(answers_match_oracle=True)),
        warming=dict(cold_hit_rate=0.3, warm_hit_rate=0.6),
        rpc=dict(shards=2, answers_match=True, digest_wire_kb=0.5,
                 roundtrips=7, stats=rpc_stats),
        rpc_async=dict(answers_match=True, overlap_s=0.01))
    write("service.json", dict(results=dict(numpy=dict(telemetry=good))))
    write("sharded.json", dict(results=dict(
        shards_2=dict(telemetry=good), **control)))
    write("sharded_trace.json", trace)
    assert bench_run.validate_telemetry_artifacts(["service",
                                                   "sharded"]) == []
    # a control-plane invariant violation must fail the smoke run
    broken = dict(control, slo=dict(shed=3, p99_over_p50=1.5))
    write("sharded.json", dict(results=dict(
        shards_2=dict(telemetry=good), **broken)))
    fails = bench_run.validate_telemetry_artifacts(["sharded"])
    assert any(name == "sharded:control" for name, _err in fails)
    write("sharded.json", dict(results=dict(
        shards_2=dict(telemetry=good), **control)))
    # a snapshot that stops validating must fail the smoke run
    bad = json.loads(json.dumps(good))
    bad["schema"] = "repro.obs/999"
    write("service.json", dict(results=dict(numpy=dict(telemetry=bad))))
    fails = bench_run.validate_telemetry_artifacts(["service"])
    # the audit walker skips unrecognized schemas, so both checks trip
    assert [name for name, _err in fails] == ["service:telemetry",
                                              "service:audit"]
    # a shadow divergence recorded in any embedded snapshot fails the run
    diverged = json.loads(json.dumps(good))
    diverged["extra"]["shadow"]["divergent"] = 1
    write("service.json",
          dict(results=dict(numpy=dict(telemetry=diverged))))
    fails = bench_run.validate_telemetry_artifacts(["service"])
    assert any(name == "service:audit" for name, _err in fails)
    # a corrupted audit report fails the run too
    bad_audit = json.loads(json.dumps(good))
    bad_audit["extra"]["audit"]["identity"]["entries"] += 1
    write("service.json",
          dict(results=dict(numpy=dict(telemetry=bad_audit))))
    fails = bench_run.validate_telemetry_artifacts(["service"])
    assert any(name == "service:audit" for name, _err in fails)
    # suites with no embedded telemetry at all must also fail
    write("sharded.json", dict(results=dict(shards_2=dict(qps=1.0))))
    fails = bench_run.validate_telemetry_artifacts(["sharded"])
    assert any(name == "sharded:telemetry" for name, _err in fails)
