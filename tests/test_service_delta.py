"""Serving-layer delta integration: ``apply_delta`` end-to-end, targeted
cache invalidation (delta + hot-swap), cache TTL, and the idempotent
``close()`` / context-manager shutdown that stops the deadline ticker."""
import threading
import time

import numpy as np
import pytest

from repro.build import GraphDelta, build_rlc_index
from repro.core.minimum_repeat import enumerate_mrs
from repro.graphgen import erdos_renyi, random_delta
from repro.service import RLCService, ServiceConfig
from repro.service.cache import ResultCache
from repro.service.sharded import ShardedRLCService, ShardedServiceConfig


def reference_answers(g, k, queries):
    ref = build_rlc_index(g, k, backend="python")
    return [ref.query(s, t, mr) for s, t, mr in queries]


def sample_queries(g, k, rng, n_per_mr=4):
    return [(int(rng.integers(g.num_vertices)),
             int(rng.integers(g.num_vertices)), mr)
            for mr in enumerate_mrs(g.num_labels, k)
            for _ in range(n_per_mr)]


# ------------------------------------------------------------------ #
# RLCService.apply_delta
# ------------------------------------------------------------------ #
def test_service_apply_delta_answers():
    g = erdos_renyi(120, 2.2, 3, seed=41)
    svc = RLCService.build(g, ServiceConfig(
        k=2, use_device=False, build_backend="numpy",
        delta_fallback_frac=1.0))
    rng = np.random.default_rng(42)
    for step in range(3):
        delta = random_delta(svc.graph, 2, 2, rng)
        summary = svc.apply_delta(delta)
        assert summary["deltas_applied"] == step + 1
        queries = sample_queries(svc.graph, 2, rng)
        got = svc.query_batch(queries)
        want = reference_answers(svc.graph, 2, queries)
        assert got == want
    assert svc.stats()["deltas_applied"] == 3
    assert svc.stats()["build"]["backend"].startswith("delta[")


def test_service_apply_delta_invalid_delta_raises():
    g = erdos_renyi(50, 2.0, 3, seed=43)
    svc = RLCService.build(g, ServiceConfig(k=2, use_device=False))
    e0 = g.edges[0].tolist()
    with pytest.raises(ValueError):
        svc.apply_delta(GraphDelta.of([e0], []))   # edge already present
    assert svc.deltas_applied == 0


def test_service_delta_targeted_cache_invalidation():
    """Stale keys are evicted; keys whose (s, t) rows stayed clean keep
    serving from cache."""
    g = erdos_renyi(150, 2.0, 3, seed=44)
    svc = RLCService.build(g, ServiceConfig(
        k=2, use_device=False, build_backend="numpy",
        delta_fallback_frac=1.0, cache_capacity=4096))
    rng = np.random.default_rng(45)
    queries = sample_queries(svc.graph, 2, rng, n_per_mr=8)
    svc.query_batch(queries)                    # prime the cache
    primed = set(svc.cache._d)
    assert primed
    delta = random_delta(svc.graph, 1, 1, rng)
    summary = svc.apply_delta(delta)
    dirty_s = set(summary["dirty_out"])
    dirty_t = set(summary["dirty_in"])
    survivors = set(svc.cache._d)
    # every evicted key was dirty; every surviving key was not
    for (s, t, mr) in primed - survivors:
        assert s in dirty_s or t in dirty_t
    for (s, t, mr) in survivors:
        assert s not in dirty_s and t not in dirty_t
    assert summary["cache_evicted"] == len(primed - survivors)
    assert svc.cache.stats.invalidations == summary["cache_evicted"]
    # survivors still serve (and answers post-delta are correct)
    got = svc.query_batch(queries)
    assert got == reference_answers(svc.graph, 2, queries)


# ------------------------------------------------------------------ #
# Cache TTL
# ------------------------------------------------------------------ #
def test_cache_ttl_expiry_with_fake_clock():
    now = [0.0]
    c = ResultCache(16, ttl_s=5.0, clock=lambda: now[0])
    c.put((1, 2, 0), True)
    assert c.get((1, 2, 0)) is True
    now[0] = 4.9
    assert c.get((1, 2, 0)) is True             # still fresh
    now[0] = 10.0
    assert c.get((1, 2, 0)) is None             # expired -> miss + evict
    assert c.stats.expirations == 1
    assert len(c) == 0
    with pytest.raises(ValueError):
        ResultCache(16, ttl_s=0.0)


def test_cache_invalidate_rows_unit():
    c = ResultCache(16)
    c.put((1, 2, 0), True)
    c.put((3, 4, 0), False)
    c.put((5, 2, 1), True)
    n = c.invalidate_rows(dirty_s={1}, dirty_t={4})
    assert n == 2
    assert c.get((5, 2, 1)) is True
    assert c.stats.invalidations == 2


def test_service_config_ttl_plumbed():
    g = erdos_renyi(40, 2.0, 2, seed=46)
    svc = RLCService.build(g, ServiceConfig(k=2, use_device=False,
                                            cache_ttl_s=123.0))
    assert svc.cache.ttl_s == 123.0
    sh = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, use_device=False, num_shards=2,
                                cache_ttl_s=45.0))
    assert sh.cache.ttl_s == 45.0


# ------------------------------------------------------------------ #
# ShardedRLCService.apply_delta + hot_swap invalidation
# ------------------------------------------------------------------ #
def test_sharded_apply_delta_answers_and_shard_routing():
    g = erdos_renyi(300, 1.8, 4, seed=47)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=4, num_replicas=2,
                                use_device=False, build_backend="numpy",
                                delta_fallback_frac=1.0))
    rng = np.random.default_rng(48)
    for step in range(2):
        delta = random_delta(svc.graph, 1, 1, rng)
        summary = svc.apply_delta(delta)
        assert summary["generation"] == step + 1
        touched = set(summary["shards_touched"])
        assert touched <= {0, 1, 2, 3}
        if not summary["delta"]["fallback"]:
            # untouched shards kept their replicas (old generation)
            for rs in svc.shards:
                if rs.shard_id in touched:
                    assert rs.generation == summary["generation"]
                else:
                    assert rs.generation < summary["generation"]
        queries = sample_queries(svc.graph, 2, rng)
        got = svc.query_batch(queries)
        want = reference_answers(svc.graph, 2, queries)
        assert got == want
    assert svc.stats()["deltas_applied"] == 2


def test_sharded_delta_cache_invalidation_and_hot_swap_clear():
    g = erdos_renyi(200, 2.0, 3, seed=49)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=2, use_device=False,
                                build_backend="numpy",
                                delta_fallback_frac=1.0))
    rng = np.random.default_rng(50)
    queries = sample_queries(svc.graph, 2, rng, n_per_mr=8)
    svc.query_batch(queries)
    primed = set(svc.cache._d)
    assert primed
    summary = svc.apply_delta(random_delta(svc.graph, 1, 1, rng))
    dirty_s = set(summary["dirty_out"])
    dirty_t = set(summary["dirty_in"])
    survivors = set(svc.cache._d)
    for (s, t, mr) in primed - survivors:
        assert s in dirty_s or t in dirty_t
    for (s, t, mr) in survivors:
        assert s not in dirty_s and t not in dirty_t
    got = svc.query_batch(queries)
    assert got == reference_answers(svc.graph, 2, queries)
    # hot_swap wipes the whole cache (coarse invalidation)
    svc.query_batch(queries)
    assert len(svc.cache) > 0
    svc.hot_swap()
    assert len(svc.cache) == 0
    got = svc.query_batch(queries)
    assert got == reference_answers(svc.graph, 2, queries)


def test_apply_delta_on_adopted_index_with_nondefault_flags():
    """An index adopted pre-built with non-default pruning flags has a
    different entry-set vintage than the delta builder's rebuild; the
    bootstrap must resync the whole serving state so later row patches
    never mix vintages (stale unpruned entries in clean rows)."""
    from repro.build import get_backend
    g = erdos_renyi(100, 2.2, 3, seed=57)
    idx = get_backend("numpy", use_pr1=False).build(g, 2)[0]
    svc = RLCService.build(
        g, ServiceConfig(k=2, use_device=False, build_backend="numpy",
                         delta_fallback_frac=1.0), index=idx)
    rng = np.random.default_rng(58)
    for _ in range(2):
        # deletion-heavy deltas: exactly the shape that leaves stale
        # reachability entries behind if vintages mix
        svc.apply_delta(random_delta(svc.graph, 1, 2, rng))
        queries = sample_queries(svc.graph, 2, rng)
        assert svc.query_batch(queries) == \
            reference_answers(svc.graph, 2, queries)


def test_sharded_hot_swap_resets_delta_builder():
    """A hot_swap replaces the serving graph; a later apply_delta must
    re-bootstrap from the swapped state, not silently revert to the
    delta builder's cached pre-swap graph."""
    g = erdos_renyi(80, 2.0, 3, seed=54)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=2, use_device=False,
                                build_backend="numpy",
                                delta_fallback_frac=1.0))
    rng = np.random.default_rng(55)
    svc.apply_delta(random_delta(svc.graph, 1, 1, rng))   # caches builder
    g2 = erdos_renyi(80, 2.4, 3, seed=56)
    svc.hot_swap(graph=g2)
    assert svc.graph is g2
    delta = random_delta(g2, 1, 1, rng)
    svc.apply_delta(delta)
    want_graph = g2.apply_delta(delta)
    assert set(map(tuple, svc.graph.edges.tolist())) == \
        set(map(tuple, want_graph.edges.tolist()))
    queries = sample_queries(svc.graph, 2, rng)
    assert svc.query_batch(queries) == \
        reference_answers(svc.graph, 2, queries)


# ------------------------------------------------------------------ #
# close() / context manager stops the deadline ticker
# ------------------------------------------------------------------ #
def _assert_close_stops_ticker(svc):
    fired = threading.Event()
    svc.batcher.start_ticker(lambda batch: fired.set())
    assert svc.batcher.ticker_running
    svc.close()
    assert not svc.batcher.ticker_running
    svc.close()                                  # idempotent
    assert not svc.batcher.ticker_running
    # a stopped ticker's thread is joined: no new flushes fire
    fired.clear()
    svc.query(0, 1, (0,))
    time.sleep(0.02)
    assert not svc.batcher.ticker_running


def test_service_close_stops_ticker():
    g = erdos_renyi(30, 2.0, 2, seed=51)
    svc = RLCService.build(g, ServiceConfig(k=2, use_device=False,
                                            max_wait_ms=1.0))
    _assert_close_stops_ticker(svc)


def test_sharded_service_close_stops_ticker():
    g = erdos_renyi(60, 2.0, 2, seed=52)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=2, use_device=False,
                                max_wait_ms=1.0))
    _assert_close_stops_ticker(svc)


def test_service_context_manager():
    g = erdos_renyi(30, 2.0, 2, seed=53)
    with RLCService.build(g, ServiceConfig(k=2, use_device=False)) as svc:
        svc.batcher.start_ticker(lambda batch: None)
        assert svc.query(0, 1, (0,)) in (True, False)
    assert not svc.batcher.ticker_running
    # closed services still answer synchronous queries
    assert svc.query(0, 1, (0,)) in (True, False)
