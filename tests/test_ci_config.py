"""CI configuration validity: the workflow must parse as YAML and keep
the job contract the repo relies on (tier-1 gate on push/PR, nightly
slow suite, benchmark smoke with artifact upload, ruff lint), and the
benchmark orchestrator must actually expose the --smoke flag the smoke
job invokes."""
import os

import pytest

yaml = pytest.importorskip("yaml")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKFLOW = os.path.join(ROOT, ".github", "workflows", "ci.yml")


def load_workflow():
    with open(WORKFLOW) as f:
        return yaml.safe_load(f)


def test_workflow_parses_and_has_jobs():
    wf = load_workflow()
    assert wf["name"] == "ci"
    # pyyaml parses the `on:` key as boolean True (YAML 1.1)
    triggers = wf.get("on", wf.get(True))
    assert "push" in triggers
    assert "pull_request" in triggers
    assert "schedule" in triggers
    assert "workflow_dispatch" in triggers
    assert set(wf["jobs"]) == {"tier1", "slow", "smoke", "lint"}


def test_tier1_job_runs_the_roadmap_command():
    wf = load_workflow()
    steps = wf["jobs"]["tier1"]["steps"]
    run_cmds = [s.get("run", "") for s in steps]
    assert any("PYTHONPATH=src python -m pytest -x -q" in c
               for c in run_cmds), "tier-1 gate must match ROADMAP.md"
    # pip caching keyed on the checked-in requirements file
    setup = [s for s in steps if "setup-python" in str(s.get("uses", ""))]
    assert setup and setup[0]["with"]["cache"] == "pip"
    assert os.path.exists(os.path.join(
        ROOT, setup[0]["with"]["cache-dependency-path"]))


def test_slow_job_gated_to_schedule_or_dispatch():
    wf = load_workflow()
    slow = wf["jobs"]["slow"]
    assert "schedule" in slow["if"] and "workflow_dispatch" in slow["if"]
    assert any("pytest -q -m slow" in s.get("run", "")
               for s in slow["steps"])
    # and tier1/smoke must NOT run on the nightly schedule
    for job in ("tier1", "smoke"):
        assert "!= 'schedule'" in wf["jobs"][job]["if"]


def test_smoke_job_runs_and_uploads_artifacts():
    wf = load_workflow()
    smoke = wf["jobs"]["smoke"]
    assert any("benchmarks/run.py --smoke" in s.get("run", "")
               for s in smoke["steps"])
    uploads = [s for s in smoke["steps"]
               if "upload-artifact" in str(s.get("uses", ""))]
    assert uploads, "smoke must upload benchmarks/artifacts"
    path = uploads[0]["with"]["path"]
    assert "benchmarks/artifacts" in path
    # the telemetry exports must ride along: JSON snapshots (inside the
    # suite JSONs + the Chrome trace) and the Prometheus text dump
    assert "*.json" in path and "*.prom" in path


def test_lint_job_uses_checked_in_ruff_config():
    wf = load_workflow()
    lint = wf["jobs"]["lint"]
    assert any("ruff check" in s.get("run", "") for s in lint["steps"])
    assert os.path.exists(os.path.join(ROOT, "ruff.toml"))
    cfg = open(os.path.join(ROOT, "ruff.toml")).read()
    assert "line-length" in cfg and "[lint]" in cfg


def test_run_py_exposes_smoke_flag():
    import sys
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    from benchmarks import run as bench_run
    # --smoke and --full are registered and mutually exclusive
    with pytest.raises(SystemExit):
        bench_run.main(["--smoke", "--full"])
