"""Distributed build/query: 1-device in-process + 8-device subprocess."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.baselines import bfs_rlc
from repro.core.distributed import (distributed_all_mr_reach,
                                    distributed_build,
                                    distributed_query_batch, make_rlc_mesh)
from repro.core.dense import DenseEngine
from repro.core.device_index import DeviceIndex
from repro.core.minimum_repeat import mr_id_space
from repro.graphgen import random_labeled_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_distributed_reach_single_device():
    g = random_labeled_graph(num_vertices=11, num_edges=30, num_labels=2,
                             seed=2, self_loop_frac=0.1)
    mesh = make_rlc_mesh()
    R = distributed_all_mr_reach(g, 2, mesh)
    eng = DenseEngine.build(g, 2)
    assert np.array_equal(R, eng.reach)


def test_distributed_build_and_query_single_device():
    g = random_labeled_graph(num_vertices=10, num_edges=28, num_labels=2,
                             seed=4)
    k = 2
    mesh = make_rlc_mesh()
    idx, _ = distributed_build(g, k, mesh, hub_batch=4)
    dev = DeviceIndex.from_index(idx, g.num_labels)
    ids = mr_id_space(g.num_labels, k)
    qs, qt, qm, want = [], [], [], []
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L, c in ids.items():
                qs.append(s)
                qt.append(t)
                qm.append(c)
                want.append(bfs_rlc(g, s, t, L))
    got = distributed_query_batch(dev, np.array(qs), np.array(qt),
                                  np.array(qm), mesh)
    assert got.tolist() == want


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import jax
    assert len(jax.devices()) == 8
    from repro.core.distributed import (distributed_all_mr_reach,
                                        distributed_build,
                                        distributed_query_batch,
                                        make_rlc_mesh)
    from repro.core.dense import DenseEngine
    from repro.core.device_index import DeviceIndex
    from repro.core.baselines import bfs_rlc
    from repro.core.minimum_repeat import mr_id_space
    from repro.graphgen import random_labeled_graph

    g = random_labeled_graph(num_vertices=13, num_edges=40, num_labels=2,
                             seed=9, self_loop_frac=0.1)
    k = 2
    mesh = make_rlc_mesh(data=4, pod=2)
    R = distributed_all_mr_reach(g, k, mesh)
    eng = DenseEngine.build(g, k)
    assert np.array_equal(R, eng.reach), "sharded reach != single-device"

    idx, _ = distributed_build(g, k, mesh, hub_batch=4)
    dev = DeviceIndex.from_index(idx, g.num_labels)
    ids = mr_id_space(g.num_labels, k)
    qs, qt, qm, want = [], [], [], []
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L, c in ids.items():
                qs.append(s); qt.append(t); qm.append(c)
                want.append(bfs_rlc(g, s, t, L))
    got = distributed_query_batch(dev, np.array(qs), np.array(qt),
                                  np.array(qm), mesh)
    assert got.tolist() == want, "distributed query mismatch"
    print("OK-8DEV")
""")


@pytest.mark.slow
def test_distributed_8_devices_subprocess():
    src = os.path.join(ROOT, "src")
    code = SUBPROC.format(src=src)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "OK-8DEV" in r.stdout
