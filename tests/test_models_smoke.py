"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config, runs one forward + one train step on
CPU, asserts output shapes + finiteness; serve path: prefill + decode
agree with the full forward."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.models import (count_params, decode_step, forward, init_cache,
                          init_model, loss_fn, prefill)

SMOKES = [a + "-smoke" for a in ASSIGNED]


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.frontend != "none":
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", SMOKES)
def test_forward_shapes_and_finite(name):
    cfg = get_config(name)
    params, axes = init_model(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda l: isinstance(l, tuple))
    batch = make_batch(cfg)
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("frontend"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # padded vocab tail masked
    if cfg.padded_vocab != cfg.vocab_size:
        tail = np.asarray(logits[..., cfg.vocab_size:], np.float32)
        assert (tail < -1e29).all()


@pytest.mark.parametrize("name", SMOKES)
def test_train_step_decreases_loss(name):
    cfg = get_config(name)
    params, _ = init_model(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, B=2, S=16, seed=1)

    def lf(p):
        loss, m = loss_fn(p, cfg, batch)
        return loss

    loss0, grads = jax.value_and_grad(lf)(params)
    assert np.isfinite(float(loss0))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # one SGD step reduces loss on the same batch
    lr = 2e-2 / max(float(gnorm), 1.0)
    p2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params,
                      grads)
    loss1 = lf(p2)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("name", SMOKES)
def test_prefill_decode_matches_forward(name):
    cfg = get_config(name)
    params, _ = init_model(cfg, jax.random.PRNGKey(2))
    B, S = 2, 12
    batch = make_batch(cfg, B=B, S=S, seed=2)
    tokens = batch["tokens"]
    fe = batch.get("frontend")
    # full forward logits
    full_logits, _ = forward(params, cfg, tokens, fe)
    # prefill on S-1 tokens, decode the last one
    n_prefix = (cfg.frontend_len
                if (cfg.frontend != "none" and not cfg.encoder_layers)
                else 0)
    max_len = S + n_prefix + 4
    cache, _ = init_cache(cfg, B, max_len)
    logits_p, cache = prefill(params, cfg, tokens[:, :S - 1], cache, fe)
    # prefill last-token logits == forward at position S-2
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full_logits[:, S - 2], np.float32),
        rtol=2e-2, atol=2e-2)
    pos = jnp.int32(S - 1 + n_prefix)
    logits_d, _ = decode_step(params, cfg, cache, tokens[:, S - 1:], pos)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full_logits[:, S - 1], np.float32),
        rtol=2e-2, atol=2e-2)


def test_param_counts_full_configs():
    """Full configs hit the advertised parameter scale (abstract init —
    no allocation)."""
    expected = {
        "qwen3-0.6b": (0.4e9, 1.1e9),
        "internlm2-1.8b": (1.4e9, 2.4e9),
        "stablelm-3b": (2.2e9, 3.6e9),
        "mamba2-2.7b": (2.2e9, 3.4e9),
        "zamba2-1.2b": (0.9e9, 1.9e9),
        "whisper-tiny": (20e6, 80e6),
        "internvl2-26b": (17e9, 27e9),       # LM backbone of the 26B VLM
        "command-r-plus-104b": (95e9, 115e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),
        "deepseek-v3-671b": (620e9, 700e9),
    }
    for name, (lo, hi) in expected.items():
        cfg = get_config(name)
        params, _ = init_model(cfg, abstract=True)
        n = count_params(params)
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params not in " \
                              f"[{lo/1e9:.1f}B, {hi/1e9:.1f}B]"
