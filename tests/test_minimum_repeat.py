"""Unit + property tests for minimum repeats, kernels and tails (paper §III-A,
§IV, Lemmas 1-2, Theorem 1)."""

from hypothesis import given
from hypothesis import strategies as st

from repro.core.minimum_repeat import (count_mrs, enumerate_mrs,
    has_k_mr_path, k_mr, kernel_tail, minimum_repeat)

seqs = st.lists(st.integers(0, 3), min_size=1, max_size=12).map(tuple)


def brute_mr(seq):
    n = len(seq)
    for p in range(1, n + 1):
        if n % p == 0 and seq[:p] * (n // p) == seq:
            return seq[:p]
    return seq


@given(seqs)
def test_mr_matches_bruteforce(seq):
    assert minimum_repeat(seq) == brute_mr(seq)


@given(seqs, st.integers(1, 4))
def test_mr_of_power_is_mr(seq, z):
    # Lemma 1 corollaries: MR(L^z) == MR(L); MR is idempotent.
    assert minimum_repeat(seq * z) == minimum_repeat(seq)
    assert minimum_repeat(minimum_repeat(seq)) == minimum_repeat(seq)


@given(seqs)
def test_mr_length_divides(seq):
    assert len(seq) % len(minimum_repeat(seq)) == 0


@given(seqs)
def test_kernel_unique_and_consistent(seq):
    """Definition 3 / Lemma 2: when a kernel exists it is unique; the
    decomposition reconstructs the sequence."""
    kt = kernel_tail(seq)
    if kt is None:
        return
    kern, tail = kt
    assert minimum_repeat(kern) == kern
    assert len(tail) < len(kern)
    h = (len(seq) - len(tail)) // len(kern)
    assert h >= 2
    assert kern * h + tail == seq
    assert tail == kern[:len(tail)]


def test_kernel_examples():
    # (knows, knows, knows): kernel (knows), tail eps (paper example)
    assert kernel_tail((0, 0, 0)) == ((0,), ())
    # L1 = (knows x4) from Example 2
    assert kernel_tail((0, 0, 0, 0)) == ((0,), ())
    # (knows, worksFor, knows, worksFor): kernel (knows, worksFor)
    assert kernel_tail((0, 1, 0, 1)) == ((0, 1), ())
    # (a b a b a): kernel (a,b), tail (a)
    assert kernel_tail((0, 1, 0, 1, 0)) == ((0, 1), (0,))
    # no kernel
    assert kernel_tail((0, 1, 2, 0)) is None
    assert kernel_tail((0,)) is None


@given(seqs, st.integers(1, 3))
def test_k_mr(seq, k):
    mr = minimum_repeat(seq)
    assert k_mr(seq, k) == (mr if len(mr) <= k else None)


def test_count_mrs_closed_form():
    # paper §V-C: C = sum F(i), F(i) = |L|^i - sum_{j | i, j != i} F(j)
    for num_labels in (1, 2, 3, 4, 8):
        for k in (1, 2, 3):
            assert count_mrs(num_labels, k) == len(
                enumerate_mrs(num_labels, k))


def test_enumerate_mrs_exact_small():
    # |L|=2, k=2: (0), (1), (0,1), (1,0)  — (0,0) and (1,1) are not MRs
    assert set(enumerate_mrs(2, 2)) == {(0,), (1,), (0, 1), (1, 0)}


@given(st.lists(st.integers(0, 2), min_size=1, max_size=8).map(tuple),
       st.lists(st.integers(0, 2), min_size=0, max_size=8).map(tuple),
       st.integers(1, 3))
def test_theorem1_case3(prefix_rest, rest, k):
    """Theorem 1 Case 3 agrees with direct MR computation when |prefix|=2k."""
    prefix = (prefix_rest * (2 * k))[:2 * k]  # force length 2k
    full = prefix + rest
    got = has_k_mr_path(prefix, rest, k)
    mr = minimum_repeat(full)
    want = mr if len(mr) <= k else None
    if len(full) > 2 * k:
        assert got == want
