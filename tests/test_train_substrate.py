"""Optimizer math, train loop, data pipeline, checkpointing, FT drills."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_pytree, save_pytree)
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.ft import ElasticMeshManager, StragglerMonitor, resilient_loop
from repro.train import OptConfig, adamw_init, adamw_update, lr_schedule
from repro.train.train_loop import init_train_state, make_train_step


# ------------------------------------------------------------------ #
# Optimizer vs numpy reference
# ------------------------------------------------------------------ #
def test_adamw_matches_numpy_reference():
    oc = OptConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                   min_lr_frac=1.0, weight_decay=0.1, clip_norm=0.0,
                   m_dtype="float32", v_dtype="float32")
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    state = adamw_init(p, oc)
    new_p, new_state, _ = adamw_update(g, state, p, oc)
    # numpy adam step 1
    gw = np.asarray(g["w"])
    m = 0.1 * gw
    v = 0.05 * gw * gw
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    want = np.asarray(p["w"]) - 1e-2 * (
        mhat / (np.sqrt(vhat) + oc.eps) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_lr_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                   min_lr_frac=0.1)
    lrs = [float(lr_schedule(oc, jnp.int32(s))) for s in
           [0, 5, 10, 60, 110, 200]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6
    assert abs(lrs[5] - 0.1) < 1e-6        # clamped past total_steps


def test_clipping_bounds_update_norm():
    oc = OptConfig(clip_norm=1e-3, weight_decay=0.0, warmup_steps=0,
                   min_lr_frac=1.0, lr=1.0, m_dtype="float32",
                   v_dtype="float32")
    p = {"w": jnp.ones((8, 8), jnp.float32)}
    g = {"w": jnp.full((8, 8), 100.0, jnp.float32)}
    state = adamw_init(p, oc)
    _, _, metrics = adamw_update(g, state, p, oc)
    assert float(metrics["grad_norm"]) > 100


# ------------------------------------------------------------------ #
# Train step: loss goes down; microbatching equivalence
# ------------------------------------------------------------------ #
def test_train_loop_loss_decreases():
    from repro.launch.train import run
    _, history, _ = run("qwen3-0.6b-smoke", steps=20, batch=4, seq=64,
                        log_every=1000)
    assert history[-1] < history[0], history


def test_microbatch_equivalence():
    cfg = get_config("internlm2-1.8b-smoke")
    oc = OptConfig(m_dtype="float32", v_dtype="float32",
                   grad_dtype="float32")
    state1, _ = init_train_state(cfg, oc, jax.random.PRNGKey(0))
    state2 = jax.tree.map(lambda x: x, state1)
    data = SyntheticLMData(cfg, DataConfig(seq_len=32, global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1, m1 = make_train_step(cfg, oc, microbatches=1)(state1, batch)
    s2, m2 = make_train_step(cfg, oc, microbatches=4)(state2, batch)
    for l1, l2 in zip(jax.tree.leaves(s1.params),
                      jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-4, atol=2e-5)


# ------------------------------------------------------------------ #
# Data pipeline
# ------------------------------------------------------------------ #
def test_data_determinism_and_sharding():
    cfg = get_config("qwen3-0.6b-smoke")
    d1 = SyntheticLMData(cfg, DataConfig(64, 8, seed=3))
    d2 = SyntheticLMData(cfg, DataConfig(64, 8, seed=3))
    b1, b2 = d1.batch_at(7), d2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(8)["tokens"], b1["tokens"])
    # host sharding: different processes see different shards
    da = SyntheticLMData(cfg, DataConfig(64, 8, seed=3, num_processes=2,
                                         process_index=0))
    db = SyntheticLMData(cfg, DataConfig(64, 8, seed=3, num_processes=2,
                                         process_index=1))
    assert da.batch_at(0)["tokens"].shape[0] == 4
    assert not np.array_equal(da.batch_at(0)["tokens"],
                              db.batch_at(0)["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


# ------------------------------------------------------------------ #
# Checkpointing
# ------------------------------------------------------------------ #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "b": [np.int32(7), np.ones(4, np.float16)]}
    save_pytree(str(tmp_path), 3, tree, extra={"note": "hi"})
    assert latest_step(str(tmp_path)) == 3
    restored, extra = restore_pytree(str(tmp_path), 3, tree)
    assert extra == {"note": "hi"}
    np.testing.assert_array_equal(restored["a"]["w"], tree["a"]["w"])
    np.testing.assert_array_equal(restored["b"][1], tree["b"][1])
    assert restored["b"][1].dtype == np.float16


def test_checkpoint_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": np.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, {"x": np.full(3, s, np.float64)})
    mgr.wait()
    assert latest_step(str(tmp_path)) == 4
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003", "step_00000004"]
    step, tree2, _ = mgr.restore_latest(tree)
    assert step == 4 and tree2["x"][0] == 4


def test_incomplete_checkpoint_ignored(tmp_path):
    save_pytree(str(tmp_path), 1, {"x": np.zeros(2)})
    # simulate a crash mid-write: directory without manifest
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 1


# ------------------------------------------------------------------ #
# Fault tolerance drills
# ------------------------------------------------------------------ #
def test_resilient_loop_restart_bit_identical(tmp_path):
    """Failure injected mid-run; the restarted run must converge to the
    same final state as an uninterrupted run (pure data pipeline +
    deterministic step)."""
    def mk_step():
        def step(state, batch):
            s = state["s"] + batch["x"].sum()
            return {"s": s, "n": state["n"] + 1}, {"loss": s}
        return step

    def batch_at(i):
        return {"x": jnp.full((4,), float(i + 1), jnp.float32)}

    init = {"s": jnp.float32(0), "n": jnp.int32(0)}
    ref, _ = resilient_loop(mk_step(), init, batch_at, 30,
                            str(tmp_path / "ref"), ckpt_every=7)
    injected, rep = resilient_loop(
        mk_step(), init, batch_at, 30, str(tmp_path / "inj"),
        ckpt_every=7, fail_at={11: RuntimeError("node died"),
                               23: RuntimeError("again")})
    assert rep.restarts == 2
    assert float(injected["s"]) == float(ref["s"])
    assert int(injected["n"]) == int(ref["n"])


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=8, factor=2.0)
    flagged = [mon.record(i, 0.1) for i in range(8)]
    assert not any(flagged)
    assert mon.record(9, 0.5) is True
    assert mon.record(10, 0.11) is False


def test_elastic_mesh_shrink():
    em = ElasticMeshManager(model_parallel=1)
    mesh = em.build()
    assert mesh.shape["data"] == len(jax.devices())
    # shrinking below a TP group raises
    em2 = ElasticMeshManager(model_parallel=len(jax.devices()) + 1)
    with pytest.raises(RuntimeError):
        em2.build()


def test_train_restart_resumes_from_checkpoint(tmp_path):
    from repro.launch.train import run
    # uninterrupted reference
    s_ref, h_ref, _ = run("qwen3-0.6b-smoke", steps=12, batch=2, seq=32,
                          ckpt_dir=str(tmp_path / "ref"), ckpt_every=4,
                          log_every=1000)
    # with two injected failures
    s_inj, h_inj, rep = run("qwen3-0.6b-smoke", steps=12, batch=2, seq=32,
                            ckpt_dir=str(tmp_path / "inj"), ckpt_every=4,
                            fail_at={5: RuntimeError("kill"),
                                     9: RuntimeError("kill2")},
                            log_every=1000)
    assert rep.restarts == 2
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_inj.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
