"""Sharding rules (divisibility fallbacks, pod-axis filtering) and the
roofline/HLO analysis machinery."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     model_flops, roofline_terms)
from repro.roofline.hlo_tools import scan_aware_totals, split_computations
from repro.sharding.partition import (ACT_RULES, PARAM_RULES,
                                      logical_to_spec)


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_logical_to_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 48 heads shard 16 ways; 6 heads fall back to replication
    assert logical_to_spec((1024, 6144), ("embed", "heads"), mesh,
                           PARAM_RULES) == P(None, "model")
    assert logical_to_spec((384, 6 * 64), ("embed", "heads"), mesh,
                           PARAM_RULES) == P(None, "model")  # 384%16==0
    assert logical_to_spec((10, 6), (None, "heads"), mesh,
                           PARAM_RULES) == P(None, None)


def test_logical_to_spec_pod_axis_filtering():
    single = FakeMesh({"data": 16, "model": 16})
    multi = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # act_batch = ("pod","data"): filtered to data on single-pod
    assert logical_to_spec((256, 128), ("act_batch", None), single,
                           ACT_RULES) == P("data", None)
    assert logical_to_spec((256, 128), ("act_batch", None), multi,
                           ACT_RULES) == P(("pod", "data"), None)
    # batch 8 not divisible by 32 -> replicate on multi
    assert logical_to_spec((8, 128), ("act_batch", None), multi,
                           ACT_RULES) == P(None, None)


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e9 / 2, 0.0)  # 1s compute, 0.5s memory
    assert t["dominant"] == "compute_s"
    assert abs(t["roofline_fraction"] - 1.0) < 1e-9
    t2 = roofline_terms(197e11, 819e9, 0.0)     # 0.1s compute, 1s memory
    assert t2["dominant"] == "memory_s"
    assert abs(t2["roofline_fraction"] - 0.1) < 1e-9


def test_model_flops_shapes():
    class C:
        num_experts = 0
        top_k = 0
    n = 1_000_000
    assert model_flops(C, "train", 128, 4, n) == 6 * n * 512
    assert model_flops(C, "prefill", 128, 4, n) == 2 * n * 512
    assert model_flops(C, "decode", 128, 4, n) == 2 * n * 4


SAMPLE_HLO = """
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p2), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8] all-gather(%d), channel_id=1, replica_groups=[4,2]<=[8], dimensions={0}
  %i2 = s32[] get-tuple-element(%p2), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ag)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_scan_aware_totals_on_synthetic_hlo():
    tot = scan_aware_totals(SAMPLE_HLO)
    # dot: 2*8*8*8 = 1024 flops, x12 trips
    assert tot["flops"] == 12 * 1024
    # all-gather: out 256B, g=2 -> wire 128B, x12
    assert tot["coll_all-gather"] == 12 * 128
    comps = split_computations(SAMPLE_HLO)
    assert "__entry__" in comps and "body" in comps


def test_collective_parser_kinds():
    text = ("%x = f32[1024]{0} all-reduce(%y), replica_groups=[2,4]<=[8]\n"
            "%z = bf16[64,32]{1,0} reduce-scatter(%w), "
            "replica_groups=[1,8]<=[8]\n")
    out = collective_bytes_from_hlo(text)
    assert out["all-reduce"] == 2 * 4096 * 3 // 4
    assert out["reduce-scatter"] == 64 * 32 * 2 * 7
    assert out["total"] == out["all-reduce"] + out["reduce-scatter"]


def test_scan_aware_matches_xla_on_real_compile():
    """On a while-free program, the HLO walk's dot flops should match
    XLA's cost analysis."""
    def f(a, b):
        return jnp.matmul(a, b)
    sa = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(sa, sa).compile()
    tot = scan_aware_totals(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, list):      # jax 0.4.x returns [dict], newer a dict
        ca = ca[0]
    want = float(ca["flops"])
    assert abs(tot["flops"] - want) / want < 0.05
