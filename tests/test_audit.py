"""Index-health auditor, regression-gate, and obs-CLI tests
(repro.obs.audit / benchmarks.regression / python -m repro.obs):
report schema contract, redundancy + soundness detection on healthy and
deliberately damaged indexes, drift-fingerprint properties across
delta-vs-rebuild, metric banking, and the artifact tooling around them."""
import json
import os
import sys

import numpy as np
import pytest

from repro.build import build_rlc_index_with_stats
from repro.graphgen import erdos_renyi, random_delta
from repro.obs import MetricsRegistry, to_prometheus
from repro.obs.audit import (AUDIT_SCHEMA, audit_index,
                             bank_audit_metrics, fingerprint,
                             validate_audit_report)
from repro.service import RLCService, ServiceConfig
from repro.service.sharded import ShardedRLCService, ShardedServiceConfig

K = 2


@pytest.fixture(scope="module")
def served():
    g = erdos_renyi(130, 3.5, 3, seed=21)
    svc = RLCService.build(g, ServiceConfig(k=K))
    yield g, svc
    svc.close()


# ------------------------------------------------------------------ #
# Report shape + healthy-index invariants
# ------------------------------------------------------------------ #
def test_audit_report_validates_and_is_json_clean(served):
    g, svc = served
    rep = svc.audit_report(sample=64)
    assert rep["schema"] == AUDIT_SCHEMA
    validate_audit_report(rep)
    validate_audit_report(json.loads(json.dumps(rep)))   # survives JSON
    assert rep is svc._last_audit
    ident = rep["identity"]
    assert ident["entries"] == svc.frozen.num_entries()
    assert ident["num_vertices"] == g.num_vertices


def test_fresh_index_has_zero_violations(served):
    g, svc = served
    rep = svc.audit_report(sample=200)
    assert rep["redundancy"]["violations"] == 0
    assert rep["soundness"]["violations"] == 0
    assert rep["soundness"]["sampled"] > 0


def test_histograms_account_for_every_entry(served):
    _g, svc = served
    rep = svc.audit_report(sample=16)
    h = rep["histograms"]
    assert sum(h["hub_rank_decile"]["out"]) == rep["identity"]["entries_out"]
    assert sum(h["hub_rank_decile"]["in_"]) == rep["identity"]["entries_in"]
    assert sum(h["mr_len"]["out"].values()) == rep["identity"]["entries_out"]
    assert sum(h["mr_len"]["in_"].values()) == rep["identity"]["entries_in"]
    assert h["label"]                         # some label carries entries


def test_byte_accounting_components(served):
    _g, svc = served
    rep = svc.audit_report(sample=8)
    b = rep["bytes"]
    assert b["index"] == svc.index.size_bytes()
    assert b["frozen"] > 0
    if svc.device_index is not None:
        assert b["device"] > 0


# ------------------------------------------------------------------ #
# Detection: injected redundancy
# ------------------------------------------------------------------ #
def test_injected_redundant_entry_is_detected(served):
    g, svc = served
    from repro.core.queries import biased_true_queries
    qs = biased_true_queries(g, K, n=40, seed=7)
    # find a Case-1-only truth: reachable via a middle hub (distinct
    # from both endpoints) but with no direct entry — adding the direct
    # entry then violates Definition 5
    target = None
    for s, t, L in qs.true_queries:
        b = svc.explain(s, t, L)
        if b["witness"]["kind"] != "case1":
            continue
        mid = b["mr_id"]
        oh, om = svc.frozen.row_out(s)
        ih, im = svc.frozen.row_in(t)
        o = set(oh[om == mid].tolist()) - {s, t}
        i = set(ih[im == mid].tolist()) - {s, t}
        if o & i:
            target = (s, t, tuple(L))
            break
    assert target is not None
    s, t, L = target
    idx, _ = build_rlc_index_with_stats(g, K)       # private copy
    idx.add_out(s, t, L)
    idx.add_in(t, s, L)
    frozen = idx.freeze(svc.mr_ids)
    rep = audit_index(frozen, svc._id_to_mr,
                      sample=frozen.num_entries() + 1)
    assert rep["redundancy"]["violations"] >= 1
    ex = rep["redundancy"]["examples"][0]
    assert set(ex) == {"s", "t", "mr_id", "mr"}


# ------------------------------------------------------------------ #
# Drift fingerprints
# ------------------------------------------------------------------ #
def test_fingerprint_delta_equals_rebuild(served):
    g, _svc = served
    svc = RLCService.build(g, ServiceConfig(k=K, use_device=False))
    svc.apply_delta(random_delta(svc.graph, 6, 3,
                                 np.random.default_rng(2)))
    rebuilt, _ = build_rlc_index_with_stats(svc.graph, K)
    fp_serving = fingerprint(svc.frozen)
    fp_rebuilt = fingerprint(rebuilt.freeze(svc.mr_ids))
    assert fp_serving == fp_rebuilt           # PR5's bit-identical claim
    svc.close()


def test_fingerprint_localizes_drift_to_row_buckets(served):
    g, svc = served
    fp0 = fingerprint(svc.frozen)
    idx, _ = build_rlc_index_with_stats(g, K)
    v = 7
    hub = next(h for h in range(g.num_vertices)
               if h != v and not idx.has_out(v, h, (0,)))
    idx.add_out(v, hub, (0,))
    fp1 = fingerprint(idx.freeze(svc.mr_ids))
    assert fp1["combined"] != fp0["combined"]
    diff = [i for i, (a, b) in enumerate(zip(fp0["row_buckets_out"],
                                             fp1["row_buckets_out"]))
            if a != b]
    assert diff == [v % 64]                   # names the residue class
    assert fp0["row_buckets_in"] == fp1["row_buckets_in"]


def test_fingerprint_differs_across_graphs():
    g1 = erdos_renyi(60, 3.0, 3, seed=1)
    g2 = erdos_renyi(60, 3.0, 3, seed=2)
    s1 = RLCService.build(g1, ServiceConfig(k=K, use_device=False))
    s2 = RLCService.build(g2, ServiceConfig(k=K, use_device=False))
    assert fingerprint(s1.frozen)["combined"] != \
        fingerprint(s2.frozen)["combined"]
    s1.close()
    s2.close()


# ------------------------------------------------------------------ #
# Schema contract: mutations must be rejected
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("mutate, hint", [
    (lambda d: d.update(schema="repro.obs.audit/0"), "schema"),
    (lambda d: d["identity"].update(entries=1), "entries"),
    (lambda d: d["identity"].update(num_vertices=-1), "num_vertices"),
    (lambda d: d["histograms"]["hub_rank_decile"].update(out=[1, 2]),
     "hub_rank_decile"),
    (lambda d: d["redundancy"].update(violations=10 ** 9), "violations"),
    (lambda d: d["redundancy"].update(sampled=True), "sampled"),
    (lambda d: d["bytes"].update(frozen=-5), "bytes"),
    (lambda d: d["fingerprint"].update(combined="nope"), "combined"),
    (lambda d: d["fingerprint"].update(row_buckets_out=[]),
     "row_buckets_out"),
])
def test_audit_report_rejects_malformed(served, mutate, hint):
    _g, svc = served
    rep = json.loads(json.dumps(svc.audit_report(sample=8)))
    mutate(rep)
    with pytest.raises(ValueError, match=hint):
        validate_audit_report(rep)


# ------------------------------------------------------------------ #
# Metric banking + sharded breakdown
# ------------------------------------------------------------------ #
def test_bank_audit_metrics_exports_prometheus_block(served):
    _g, svc = served
    reg = MetricsRegistry()
    bank_audit_metrics(reg, svc.audit_report(sample=8))
    text = to_prometheus(reg)
    assert 'rlc_audit_entries{direction="out"}' in text
    assert "rlc_audit_redundancy_violations" in text
    assert 'rlc_audit_bytes{component="frozen"}' in text


def test_sharded_audit_adds_per_shard_rows(served):
    g, svc = served
    sh = ShardedRLCService.build(
        g, ShardedServiceConfig(k=K, num_shards=3), index=svc.index)
    rep = sh.audit_report(sample=32)
    validate_audit_report(rep)
    assert len(rep["shards"]) == 3
    assert sum(r["entries"] for r in rep["shards"]) == \
        rep["identity"]["entries"]
    for r in rep["shards"]:
        assert r["frozen_bytes"] > 0
    # audit rides the sharded snapshot's extra section too
    snap = sh.telemetry_snapshot()
    assert snap["extra"]["audit"]["shards"] == rep["shards"]
    sh.close()


# ------------------------------------------------------------------ #
# Regression gate (benchmarks/regression.py)
# ------------------------------------------------------------------ #
def _bench_regression():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import regression
    return regression


def _write_artifacts(d, qps=100.0, swap=0.1):
    arts = {
        "service.json": dict(results=dict(
            sorted=dict(qps=qps), numpy=dict(qps=qps / 2),
            cache_4096=dict(hit_rate=0.9, qps=qps * 2))),
        "sharded.json": dict(results=dict(
            shards_2=dict(qps=qps), hot_swap=dict(swap_s=swap),
            slo=dict(p99_over_p50=1.5),
            overload=dict(shed_ratio=0.1),
            warming=dict(warm_hit_rate=0.6),
            rpc=dict(qps=qps / 4, roundtrip_p99_us=swap * 1e4,
                     digest_wire_kb=swap * 40.0))),
        "indexing.json": dict(aggregate_s=dict(python=2.0, numpy=0.4),
                              numpy_aggregate_speedup=5.0,
                              parallel_speedup=1.8),
        "delta.json": dict(best_single_speedup=5.0),
    }
    for name, doc in arts.items():
        with open(os.path.join(d, name), "w") as f:
            json.dump(doc, f)


def test_regression_distill_and_clean_compare(tmp_path):
    regression = _bench_regression()
    d = str(tmp_path)
    _write_artifacts(d)
    base = regression.distill(d)
    assert base["schema"] == regression.BASELINES_SCHEMA
    assert len(base["metrics"]) == len(regression.METRICS)
    verdict = regression.compare(d, base)
    assert verdict["failed"] == 0 and verdict["warned"] == 0
    assert all(r["status"] == "ok" for r in verdict["metrics"])


def test_regression_warn_then_fail_ladder(tmp_path, monkeypatch):
    regression = _bench_regression()
    monkeypatch.delenv("RLC_BENCH_WARN_RATIO", raising=False)
    monkeypatch.delenv("RLC_BENCH_FAIL_RATIO", raising=False)
    d = str(tmp_path)
    _write_artifacts(d, qps=100.0, swap=0.1)
    base = regression.distill(d)
    # 2x worse qps everywhere: warns (inside the 8x fail ratio)
    _write_artifacts(d, qps=50.0, swap=0.2)
    verdict = regression.compare(d, base)
    assert verdict["failed"] == 0
    assert verdict["warned"] >= 3
    # 10x worse: hard failure
    _write_artifacts(d, qps=10.0, swap=1.0)
    verdict = regression.compare(d, base)
    assert verdict["failed"] >= 3
    # a *better* fresh number never warns, whatever the direction
    _write_artifacts(d, qps=1000.0, swap=0.01)
    verdict = regression.compare(d, base)
    assert verdict["failed"] == 0 and verdict["warned"] == 0
    # env override tightens the ladder
    monkeypatch.setenv("RLC_BENCH_FAIL_RATIO", "1.5")
    _write_artifacts(d, qps=50.0, swap=0.2)
    verdict = regression.compare(d, base)
    assert verdict["failed"] >= 3


def test_regression_missing_metric_fails(tmp_path):
    regression = _bench_regression()
    d = str(tmp_path)
    _write_artifacts(d)
    base = regression.distill(d)
    os.unlink(os.path.join(d, "delta.json"))
    verdict = regression.compare(d, base)
    rows = {r["metric"]: r for r in verdict["metrics"]}
    assert rows["delta:best_single_speedup"]["status"] == "missing"
    assert verdict["failed"] >= 1


def test_regression_gate_writes_verdict_and_reports(tmp_path):
    regression = _bench_regression()
    d = str(tmp_path)
    _write_artifacts(d)
    base_path = os.path.join(d, "baselines.json")
    with open(base_path, "w") as f:
        json.dump(regression.distill(d), f)
    assert regression.gate(d, base_path) == []
    with open(os.path.join(d, "regression.json")) as f:
        verdict = json.load(f)
    assert verdict["schema"] == "repro.bench.regression/1"
    # degrade far past fail_ratio: gate returns orchestrator failures
    _write_artifacts(d, qps=1.0, swap=10.0)
    failures = regression.gate(d, base_path)
    assert failures and all(n.startswith("regression:")
                            for n, _e in failures)


def test_committed_baselines_parse():
    regression = _bench_regression()
    doc = regression.load_baselines()
    assert doc is not None, "benchmarks/baselines.json must be committed"
    assert doc["schema"] == regression.BASELINES_SCHEMA
    assert doc["metrics"]


# ------------------------------------------------------------------ #
# CLI: python -m repro.obs
# ------------------------------------------------------------------ #
def _cli(argv):
    from repro.obs.__main__ import main
    return main(argv)


def test_cli_validate_dump_prom_audit(tmp_path, served, capsys):
    _g, svc = served
    svc.query_batch([(0, 1, (0,)), (2, 3, (1,))])
    svc.audit_report(sample=8)
    snap_path = tmp_path / "snap.json"
    with open(snap_path, "w") as f:
        json.dump(svc.telemetry_snapshot(), f)
    assert _cli(["validate", str(snap_path)]) == 0
    out = capsys.readouterr().out
    assert "OK repro.obs/1" in out
    assert "OK repro.obs.audit/1" in out      # embedded in extra
    assert _cli(["dump", str(snap_path)]) == 0
    assert "rlc_cache_lookups" in capsys.readouterr().out
    assert _cli(["prom", str(snap_path)]) == 0
    assert "rlc_cache_lookups_total" in capsys.readouterr().out
    assert _cli(["audit", str(snap_path)]) == 0
    assert "fingerprint:" in capsys.readouterr().out


def test_cli_flags_invalid_documents(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    with open(bad, "w") as f:
        json.dump(dict(schema="repro.obs/1", metrics="nope"), f)
    assert _cli(["validate", str(bad)]) == 1
    assert "INVALID" in capsys.readouterr().out
    empty = tmp_path / "empty.json"
    with open(empty, "w") as f:
        json.dump(dict(hello="world"), f)
    assert _cli(["validate", str(empty)]) == 1
    assert _cli(["audit", str(empty)]) == 1
    assert _cli([]) == 2                      # usage error
    assert _cli(["validate", str(tmp_path / "missing.json")]) == 2


def test_cli_chrome_trace_summary(tmp_path, capsys):
    g = erdos_renyi(50, 3.0, 3, seed=4)
    svc = RLCService.build(g, ServiceConfig(k=K, trace_sample_rate=1.0,
                                            use_device=False))
    svc.query_batch([(0, 1, (0,)), (2, 3, (1,))])
    path = tmp_path / "trace.json"
    with open(path, "w") as f:
        json.dump(svc.chrome_trace(), f)
    assert _cli(["chrome", str(path)]) == 0
    assert "spans" in capsys.readouterr().out
    assert _cli(["validate", str(path)]) == 0
    svc.close()
