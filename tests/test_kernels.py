"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.bitpack import pack_bits, unpack_bits


def rand_bool(rng, shape, density=0.2, dtype=np.float32):
    return (rng.random(shape) < density).astype(dtype)


# ------------------------------------------------------------------ #
# bool_semiring
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 128, 384), (64, 64, 64), (128, 256, 128),
    (100, 130, 90),        # ragged -> exercises padding
    (8, 8, 8),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_bool_matmul_matches_ref(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = rand_bool(rng, (m, k)).astype(dtype)
    b = rand_bool(rng, (k, n)).astype(dtype)
    got = ops.bool_matmul(a, b, interpret=True)
    want = ref.bool_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("n", [64, 128, 200, 256])
def test_closure_step_matches_ref(n):
    rng = np.random.default_rng(n)
    r = rand_bool(rng, (n, n), density=0.05)
    got = ops.closure_step(jnp.asarray(r), interpret=True)
    want = ref.fused_closure_step_ref(jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_closure_step_converges_to_transitive_closure():
    rng = np.random.default_rng(0)
    n = 96
    r = rand_bool(rng, (n, n), density=0.02)
    R = jnp.asarray(r)
    for _ in range(8):
        R = ops.closure_step(R, interpret=True)
    # fixpoint reached: R == R | R@R
    np.testing.assert_array_equal(
        np.asarray(R), np.asarray(ref.fused_closure_step_ref(R)))


# ------------------------------------------------------------------ #
# mergejoin
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("n,E,Q", [(32, 8, 17), (64, 24, 64), (128, 64, 3)])
def test_mergejoin_matches_ref(n, E, Q):
    rng = np.random.default_rng(n + E + Q)
    def rows():
        hub = rng.integers(-1, n, size=(n, E)).astype(np.int32)
        mr = rng.integers(0, 6, size=(n, E)).astype(np.int32)
        mr[hub == -1] = -1
        return jnp.asarray(hub), jnp.asarray(mr)
    oh, om = rows()
    ih, im = rows()
    s = jnp.asarray(rng.integers(0, n, Q).astype(np.int32))
    t = jnp.asarray(rng.integers(0, n, Q).astype(np.int32))
    mr = jnp.asarray(rng.integers(0, 6, Q).astype(np.int32))
    got = ops.mergejoin_query(oh, om, ih, im, s, t, mr, interpret=True)
    want = ref.mergejoin_ref(oh, om, ih, im, s, t, mr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mergejoin_on_real_index():
    from repro.core.device_index import DeviceIndex
    from repro.core.index_builder import build_rlc_index
    from repro.core.minimum_repeat import mr_id_space
    from repro.graphgen import random_labeled_graph

    g = random_labeled_graph(num_vertices=12, num_edges=36, num_labels=2,
                             seed=0)
    idx = build_rlc_index(g, 2)
    dev = DeviceIndex.from_index(idx, g.num_labels)
    ids = mr_id_space(g.num_labels, 2)
    qs, qt, qm, want = [], [], [], []
    for s in range(12):
        for t in range(12):
            for L, c in ids.items():
                qs.append(s), qt.append(t), qm.append(c)
                want.append(idx.query(s, t, L))
    got = dev.query_batch(np.array(qs), np.array(qt), np.array(qm),
                          use_pallas=True)
    assert got.tolist() == want


# ------------------------------------------------------------------ #
# bitpack
# ------------------------------------------------------------------ #
def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    x = rand_bool(rng, (16, 256))
    xp = pack_bits(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(unpack_bits(xp)), x)


@pytest.mark.parametrize("m,k,n", [(64, 64, 1024), (128, 128, 4096),
                                   (32, 100, 512)])
def test_bitpack_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    a = rand_bool(rng, (m, k), density=0.15)
    b = rand_bool(rng, (k, n), density=0.15)
    bp = pack_bits(jnp.asarray(b))
    got = ops.bitpack_matmul(jnp.asarray(a), bp, interpret=True)
    # oracle: unpack(out) == bool_matmul(a, b)
    want = ref.bool_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(got))[:, :n], np.asarray(want))


# ------------------------------------------------------------------ #
# label_frontier
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("B,V,L", [(128, 128, 3), (64, 200, 2),
                                   (32, 64, 5)])
def test_frontier_step_matches_ref(B, V, L):
    rng = np.random.default_rng(B + V + L)
    f = rand_bool(rng, (B, V), density=0.1)
    A = rand_bool(rng, (L, V, V), density=0.05)
    for lab in range(L):
        got = ops.frontier_step(jnp.asarray(f), jnp.asarray(A),
                                jnp.asarray(lab), interpret=True)
        want = ref.frontier_step_ref(jnp.asarray(f), jnp.asarray(A),
                                     jnp.asarray(lab))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------------ #
# dense engine plumbed through the Pallas matmul
# ------------------------------------------------------------------ #
def test_dense_engine_with_pallas_matmul():
    from functools import partial
    from repro.core.dense import DenseEngine
    from repro.core.baselines import ETC
    from repro.graphgen import random_labeled_graph

    g = random_labeled_graph(num_vertices=10, num_edges=30, num_labels=2,
                             seed=6)
    mm = partial(ops.bool_matmul, interpret=True)
    eng = DenseEngine.build(g, 2, matmul=mm)
    etc = ETC(g, 2)
    for u in range(10):
        for v in range(10):
            assert eng.s_k(u, v) == etc.s_k(u, v)


# ------------------------------------------------------------------ #
# label_frontier: multi-label / multi-step batching
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("R,V,L", [(6, 128, 3), (9, 256, 4)])
def test_frontier_step_many_matches_per_row(R, V, L):
    from repro.kernels.label_frontier import frontier_step_many

    rng = np.random.default_rng(R + V + L)
    f = rand_bool(rng, (R, V), density=0.08)
    A = rand_bool(rng, (L, V, V), density=0.04)
    labels = rng.integers(0, L, R).astype(np.int32)
    got = frontier_step_many(jnp.asarray(f), jnp.asarray(A),
                             jnp.asarray(labels), interpret=True)
    want = np.stack([(f[r] @ A[labels[r]]) > 0 for r in range(R)])
    np.testing.assert_array_equal(np.asarray(got),
                                  want.astype(np.float32))


def test_frontier_steps_matches_chained_many():
    from repro.kernels.label_frontier import frontier_steps

    rng = np.random.default_rng(42)
    R, V, L, T = 5, 128, 3, 4
    f = rand_bool(rng, (R, V), density=0.08)
    A = rand_bool(rng, (L, V, V), density=0.04)
    labels = rng.integers(0, L, (T, R)).astype(np.int32)
    dst = np.stack([rng.permutation(R) for _ in range(T)]).astype(np.int32)
    got = frontier_steps(jnp.asarray(f), jnp.asarray(A),
                         jnp.asarray(labels), jnp.asarray(dst),
                         interpret=True)
    ref_f = f.copy()
    for t in range(T):
        step = np.stack([(ref_f[r] @ A[labels[t, r]]) > 0
                         for r in range(R)]).astype(np.float32)
        out = np.zeros_like(step)
        out[dst[t]] = step
        ref_f = out
    np.testing.assert_array_equal(np.asarray(got), ref_f)


def test_frontier_steps_advances_product_automaton():
    """frontier_steps with the cyclic phase shift == m scalar BFS waves
    of the kernel-BFS (no pruning) on a real graph."""
    from repro.graphgen import random_labeled_graph
    from repro.kernels.label_frontier import frontier_steps

    g = random_labeled_graph(num_vertices=20, num_edges=70, num_labels=2,
                             seed=1)
    V, Vp = g.num_vertices, 128
    A = np.zeros((2, Vp, Vp), np.float32)
    e = g.edges
    A[e[:, 1], e[:, 0], e[:, 2]] = 1
    Lseq = (0, 1)
    m = len(Lseq)
    # rows = phases; row p follows label L[p], result lands at (p+1) % m
    labels = np.tile([Lseq[p] for p in range(m)], (m, 1)).astype(np.int32)
    dst = np.tile((np.arange(m) + 1) % m, (m, 1)).astype(np.int32)
    F = np.zeros((m, Vp), np.float32)
    F[0, 3] = 1  # seed vertex 3 at phase 0
    got = np.asarray(frontier_steps(jnp.asarray(F), jnp.asarray(A),
                                    jnp.asarray(labels), jnp.asarray(dst),
                                    interpret=True))
    # scalar oracle: m unpruned product-automaton waves
    cur = {(3, 0)}
    for _ in range(m):
        nxt = set()
        for (x, p) in cur:
            for y in g.out_neighbors_with_label(x, Lseq[p]).tolist():
                nxt.add((y, (p + 1) % m))
        cur = nxt
    want = np.zeros((m, Vp), np.float32)
    for (y, p) in cur:
        want[p, y] = 1
    np.testing.assert_array_equal(got, want)
