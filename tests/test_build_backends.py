"""Build-backend equivalence: every backend of the staged pipeline must
produce bit-identical index entries AND pruning counters to the python
reference, across graph families, k, |L|, loop density, and pruning-flag
ablations — plus counter sanity invariants and the serving integration
(stats block, hot-swap on a non-python backend)."""
import numpy as np
import pytest

from repro.build import (build_rlc_index, build_rlc_index_with_stats,
    get_backend, list_backends)
from repro.core.baselines import bfs_rlc
from repro.core.minimum_repeat import enumerate_mrs
from repro.graphgen import (barabasi_albert, erdos_renyi, fig2_graph,
                            random_labeled_graph)


def entry_sets(idx):
    out = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_out)
                       for h, ms in d.items() for m in ms))
    inn = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_in)
                       for h, ms in d.items() for m in ms))
    return out, inn


def assert_equivalent(g, k, flags=None, backends=(("numpy", {}),)):
    flags = flags or {}
    ref_idx, ref_stats = build_rlc_index_with_stats(
        g, k, backend="python", **flags)
    ref_entries = entry_sets(ref_idx)
    for name, kw in backends:
        idx, stats = build_rlc_index_with_stats(g, k, backend=name,
                                                **flags, **kw)
        assert entry_sets(idx) == ref_entries, (name, kw, flags)
        assert stats.counters() == ref_stats.counters(), (name, kw, flags)
    return ref_idx, ref_stats


NUMPY_MODES = [("numpy", dict(mode="hybrid")),
               ("numpy", dict(mode="vector")),
               ("numpy", dict(mode="bits")),
               ("numpy", dict(mode="scalar"))]


# ------------------------------------------------------------------ #
# Property sweep: vary V, |L|, k, loop density across families
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("k,num_labels,loops", [
    (1, 2, 0.0), (2, 2, 0.2), (2, 3, 0.0), (3, 2, 0.3), (3, 3, 0.1)])
def test_numpy_matches_python_random(seed, k, num_labels, loops):
    g = random_labeled_graph(num_vertices=12, num_edges=40,
                             num_labels=num_labels, seed=seed,
                             self_loop_frac=loops)
    assert_equivalent(g, k, backends=NUMPY_MODES)


@pytest.mark.parametrize("seed", range(3))
def test_numpy_matches_python_families(seed):
    assert_equivalent(erdos_renyi(30, 3.0, 4, seed=seed), 2,
                      backends=NUMPY_MODES)
    assert_equivalent(barabasi_albert(24, 3, 3, seed=seed), 2,
                      backends=NUMPY_MODES)


@pytest.mark.parametrize("flags", [
    dict(use_pr1=False), dict(use_pr2=False), dict(use_pr3=False),
    dict(use_pr1=False, use_pr2=False, use_pr3=False)])
def test_numpy_matches_python_pruning_ablations(flags):
    g = random_labeled_graph(num_vertices=14, num_edges=50, num_labels=2,
                             seed=7, self_loop_frac=0.2)
    assert_equivalent(g, 2, flags=flags, backends=NUMPY_MODES)


def test_numpy_answers_match_oracle():
    """End-to-end: batched build answers == product-automaton oracle."""
    g = random_labeled_graph(num_vertices=12, num_edges=40, num_labels=2,
                             seed=3, self_loop_frac=0.15)
    idx = build_rlc_index(g, 2, backend="numpy")
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L in enumerate_mrs(2, 2):
                assert idx.query(s, t, L) == bfs_rlc(g, s, t, L)


def test_fig2_all_backends():
    g, _ = fig2_graph()
    idx, _ = assert_equivalent(g, 2, backends=NUMPY_MODES)
    assert idx.is_condensed()


def test_edge_cases():
    # edgeless graph and single-vertex self loops
    g0 = __import__("repro.core.graph", fromlist=["LabeledGraph"]
                    ).LabeledGraph.from_edges(3, 2, np.zeros((0, 3)))
    assert_equivalent(g0, 2, backends=NUMPY_MODES)
    g1 = __import__("repro.core.graph", fromlist=["LabeledGraph"]
                    ).LabeledGraph.from_edges(
        1, 2, np.array([[0, 0, 0], [0, 1, 0]]))
    idx, _ = assert_equivalent(g1, 2, backends=NUMPY_MODES)
    assert idx.query(0, 0, (0, 1))


# ------------------------------------------------------------------ #
# Pallas backend (interpret mode on CPU — keep the graphs tiny)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", [0, 1])
def test_pallas_matches_python(seed):
    pytest.importorskip("jax")
    g = random_labeled_graph(num_vertices=9, num_edges=24, num_labels=2,
                             seed=seed, self_loop_frac=0.2)
    assert_equivalent(g, 2, backends=[
        ("pallas", dict(mode="vector", interpret=True))])


# ------------------------------------------------------------------ #
# Counter invariants
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("backend", ["python", "numpy"])
def test_counter_invariants(backend):
    g = random_labeled_graph(num_vertices=16, num_edges=60, num_labels=3,
                             seed=11, self_loop_frac=0.1)
    idx, st = build_rlc_index_with_stats(g, 2, backend=backend)
    # PR3 can cut at most one subtree per discovered kernel-BFS state
    assert st.pr3_cuts <= st.kernel_bfs_states
    # with full pruning every successful insert is a distinct new entry
    assert st.inserted == idx.num_entries()
    # every pruned/successful attempt was a discovered state
    attempts = st.inserted + st.pruned_pr1 + st.pruned_pr2
    assert attempts <= st.kernel_search_states + st.kernel_bfs_states
    assert st.backend == backend
    assert st.wall_time_s > 0


def test_registry_and_auto():
    assert set(list_backends()) >= {"python", "numpy", "pallas"}
    assert get_backend("auto").name == "numpy"
    with pytest.raises(ValueError):
        get_backend("no-such-backend")
    with pytest.raises(ValueError):
        get_backend("numpy", mode="warp-drive")


# ------------------------------------------------------------------ #
# Serving integration: BuildStats in stats(), hot-swap backend
# ------------------------------------------------------------------ #
def test_service_stats_build_block():
    from repro.service import RLCService, ServiceConfig
    g = erdos_renyi(60, 3.0, 3, seed=5)
    svc = RLCService.build(g, ServiceConfig(k=2, build_backend="numpy",
                                            use_device=False))
    blk = svc.stats()["build"]
    assert blk["backend"] == "numpy"
    assert blk["inserted"] == svc.index.num_entries()
    assert blk["wall_time_s"] > 0
    # adopted index -> no build stats
    svc2 = RLCService.build(g, ServiceConfig(k=2, use_device=False),
                            index=svc.index)
    assert svc2.stats()["build"] is None


def test_sharded_hot_swap_uses_batched_backend():
    from repro.service.sharded import ShardedRLCService, ShardedServiceConfig
    g = erdos_renyi(80, 3.0, 3, seed=9)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=2, build_backend="numpy",
                                use_device=False))
    assert svc.stats()["build"]["backend"] == "numpy"
    g2 = erdos_renyi(80, 3.2, 3, seed=10)
    gen = svc.hot_swap(graph=g2)
    assert gen == 1
    assert svc.stats()["build"]["backend"] == "numpy"
    assert all(sh["build_backend"] == "numpy"
               for sh in svc.stats()["shards"])
    # answers after the swap match a fresh python-reference build
    ref = build_rlc_index(g2, 2, backend="python")
    rng = np.random.default_rng(0)
    queries = [(int(rng.integers(80)), int(rng.integers(80)), mr)
               for mr in enumerate_mrs(3, 2) for _ in range(4)]
    got = svc.query_batch([(s, t, mr) for s, t, mr in queries])
    want = [ref.query(s, t, mr) for s, t, mr in queries]
    assert got == want
    # explicit override is honored
    svc.hot_swap(graph=g2, build_backend="python")
    assert svc.stats()["build"]["backend"] == "python"
