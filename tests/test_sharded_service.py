"""Sharded multi-host serving (repro.service.sharded.*): shard planner,
frozen-slice views, two-sided router, scatter/gather fan-out, replica
hot-swap, and sharded-vs-single-host agreement (ISSUE-3 acceptance:
bit-identical answers over shard counts {1, 2, 4} x replicas {1, 2} on
>= 3 random graphs, plus a passing mid-stream hot-swap test)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.baselines import bibfs_rlc
from repro.core.index_builder import build_rlc_index
from repro.core.minimum_repeat import enumerate_mrs, mr_id_space
from repro.core.rlc_index import merge_join_rows
from repro.graphgen import barabasi_albert, erdos_renyi
from repro.service import RLCService, ServiceConfig
from repro.service.sharded import (ShardedRLCService, ShardedServiceConfig,
                                   TwoSidedRouter, plan_shards)


def _frozen(g, k=2):
    idx = build_rlc_index(g, k)
    ids = mr_id_space(g.num_labels, k)
    return idx, ids, idx.freeze(ids)


# ------------------------------------------------------------------ #
# Shard planner
# ------------------------------------------------------------------ #
def test_plan_contiguous_and_covering():
    g = erdos_renyi(80, 3.0, 3, seed=1)
    _, _, frozen = _frozen(g)
    for S in (1, 2, 3, 4, 8):
        plan = plan_shards(frozen, S)
        assert plan.num_shards == S
        assert plan.starts[0] == 0 and plan.starts[-1] == 80
        assert np.all(np.diff(plan.starts) >= 1)    # every shard non-empty
        # every vertex maps into the shard whose range contains it
        for v in range(80):
            s = plan.shard_of(v)
            lo, hi = plan.range(s)
            assert lo <= v < hi
        np.testing.assert_array_equal(
            plan.shard_of_batch(np.arange(80)),
            [plan.shard_of(v) for v in range(80)])


def test_plan_balances_by_entries_not_vertices():
    # hub-heavy head: BA graphs concentrate entries on early vertices
    g = barabasi_albert(120, 3, 3, seed=5)
    _, _, frozen = _frozen(g)
    plan = plan_shards(frozen, 4)
    w = frozen.entry_weights()
    per_shard = [int(w[lo:hi].sum()) for lo, hi in plan.ranges()]
    vertices = [hi - lo for lo, hi in plan.ranges()]
    # entry counts stay near-balanced ...
    assert max(per_shard) <= 2.0 * (sum(per_shard) / len(per_shard))
    # ... which for a skewed graph forces unequal vertex counts
    assert max(vertices) > min(vertices)


def test_plan_rejects_bad_shard_counts():
    g = erdos_renyi(10, 2.0, 2, seed=0)
    _, _, frozen = _frozen(g)
    with pytest.raises(ValueError):
        plan_shards(frozen, 0)
    with pytest.raises(ValueError):
        plan_shards(frozen, 11)


# ------------------------------------------------------------------ #
# Frozen slice views
# ------------------------------------------------------------------ #
def test_slice_rows_zero_copy_and_query_equivalence():
    g = erdos_renyi(50, 3.0, 3, seed=3)
    idx, ids, frozen = _frozen(g)
    sl = frozen.slice_rows(10, 35)
    # entry arrays are views of the parent's buffers, not copies
    assert sl.out_hub.base is not None and sl.in_hub.base is not None
    assert sl.num_entries() <= frozen.num_entries()
    mrs = enumerate_mrs(3, 2)
    rng = np.random.default_rng(4)
    for _ in range(150):
        s, t = int(rng.integers(10, 35)), int(rng.integers(10, 35))
        m = int(rng.integers(len(mrs)))
        # both endpoints in range: the slice answers exactly like the parent
        assert sl.query(s, t, m) == frozen.query(s, t, m)
    # out-of-range s sees an empty out-row (the routing contract)
    oh, _ = sl.row_out(5)
    assert len(oh) == 0


def test_slice_digest_join_matches_full_index():
    """Cross-shard contract: s's out-row digest + t-owner's local in-row
    through merge_join_rows == the unsharded answer."""
    g = erdos_renyi(50, 3.5, 3, seed=8)
    _, ids, frozen = _frozen(g)
    left, right = frozen.slice_rows(0, 25), frozen.slice_rows(25, 50)
    mrs = enumerate_mrs(3, 2)
    rng = np.random.default_rng(9)
    for _ in range(150):
        s, t = int(rng.integers(0, 25)), int(rng.integers(25, 50))
        m = int(rng.integers(len(mrs)))
        oh, om = left.row_out(s)        # the shipped digest
        ih, im = right.row_in(t)        # in-side owner's local row
        got = merge_join_rows(oh, om, ih, im, frozen.aid, s, t, m)
        assert got == frozen.query(s, t, m), (s, t, m)


def test_slice_rows_rejects_bad_range():
    g = erdos_renyi(20, 2.0, 2, seed=0)
    _, _, frozen = _frozen(g)
    with pytest.raises(ValueError):
        frozen.slice_rows(-1, 10)
    with pytest.raises(ValueError):
        frozen.slice_rows(5, 21)


# ------------------------------------------------------------------ #
# Two-sided router
# ------------------------------------------------------------------ #
def test_router_invariant_home_is_shard_t():
    g = erdos_renyi(40, 3.0, 3, seed=2)
    _, _, frozen = _frozen(g)
    router = TwoSidedRouter(plan_shards(frozen, 4))
    rng = np.random.default_rng(6)
    for _ in range(100):
        s, t = int(rng.integers(40)), int(rng.integers(40))
        r = router.route(s, t)
        assert r.home == r.shard_t == router.plan.shard_of(t)
        assert r.local == (router.plan.shard_of(s) == r.shard_t)
    st_ = router.stats()
    assert st_["local"] + st_["remote"] == 100
    assert sum(st_["pairs"].values()) == 100


# ------------------------------------------------------------------ #
# Sharded vs single-host agreement (property, hypothesis stub)
# ------------------------------------------------------------------ #
@settings(max_examples=4)
@given(st.integers(0, 10_000), st.integers(40, 70))
def test_sharded_matches_single_host_and_oracle(seed, n):
    """>= 3 random graphs (4 stub examples) x shards {1,2,4} x replicas
    {1,2}: bit-identical to RLCService and the BiBFS oracle."""
    g = erdos_renyi(n, 3.5, 3, seed=seed)
    base = RLCService.build(
        g, ServiceConfig(k=2, batch_size=8, cache_capacity=128))
    rng = np.random.default_rng(seed + 1)
    mrs = enumerate_mrs(3, 2)
    queries = [(int(rng.integers(n)), int(rng.integers(n)),
                mrs[int(rng.integers(len(mrs)))]) for _ in range(100)]
    want = base.query_batch(queries)
    oracle = [bibfs_rlc(g, s, t, L) for s, t, L in queries]
    assert want == oracle
    for num_shards in (1, 2, 4):
        for num_replicas in (1, 2):
            svc = ShardedRLCService.build(
                g, ShardedServiceConfig(
                    k=2, batch_size=8, cache_capacity=128,
                    num_shards=num_shards, num_replicas=num_replicas),
                index=base.index)
            got = svc.query_batch(queries)
            assert got == want, (num_shards, num_replicas)
            # replay through the warm cache: still identical
            assert svc.query_batch(queries) == want


def test_sharded_exercises_cross_shard_paths():
    g = erdos_renyi(60, 4.0, 3, seed=21)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, batch_size=8, cache_capacity=0,
                                num_shards=4, num_replicas=2))
    rng = np.random.default_rng(22)
    mrs = enumerate_mrs(3, 2)
    queries = [(int(rng.integers(60)), int(rng.integers(60)),
                mrs[int(rng.integers(len(mrs)))]) for _ in range(160)]
    got = svc.query_batch(queries)
    assert got == [bibfs_rlc(g, s, t, L) for s, t, L in queries]
    st_ = svc.stats()
    assert st_["router"]["remote"] > 0 and st_["router"]["local"] > 0
    ex = st_["executor"]
    assert ex["remote"]["queries"] >= st_["router"]["remote"] or \
        ex["remote"]["batches"] > 0
    assert ex["remote_joins_device"] + ex["remote_joins_numpy"] > 0
    assert ex["digest_bytes"] > 0


def test_sharded_accepts_string_constraints_and_rejects_bad_input():
    g = erdos_renyi(30, 3.0, 2, seed=12)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=2))
    base = RLCService.build(g, ServiceConfig(k=2), index=svc.index)
    assert svc.query(0, 17, "(0 1)+") == base.query(0, 17, "(0 1)+")
    with pytest.raises(ValueError):
        svc.query(0, 99, "(0)+")


# ------------------------------------------------------------------ #
# Replica hot-swap
# ------------------------------------------------------------------ #
def test_hot_swap_mid_stream():
    """Serve -> swap in an index for a denser graph -> keep serving: the
    stream's answers flip to the new graph's truth, the cache never leaks
    stale answers, every shard reports the new generation."""
    n = 50
    g1 = erdos_renyi(n, 2.0, 3, seed=31)
    g2 = erdos_renyi(n, 5.0, 3, seed=32)
    svc = ShardedRLCService.build(
        g1, ShardedServiceConfig(k=2, batch_size=8, cache_capacity=256,
                                 num_shards=4, num_replicas=2))
    rng = np.random.default_rng(33)
    mrs = enumerate_mrs(3, 2)
    queries = [(int(rng.integers(n)), int(rng.integers(n)),
                mrs[int(rng.integers(len(mrs)))]) for _ in range(80)]
    want1 = [bibfs_rlc(g1, s, t, L) for s, t, L in queries]
    want2 = [bibfs_rlc(g2, s, t, L) for s, t, L in queries]
    assert want1 != want2   # the swap must be observable
    assert svc.query_batch(queries) == want1
    gen = svc.hot_swap(graph=g2)
    assert gen == 1
    assert svc.query_batch(queries) == want2    # cache was invalidated
    st_ = svc.stats()
    assert st_["index"]["generation"] == 1
    for sh in st_["shards"]:
        assert sh["generation"] == 1 and sh["swaps"] == 1


def test_hot_swap_noop_refresh_keeps_answers():
    g = erdos_renyi(40, 3.0, 3, seed=41)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=2, num_replicas=2))
    rng = np.random.default_rng(42)
    mrs = enumerate_mrs(3, 2)
    queries = [(int(rng.integers(40)), int(rng.integers(40)),
                mrs[int(rng.integers(len(mrs)))]) for _ in range(60)]
    before = svc.query_batch(queries)
    assert svc.hot_swap() == 1          # re-freeze of the same index
    assert svc.query_batch(queries) == before


def test_hot_swap_rejects_mismatched_graph():
    g = erdos_renyi(40, 3.0, 3, seed=51)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=2))
    with pytest.raises(ValueError):
        svc.hot_swap(graph=erdos_renyi(41, 3.0, 3, seed=52))
    with pytest.raises(ValueError):
        svc.hot_swap(index=build_rlc_index(g, 1))   # k mismatch


def test_replicas_share_windowed_device_layout():
    """Per-shard device arrays cover only the shard's row window (memory
    really shrinks ~1/S) and a shard's replicas share one immutable
    layout object instead of re-packing it per replica."""
    g = erdos_renyi(60, 3.0, 3, seed=91)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, num_shards=4, num_replicas=2))
    for rs in svc.shards:
        r0, r1 = rs.replicas
        if r0.device_index is None:
            continue    # degraded mode on this host
        assert r0.device_index is r1.device_index
        assert r0.device_index.out_hub.shape[0] == rs.hi - rs.lo
        assert r0.device_index.row_lo == rs.lo
    gen_layouts = [rs.replicas[0].device_index for rs in svc.shards]
    svc.hot_swap()
    for rs, old in zip(svc.shards, gen_layouts):
        r0, r1 = rs.replicas
        if r0.device_index is None:
            continue
        assert r0.device_index is r1.device_index   # still shared ...
        assert r0.device_index is not old           # ... but rebuilt


@pytest.mark.slow
def test_sharded_agreement_heavy_sweep():
    """Paper-scale-ish sweep (deselected by default; run `pytest -m slow`):
    8-way sharding on a 400-vertex hub-skewed graph, swap under a longer
    stream."""
    n = 400
    g = barabasi_albert(n, 3, 4, seed=71)
    base = RLCService.build(
        g, ServiceConfig(k=2, batch_size=32, cache_capacity=1024))
    rng = np.random.default_rng(72)
    mrs = enumerate_mrs(4, 2)
    queries = [(int(rng.integers(n)), int(rng.integers(n)),
                mrs[int(rng.integers(len(mrs)))]) for _ in range(600)]
    want = base.query_batch(queries)
    for num_shards in (2, 8):
        svc = ShardedRLCService.build(
            g, ShardedServiceConfig(k=2, batch_size=32, cache_capacity=1024,
                                    num_shards=num_shards, num_replicas=2),
            index=base.index)
        assert svc.query_batch(queries) == want
        g2 = erdos_renyi(n, 4.0, 4, seed=73)
        svc.hot_swap(graph=g2)
        assert svc.query_batch(queries[:200]) == \
            [bibfs_rlc(g2, s, t, L) for s, t, L in queries[:200]]


# ------------------------------------------------------------------ #
# Stats surface
# ------------------------------------------------------------------ #
def test_sharded_stats_per_shard_breakdown():
    g = erdos_renyi(60, 3.0, 3, seed=61)
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=2, batch_size=8, num_shards=4,
                                num_replicas=2))
    rng = np.random.default_rng(62)
    mrs = enumerate_mrs(3, 2)
    svc.query_batch([(int(rng.integers(60)), int(rng.integers(60)),
                      mrs[int(rng.integers(len(mrs)))]) for _ in range(40)])
    st_ = svc.stats()
    assert 0.0 <= st_["cache"]["hit_rate"] <= 1.0
    shards = st_["shards"]
    assert len(shards) == 4
    assert sum(sh["entries"] for sh in shards) == st_["index"]["entries"]
    for sh in shards:
        assert sh["size_bytes"] > 0 and sh["replicas"] == 2
        assert sh["hi"] > sh["lo"]
    # nested executor shape: latencies and traffic live together
    assert set(st_["executor"]) >= {"local", "remote", "sub_batches",
                                    "digest_bytes"}
