"""Serving control plane: SLO-aware batching, admission control +
shedding, prioritized cache warming (:mod:`repro.service.control`).

The overload tests drive the service open-loop through a
:class:`VirtualClock`: the test advances the clock to each arrival's
stamp while the service advances it by measured execute time, so queue
waits accumulate exactly as they would in an open-loop server at an
offered load above capacity — deterministic overload without threads.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.baselines import bibfs_rlc
from repro.core.queries import biased_true_queries
from repro.graphgen import erdos_renyi
from repro.graphgen.generators import random_delta
from repro.obs import MetricsRegistry
from repro.service import (SHED, AdmissionController, CacheWarmer,
                           FrequencySketch, MicroBatcher, ResultCache,
                           RLCService, ServiceConfig, ShardedRLCService,
                           ShardedServiceConfig, SLOBatchController,
                           VirtualClock)


def _graph(n=100, seed=7):
    return erdos_renyi(n, 3.5, 3, seed=seed)


def _pool(g, k=2, n=24, seed=3):
    qs = biased_true_queries(g, k, n=n, seed=seed)
    return qs.true_queries + qs.false_queries


# --------------------------------------------------------------------- #
# SHED sentinel
# --------------------------------------------------------------------- #
def test_shed_is_not_a_boolean():
    assert repr(SHED) == "SHED"
    with pytest.raises(TypeError):
        bool(SHED)
    assert SHED is SHED


# --------------------------------------------------------------------- #
# VirtualClock
# --------------------------------------------------------------------- #
def test_virtual_clock_monotone():
    c = VirtualClock()
    assert c() == 0.0
    c.advance(1.5)
    c.advance(-3.0)         # negative advances are ignored
    assert c() == 1.5
    c.at_least(1.0)         # never goes backwards
    assert c() == 1.5
    c.at_least(4.0)
    assert c() == 4.0


# --------------------------------------------------------------------- #
# FrequencySketch
# --------------------------------------------------------------------- #
def test_sketch_estimates_and_hot_set():
    sk = FrequencySketch(width=512, depth=4, hot_capacity=4,
                         decay_every=10 ** 9)
    for _ in range(50):
        sk.observe((1, 2, 0), mr_len=1)
    for _ in range(10):
        sk.observe((3, 4, 0), mr_len=2)
    sk.observe((5, 6, 1), mr_len=3)
    assert sk.estimate((1, 2, 0)) >= 50      # count-min overestimates only
    assert sk.estimate((3, 4, 0)) >= 10
    assert sk.estimate((9, 9, 9)) < 50       # cold key stays (near) zero
    hot = sk.hot(2)
    assert hot[0][2] == (1, 2, 0)
    assert hot[1][2] == (3, 4, 0)


def test_sketch_decay_halves_counts():
    sk = FrequencySketch(width=256, depth=2, decay_every=10 ** 9)
    for _ in range(40):
        sk.observe((7, 8, 0))
    before = sk.estimate((7, 8, 0))
    sk.decay()
    assert sk.estimate((7, 8, 0)) == before // 2
    assert sk.decays == 1


def test_sketch_hot_capacity_bounded():
    sk = FrequencySketch(hot_capacity=8, decay_every=10 ** 9)
    for i in range(100):
        for _ in range(i % 5 + 1):
            sk.observe((i, i, 0), mr_len=1)
    assert len(sk.hot()) <= 8


# --------------------------------------------------------------------- #
# SLO controller
# --------------------------------------------------------------------- #
def test_slo_controller_converges_on_bimodal_workload():
    """Synthetic bimodal workload: MR length 1 is cheap (0.1ms/batch),
    MR length 3 is expensive (8ms/batch, past the shrink threshold of a
    10ms SLO). The controller must grow the cheap bucket's batches (its
    fill says demand exists) and shrink the expensive bucket's, and give
    the expensive bucket a tighter deadline."""
    clock = VirtualClock()
    ctl = SLOBatchController(MetricsRegistry(), target_p99_s=0.010,
                            base_batch=8, base_wait_s=0.002,
                            max_batch=64, interval_s=0.0, clock=clock)
    for _ in range(60):
        clock.advance(0.001)
        # saturating demand: the cheap bucket always flushes full at its
        # current size, the expensive one stays expensive per batch
        ctl.observe_batch(1, n_real=ctl.params(1)[0], exec_s=0.0001)
        ctl.observe_batch(3, n_real=ctl.params(3)[0], exec_s=0.008)
    cheap_b, cheap_w = ctl.params(1)
    exp_b, exp_w = ctl.params(3)
    assert cheap_b == 64, "cheap bucket should grow to max_batch"
    assert exp_b == 1, "expensive bucket should shrink to min_batch"
    assert exp_w < cheap_w, "expensive bucket gets the tighter deadline"
    assert cheap_w <= 0.005      # never above target/2
    st = ctl.stats()
    assert st["updates"] > 0
    assert st["batch_size"][1] == 64 and st["batch_size"][3] == 1


def test_slo_controller_steers_the_scheduler():
    """The batcher consults the controller per bucket: a grown batch
    size changes the full-flush threshold."""
    clock = VirtualClock()
    ctl = SLOBatchController(MetricsRegistry(), target_p99_s=0.010,
                            base_batch=2, base_wait_s=1.0,
                            max_batch=8, interval_s=0.0, clock=clock)
    b = MicroBatcher(2, 1.0, clock=clock, params_fn=ctl.params)
    # before any feedback: flushes at the base size of 2
    _, ready = b.submit(0, 1, 0, 1)
    _, ready = b.submit(2, 3, 0, 1)
    assert len(ready) == 1 and ready[0].n_real == 2
    # cheap + full feedback grows the bucket to 4
    for _ in range(10):
        clock.advance(0.001)
        ctl.observe_batch(1, n_real=2, exec_s=0.0001)
    grown, _w = ctl.params(1)
    assert grown > 2
    for i in range(grown - 1):
        _, ready = b.submit(10 + i, 1, 0, 1)
        assert ready == []
    _, ready = b.submit(50, 1, 0, 1)
    assert len(ready) == 1 and ready[0].n_real == grown


def test_slo_controller_rejects_bad_target():
    with pytest.raises(ValueError):
        SLOBatchController(MetricsRegistry(), target_p99_s=0.0,
                           base_batch=8, base_wait_s=0.002)


# --------------------------------------------------------------------- #
# scheduler: no padding, eviction, priority scans
# --------------------------------------------------------------------- #
def test_flush_carries_real_slots_only_and_padding_ratio_is_zero():
    reg = MetricsRegistry()

    class Obs:
        registry = reg
    clock = [0.0]
    b = MicroBatcher(8, 0.5, clock=lambda: clock[0], obs=Obs())
    b.submit(0, 1, 0, 1)
    b.submit(2, 3, 0, 1)
    clock[0] = 1.0
    ready = b.poll()
    assert len(ready) == 1
    assert len(ready[0].s) == 2 == ready[0].n_real
    assert ready[0].n_padding == 0
    m = reg.get("rlc_batcher_padding_ratio")
    (_key, cell), = m.series()
    assert cell.reservoir.count == 1 and cell.reservoir.vmax == 0.0


def test_evict_removes_queued_request():
    b = MicroBatcher(8, 100.0, clock=lambda: 0.0)
    r1, _ = b.submit(0, 1, 0, 1)
    r2, _ = b.submit(2, 3, 0, 1)
    assert b.evict(r1) is True
    assert b.pending() == 1
    assert not b.is_inflight((0, 1, 0))
    assert b.evict(r1) is False          # already gone
    ready = b.drain()
    assert [r.req_id for r in ready[0].requests] == [r2.req_id]


def test_priority_scans():
    b = MicroBatcher(8, 100.0, clock=lambda: 0.0)
    assert b.lowest_priority_pending(lambda r: r.s) is None
    assert b.median_pending_priority(lambda r: r.s) is None
    for s in (5, 1, 9):
        b.submit(s, 0, 0, 1)
    worst = b.lowest_priority_pending(lambda r: r.s)
    assert worst.s == 1
    assert b.median_pending_priority(lambda r: r.s) == 5


# --------------------------------------------------------------------- #
# admission controller (unit)
# --------------------------------------------------------------------- #
def _sketch_with(keys):
    sk = FrequencySketch(decay_every=10 ** 9)
    for key, count, mr_len in keys:
        for _ in range(count):
            sk.observe(key, mr_len)
    return sk


def test_admission_hard_bound_sheds_coldest_deepest():
    hot, cold = (1, 1, 0), (2, 2, 1)
    sk = _sketch_with([(hot, 50, 1), (cold, 1, 3)])
    adm = AdmissionController(MetricsRegistry(), sk, max_pending=1)
    b = MicroBatcher(64, 100.0, clock=lambda: 0.0)
    assert adm.decide(cold, 3, b)[0] == "admit"
    b.submit(*cold, 3)
    # queue full; the hot short arrival evicts the cold deep victim
    decision, victim = adm.decide(hot, 1, b)
    assert decision == "evict" and victim.key == cold
    b.evict(victim)
    b.submit(*hot, 1)
    # queue full again; a second cold arrival is shed outright
    decision, victim = adm.decide(cold, 3, b)
    assert decision == "shed" and victim is None
    # two requests were shed in total: the evicted victim + this arrival
    assert adm.stats()["shed"] == 2


def test_admission_backpressure_sheds_low_priority_and_recovers():
    hot, cold = (1, 1, 0), (2, 2, 1)
    sk = _sketch_with([(hot, 50, 1), (cold, 1, 3)])
    adm = AdmissionController(MetricsRegistry(), sk,
                              backpressure_s=0.010)
    b = MicroBatcher(64, 100.0, clock=lambda: 0.0)
    b.submit(*hot, 1)
    b.submit(*cold, 3)
    assert not adm.backpressured
    for _ in range(20):
        adm.observe_wait(0.050)          # queue waits blow past 10ms
    assert adm.backpressured
    assert adm.decide(cold, 3, b)[0] == "shed"
    assert adm.decide(hot, 1, b)[0] == "admit"   # hot short still flows
    for _ in range(50):
        adm.observe_wait(0.0001)         # backlog drained
    assert not adm.backpressured
    assert adm.decide(cold, 3, b)[0] == "admit"  # shedding recovered


# --------------------------------------------------------------------- #
# service-level overload: shed under 2x capacity, recover after
# --------------------------------------------------------------------- #
def _overloaded_service(g, clock, **cfg):
    return RLCService.build(g, ServiceConfig(
        k=2, batch_size=8, max_wait_ms=2.0, backend="numpy",
        use_device=False, cache_capacity=0, clock=clock, **cfg))


def test_service_sheds_under_injected_overload_and_recovers():
    g = _graph()
    pool = _pool(g)
    clock = VirtualClock()
    svc = _overloaded_service(g, clock, admission_max_pending=4,
                              admission_backpressure_ms=1.0)
    # capacity run: arrivals spaced far apart -> zero shed
    for s, t, c in pool[:12]:
        clock.advance(1.0)
        assert svc.query_batch([(s, t, c)])[0] is not SHED
    assert svc.queries_shed == 0
    # overload: all arrivals at one instant, far past max_pending — the
    # hard bound must shed the overflow with the explicit sentinel
    ans = svc.query_batch(pool)
    shed = [a for a in ans if a is SHED]
    assert shed, "hard admission bound never shed under 6x pending"
    assert svc.queries_shed == len(shed)
    assert svc.stats()["control"]["admission"]["shed"] >= len(shed)
    # non-shed answers stay bit-identical to the oracle
    for (s, t, c), a in zip(pool, ans):
        if a is not SHED:
            assert bool(a) == bibfs_rlc(g, s, t, svc.parse(c).mr)
    # recovery: spaced arrivals again -> no further shedding
    before = svc.queries_shed
    for s, t, c in pool[:12]:
        clock.advance(1.0)
        svc.query_batch([(s, t, c)])
    assert svc.queries_shed == before


def test_no_shedding_at_offered_load_below_capacity():
    g = _graph()
    pool = _pool(g)
    clock = VirtualClock()
    svc = _overloaded_service(g, clock, target_p99_ms=50.0,
                              admission_max_pending=256)
    for chunk in range(0, len(pool), 8):
        clock.advance(1.0)               # arrivals well under capacity
        ans = svc.query_batch(pool[chunk:chunk + 8])
        assert all(a is not SHED for a in ans)
    assert svc.queries_shed == 0


# --------------------------------------------------------------------- #
# cache warmer
# --------------------------------------------------------------------- #
def _warmer(cache, sk, budget_bytes=1 << 20, budget_s=10.0, chunk=4,
            fail_epoch=None):
    calls = []

    def execute(s, t, mr, mr_len):
        calls.append(len(s))
        return np.ones(len(s), dtype=bool)

    w = CacheWarmer(cache, sk, execute, budget_bytes=budget_bytes,
                    budget_s=budget_s, chunk=chunk)
    return w, calls


def test_warmer_fills_hot_uncached_keys():
    cache = ResultCache(64)
    sk = _sketch_with([((1, 2, 0), 30, 1), ((3, 4, 0), 20, 1),
                       ((5, 6, 1), 10, 2)])
    cache.put((1, 2, 0), True, mr_len=1)     # hottest already cached
    w, calls = _warmer(cache, sk)
    rep = w.warm("manual")
    assert rep["warmed"] == 2
    assert cache.peek((3, 4, 0)) is True
    assert cache.peek((5, 6, 1)) is True
    assert rep["stale"] == 0


def test_warmer_respects_byte_budget():
    cache = ResultCache(1024)
    sk = FrequencySketch(hot_capacity=64, decay_every=10 ** 9)
    for i in range(32):
        for _ in range(2):
            sk.observe((i, i + 1, 0), 1)
    budget_keys = 5
    w, calls = _warmer(cache, sk,
                       budget_bytes=budget_keys * CacheWarmer.ENTRY_BYTES)
    rep = w.warm("manual")
    assert rep["warmed"] <= budget_keys
    assert rep["bytes"] <= budget_keys * CacheWarmer.ENTRY_BYTES
    assert rep["skipped_budget"] >= 32 - budget_keys
    assert len(cache) == rep["warmed"]


def test_warmer_epoch_fenced_mid_pass():
    """A mutation landing while a warm chunk executes must abort the
    pass: answers computed against the dead index never enter the
    cache (mirrors the shadow verifier's discard-on-mutation fencing)."""
    cache = ResultCache(1024)
    sk = FrequencySketch(hot_capacity=64, decay_every=10 ** 9)
    for i in range(12):
        sk.observe((i, i + 1, 0), 1)
    w = None

    def execute(s, t, mr, mr_len):
        w.bump_epoch()                    # delta lands mid-execute
        return np.ones(len(s), dtype=bool)

    w = CacheWarmer(cache, sk, execute, budget_bytes=1 << 20,
                    budget_s=10.0, chunk=4)
    rep = w.warm("apply_delta")
    assert rep["warmed"] == 0
    assert rep["stale"] > 0
    assert len(cache) == 0


def test_service_warm_after_apply_delta_is_epoch_consistent():
    """End-to-end: warming runs after apply_delta against the *new*
    index; every warmed answer matches the post-delta oracle."""
    g = _graph(80, seed=11)
    svc = RLCService.build(g, ServiceConfig(
        k=2, batch_size=8, backend="numpy", use_device=False,
        cache_capacity=256, warm_capacity=64))
    pool = _pool(g, n=16, seed=5)
    for _ in range(3):
        svc.query_batch(pool)            # populate the sketch
    delta = random_delta(svc.graph, 2, 2, np.random.default_rng(0))
    rep = svc.apply_delta(delta)
    assert rep["warm"] is not None and rep["warm"]["trigger"] == "apply_delta"
    assert rep["warm"]["stale"] == 0
    g2 = svc.graph
    for key in list(svc.cache._d):
        s, t, mr_id = key
        val = svc.cache.peek(key)
        assert val == bibfs_rlc(g2, s, t, svc._id_to_mr[mr_id])


def test_sharded_warm_after_hot_swap_raises_early_hit_rate():
    """The acceptance-shaped check: after hot_swap (cache cleared), the
    warmed service hits on early queries where the unwarmed one cold
    misses."""
    g = _graph(100, seed=13)
    pool = _pool(g, n=20, seed=9)
    rng = np.random.default_rng(2)
    zipf = rng.choice(len(pool), size=300,
                      p=(lambda w: w / w.sum())(
                          1.0 / np.arange(1, len(pool) + 1)))
    stream = [pool[i] for i in zipf]
    rates = {}
    for warm_capacity in (0, 128):
        svc = ShardedRLCService.build(g, ShardedServiceConfig(
            k=2, num_shards=2, num_replicas=1, use_device=False,
            batch_size=8, cache_capacity=1024,
            warm_capacity=warm_capacity))
        svc.query_batch(stream)          # populate sketch + cache
        svc.hot_swap()                   # clears the cache; warms if on
        pre = svc.cache.stats.hits
        svc.query_batch(stream[:100])
        rates[warm_capacity] = svc.cache.stats.hits - pre
    assert rates[128] > rates[0], (
        f"warmed first-100 hits {rates[128]} <= unwarmed {rates[0]}")


# --------------------------------------------------------------------- #
# mid-swap BiBFS degradation
# --------------------------------------------------------------------- #
def test_fanout_degrades_to_bibfs_mid_swap():
    g = _graph(90, seed=17)
    pool = _pool(g, n=12, seed=4)
    svc = ShardedRLCService.build(g, ShardedServiceConfig(
        k=2, num_shards=2, num_replicas=1, use_device=False,
        batch_size=8, cache_capacity=0))
    expected = [bool(a) for a in svc.query_batch(pool)]
    # pin one replica set mid-swap: every sub-batch touching it must
    # take the online-BiBFS path and still answer exactly
    svc.shards[0].swapping = True
    try:
        degraded = svc.query_batch(pool)
    finally:
        svc.shards[0].swapping = False
    assert [bool(a) for a in degraded] == expected
    assert svc.fanout.degraded > 0
    reg = svc.obs.registry
    m = reg.get("rlc_fanout_degraded")
    (_key, cell), = m.series()
    assert cell.value == svc.fanout.degraded
    # swap done: back to the indexed path, no further degradation
    n = svc.fanout.degraded
    svc.query_batch(pool)
    assert svc.fanout.degraded == n


# --------------------------------------------------------------------- #
# cache breakdowns
# --------------------------------------------------------------------- #
def test_cache_hit_rate_excludes_expired_and_breaks_down_by_mr_len():
    clock = [0.0]
    c = ResultCache(8, ttl_s=1.0, clock=lambda: clock[0])
    c.put((1, 1, 0), True, mr_len=1)
    assert c.get((1, 1, 0), mr_len=1) is True        # hit
    assert c.get((2, 2, 0), mr_len=2) is None        # miss
    clock[0] = 2.0
    assert c.get((1, 1, 0), mr_len=1) is None        # expired, not a miss
    assert c.stats.hits == 1
    assert c.stats.misses == 1
    assert c.stats.expirations == 1
    assert c.stats.lookups == 3
    assert c.stats.hit_rate == pytest.approx(1 / 3)
    by_len = c.hit_rate_by_mr_len()
    assert by_len[1] == pytest.approx(0.5)           # 1 hit, 1 expired
    assert by_len[2] == 0.0
    assert c.stats.as_dict()["hit_rate_by_mr_len"] == by_len


def test_cache_eviction_age_tracked():
    clock = [0.0]
    c = ResultCache(2, clock=lambda: clock[0])
    c.put((1, 1, 0), True)
    clock[0] = 5.0
    c.put((2, 2, 0), True)
    c.put((3, 3, 0), True)              # evicts key 1, aged 5s
    assert c.stats.evictions == 1
    summ = c.eviction_age_summary()
    assert summ["count"] == 1
    assert summ["max"] == pytest.approx(5.0)


def test_cache_mr_lookup_series():
    reg = MetricsRegistry()

    class Obs:
        registry = reg
    c = ResultCache(8, obs=Obs())
    c.put((1, 1, 0), True, mr_len=2)
    c.get((1, 1, 0), mr_len=2)
    c.get((9, 9, 0), mr_len=3)
    m = reg.get("rlc_cache_mr_lookups")
    assert m.value(outcome="hit", mr_len=2) == 1
    assert m.value(outcome="miss", mr_len=3) == 1
