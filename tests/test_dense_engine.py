"""Dense semiring engine + device builder + batched query engine vs the
faithful reference (paper semantics)."""
import numpy as np
import pytest

from repro.core.baselines import ETC, bfs_rlc
from repro.core.dense import DenseEngine, build_condensed_device
from repro.core.device_index import DeviceIndex
from repro.core.index_builder import build_rlc_index
from repro.core.minimum_repeat import enumerate_mrs, mr_id_space
from repro.graphgen import fig2_graph, random_labeled_graph


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("k", [1, 2])
def test_dense_engine_equals_etc(seed, k):
    g = random_labeled_graph(num_vertices=12, num_edges=36, num_labels=3,
                             seed=seed, self_loop_frac=0.1)
    eng = DenseEngine.build(g, k)
    etc = ETC(g, k)
    for u in range(g.num_vertices):
        for v in range(g.num_vertices):
            assert eng.s_k(u, v) == etc.s_k(u, v), (u, v)


def test_dense_engine_fig2():
    g, names = fig2_graph()
    eng = DenseEngine.build(g, 2)
    assert eng.query(names["v3"], names["v6"], (1, 0))
    assert not eng.query(names["v1"], names["v3"], (0,))


@pytest.mark.parametrize("hub_batch", [1, 4])
@pytest.mark.parametrize("seed", range(3))
def test_device_builder_sound_complete(seed, hub_batch):
    g = random_labeled_graph(num_vertices=12, num_edges=34, num_labels=2,
                             seed=seed, self_loop_frac=0.15)
    k = 2
    idx, eng = build_condensed_device(g, k, hub_batch=hub_batch)
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L in enumerate_mrs(2, k):
                want = bfs_rlc(g, s, t, L)
                assert idx.query(s, t, L) == want, (s, t, L)


def test_device_builder_b1_condensed_and_small():
    g = random_labeled_graph(num_vertices=10, num_edges=26, num_labels=2,
                             seed=1)
    k = 2
    dev_idx, _ = build_condensed_device(g, k, hub_batch=1)
    ref_idx = build_rlc_index(g, k)
    # B=1 device schedule prunes sequentially => condensed (Definition 5)
    assert dev_idx.is_condensed()
    # batched build should not blow up entry counts vs the reference
    b4_idx, _ = build_condensed_device(g, k, hub_batch=4)
    assert dev_idx.num_entries() <= b4_idx.num_entries() * 2 + 8
    assert dev_idx.num_entries() <= ref_idx.num_entries() * 3 + 8


@pytest.mark.parametrize("method", ["dense", "sorted"])
@pytest.mark.parametrize("seed", range(3))
def test_device_index_batched_query(seed, method):
    g = random_labeled_graph(num_vertices=13, num_edges=40, num_labels=3,
                             seed=seed)
    k = 2
    idx = build_rlc_index(g, k)
    dev = DeviceIndex.from_index(idx, g.num_labels)
    ids = mr_id_space(g.num_labels, k)
    qs, qt, qm, want = [], [], [], []
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L, c in ids.items():
                qs.append(s)
                qt.append(t)
                qm.append(c)
                want.append(idx.query(s, t, L))
    got = dev.query_batch(np.array(qs), np.array(qt), np.array(qm),
                          method=method)
    assert got.tolist() == want
