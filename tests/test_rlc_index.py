"""Soundness + completeness of the RLC index (Theorems 2-3) against the
product-automaton oracle and ETC, across random graph families."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.baselines import ETC, NFA, bfs_nfa, bfs_rlc, bibfs_rlc
from repro.core.graph import LabeledGraph
from repro.core.index_builder import (build_rlc_index,
                                      build_rlc_index_with_stats)
from repro.core.minimum_repeat import enumerate_mrs, mr_id_space
from repro.graphgen import (barabasi_albert, erdos_renyi, fig1_graph,
                            fig2_graph, random_labeled_graph)


def exhaustive_check(g, k, idx=None, etc=None):
    """Assert index answers == oracle for ALL (s, t, MR<=k) triples."""
    idx = idx if idx is not None else build_rlc_index(g, k)
    mrs = enumerate_mrs(g.num_labels, k)
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L in mrs:
                want = bfs_rlc(g, s, t, L)
                got = idx.query(s, t, L)
                assert got == want, (
                    f"mismatch s={s} t={t} L={L}: index={got} oracle={want}")
                if etc is not None:
                    assert etc.query(s, t, L) == want
    return idx


# ------------------------------------------------------------------ #
# Paper illustration graphs
# ------------------------------------------------------------------ #
def test_fig2_running_example():
    g, names = fig2_graph()
    idx = build_rlc_index(g, k=2)
    v = lambda s: names[s]
    l1, l2 = 0, 1
    # Example 4 queries
    assert idx.query(v("v3"), v("v6"), (l2, l1)) is True   # Q1
    assert idx.query(v("v1"), v("v2"), (l2, l1)) is True   # Q2
    assert idx.query(v("v1"), v("v3"), (l1,)) is False     # Q3
    exhaustive_check(g, 2, idx=idx)


def test_fig2_condensed():
    g, _ = fig2_graph()
    idx = build_rlc_index(g, k=2)
    assert idx.is_condensed()  # Theorem 2


def test_fig1_motivating_queries():
    g, names, labels = fig1_graph()
    idx = build_rlc_index(g, k=3)
    D, C, K, W = (labels[x] for x in
                  ("debits", "credits", "knows", "worksFor"))
    # Q1(A14, A19, (debits, credits)+) = true (Example 1)
    assert idx.query(names["A14"], names["A19"], (D, C)) is True
    # Q2(P10, P13, (knows, knows, worksFor)+) = false
    assert idx.query(names["P10"], names["P13"], (K, K, W)) is False
    exhaustive_check(g, 2, idx=build_rlc_index(g, k=2))


# ------------------------------------------------------------------ #
# Random graph sweeps (exhaustive oracle comparison)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("k", [1, 2, 3])
def test_random_graphs_sound_complete(seed, k):
    g = random_labeled_graph(num_vertices=14, num_edges=40, num_labels=3,
                             seed=seed, self_loop_frac=0.1)
    etc = ETC(g, k)
    exhaustive_check(g, k, etc=etc)


@pytest.mark.parametrize("seed", range(4))
def test_er_graphs(seed):
    g = erdos_renyi(num_vertices=20, avg_degree=2.5, num_labels=3, seed=seed)
    exhaustive_check(g, 2)


@pytest.mark.parametrize("seed", range(3))
def test_ba_graphs(seed):
    g = barabasi_albert(num_vertices=16, m_attach=2, num_labels=3, seed=seed)
    exhaustive_check(g, 2)


def test_dense_cyclic_graph():
    # dense + many self loops: the hardest regime (paper SO/WF graphs)
    g = random_labeled_graph(num_vertices=8, num_edges=60, num_labels=2,
                             seed=7, self_loop_frac=0.3)
    etc = ETC(g, 3)
    exhaustive_check(g, 3, etc=etc)


def test_single_vertex_self_loops():
    g = LabeledGraph.from_edges(1, 2, np.array([[0, 0, 0], [0, 1, 0]]))
    idx = build_rlc_index(g, 2)
    assert idx.query(0, 0, (0,))
    assert idx.query(0, 0, (1,))
    assert idx.query(0, 0, (0, 1))  # alternate loops: (0,1)^+ realizable
    exhaustive_check(g, 2, idx=idx)


def test_empty_and_edgeless_graph():
    g = LabeledGraph.from_edges(3, 2, np.zeros((0, 3)))
    idx = build_rlc_index(g, 2)
    assert not idx.query(0, 1, (0,))
    assert idx.num_entries() == 0


# ------------------------------------------------------------------ #
# Pruning rules: condensedness + ablations stay correct
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", range(4))
def test_condensed_property(seed):
    g = random_labeled_graph(num_vertices=12, num_edges=34, num_labels=3,
                             seed=seed)
    idx = build_rlc_index(g, 2)
    assert idx.is_condensed()


def test_pruning_reduces_entries_but_not_answers():
    g = random_labeled_graph(num_vertices=14, num_edges=50, num_labels=2,
                             seed=3, self_loop_frac=0.15)
    full, s_full = build_rlc_index_with_stats(g, 2)
    nopr, s_nopr = build_rlc_index_with_stats(
        g, 2, use_pr1=False, use_pr2=False, use_pr3=False)
    assert full.num_entries() <= nopr.num_entries()
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L in enumerate_mrs(2, 2):
                assert full.query(s, t, L) == nopr.query(s, t, L)
    exhaustive_check(g, 2, idx=full)


@pytest.mark.parametrize("flags", [
    dict(use_pr1=False), dict(use_pr2=False), dict(use_pr3=False),
    dict(use_pr1=False, use_pr3=False)])
def test_pruning_ablations_sound_complete(flags):
    g = random_labeled_graph(num_vertices=12, num_edges=40, num_labels=2,
                             seed=11, self_loop_frac=0.2)
    idx = build_rlc_index(g, 2, **flags)
    exhaustive_check(g, 2, idx=idx)


# ------------------------------------------------------------------ #
# Frozen (merge-join) layout
# ------------------------------------------------------------------ #
def test_frozen_index_matches_dict_index():
    g = random_labeled_graph(num_vertices=15, num_edges=45, num_labels=3,
                             seed=5)
    k = 2
    idx = build_rlc_index(g, k)
    ids = mr_id_space(g.num_labels, k)
    frozen = idx.freeze(ids)
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            for L, mid in ids.items():
                assert frozen.query(s, t, mid) == idx.query(s, t, L)


# ------------------------------------------------------------------ #
# Baselines agree with each other (BiBFS == BFS == NFA-BFS)
# ------------------------------------------------------------------ #
@given(st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_bibfs_matches_bfs(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(num_vertices=10, num_edges=26, num_labels=2,
                             seed=seed, self_loop_frac=0.1)
    s = int(rng.integers(10))
    t = int(rng.integers(10))
    for L in [(0,), (1,), (0, 1), (1, 0)]:
        want = bfs_rlc(g, s, t, L)
        assert bibfs_rlc(g, s, t, L) == want
        nfa = NFA.from_plus_blocks([L])
        assert bfs_nfa(g, s, t, nfa) == want


def test_nfa_extended_query_q4():
    # Q4 = a+ ∘ b+ on a tiny chain: 0 -a-> 1 -a-> 2 -b-> 3
    g = LabeledGraph.from_edges(4, 2, np.array(
        [[0, 0, 1], [1, 0, 2], [2, 1, 3]]))
    nfa = NFA.from_plus_blocks([(0,), (1,)])
    assert bfs_nfa(g, 0, 3, nfa) is True       # a a b
    assert bfs_nfa(g, 0, 2, nfa) is False      # a a  (no b block)
    assert bfs_nfa(g, 2, 3, nfa) is False      # b alone (no a block)


# ------------------------------------------------------------------ #
# ETC equals ground-truth S^k
# ------------------------------------------------------------------ #
def test_etc_sk_definition():
    g, _ = fig2_graph()
    etc = ETC(g, 2)
    # S^2(P12,P16) analogue on fig2: check a couple of concrete sets
    # against per-query oracle for every pair.
    for s in range(g.num_vertices):
        for t in range(g.num_vertices):
            sk = etc.s_k(s, t)
            for L in enumerate_mrs(g.num_labels, 2):
                assert (L in sk) == bfs_rlc(g, s, t, L)
