"""Cross-engine agreement property test.

On small random labeled graphs, the dict-layout index (`RLCIndex.query`),
the frozen-numpy CSR merge-join (`FrozenRLCIndex.query_batch`), the padded
device layout in both formulations (XLA sorted-key and the dense reference)
and the full `RLCService` path must all agree with the product-automaton
BiBFS oracle on the same query set — >= 200 queries across >= 3 graphs
(ISSUE-1 acceptance)."""
import numpy as np
import pytest

from repro.core.baselines import bibfs_rlc
from repro.core.device_index import DeviceIndex
from repro.core.index_builder import build_rlc_index
from repro.core.minimum_repeat import enumerate_mrs, mr_id_space
from repro.graphgen import (barabasi_albert, erdos_renyi,
                            random_labeled_graph)
from repro.service import RLCService, ServiceConfig

GRAPHS = [
    ("er", lambda: erdos_renyi(30, 3.0, 3, seed=11)),
    ("ba", lambda: barabasi_albert(24, 2, 3, seed=12)),
    ("loopy", lambda: random_labeled_graph(20, 70, 2, seed=13,
                                           self_loop_frac=0.2)),
    ("sparse", lambda: erdos_renyi(40, 1.5, 4, seed=14)),
]
PER_GRAPH = 80  # x 4 graphs = 320 queries >= the 200-query acceptance bar


@pytest.mark.parametrize("name,make", GRAPHS, ids=[g[0] for g in GRAPHS])
def test_cross_engine_agreement(name, make):
    g = make()
    k = 2
    idx = build_rlc_index(g, k)
    ids = mr_id_space(g.num_labels, k)
    frozen = idx.freeze(ids)
    dev = DeviceIndex.from_frozen(frozen, ids)
    svc = RLCService.build(g, ServiceConfig(k=k, batch_size=16,
                                            cache_capacity=128), index=idx)

    rng = np.random.default_rng(hash(name) % 2**31)
    mrs = enumerate_mrs(g.num_labels, k)
    queries = [(int(rng.integers(g.num_vertices)),
                int(rng.integers(g.num_vertices)),
                mrs[int(rng.integers(len(mrs)))]) for _ in range(PER_GRAPH)]
    want = [bibfs_rlc(g, s, t, L) for s, t, L in queries]

    s = np.array([q[0] for q in queries], np.int32)
    t = np.array([q[1] for q in queries], np.int32)
    mid = np.array([ids[q[2]] for q in queries], np.int32)

    # 1. dict layout (Algorithm 1 over hash maps)
    got_dict = [idx.query(*q) for q in queries]
    assert got_dict == want

    # 2. frozen-numpy CSR merge join
    got_np = frozen.query_batch(s, t, mid)
    np.testing.assert_array_equal(got_np, np.asarray(want))

    # 3. device layout, sorted-key XLA formulation
    got_sorted = dev.query_batch(s, t, mid, method="sorted")
    np.testing.assert_array_equal(got_sorted, np.asarray(want))

    # 4. device layout, dense reference formulation
    got_dense = dev.query_batch(s, t, mid, method="dense")
    np.testing.assert_array_equal(got_dense, np.asarray(want))

    # 5. the full service path (cache + scheduler + executor)
    got_svc = svc.query_batch(queries)
    assert got_svc == want
