"""Unit tests for the online serving subsystem (repro.service.*):
expression parser, LRU result cache, micro-batching scheduler, multi-
backend executor and the RLCService facade."""
import numpy as np
import pytest

from repro.core.baselines import bibfs_rlc
from repro.core.index_builder import build_rlc_index
from repro.core.minimum_repeat import enumerate_mrs
from repro.core.queries import biased_true_queries
from repro.graphgen import erdos_renyi, fig1_graph
from repro.service import (BatchExecutor, ExpressionError, MicroBatcher,
                           RLCService, ResultCache, ServiceConfig,
                           parse_expression)


# ------------------------------------------------------------------ #
# Parser
# ------------------------------------------------------------------ #
def test_parse_numeric_forms():
    for text in ["(0 1)+", "( 0 1 )+", '("0 1")+', "'0 1'+", "0,1+",
                 "(0, 1)+"]:
        e = parse_expression(text, num_labels=3, k=2)
        assert e.mr == (0, 1), text


def test_parse_named_labels():
    names = {"debits": 2, "credits": 3}
    e = parse_expression("(debits credits)+", num_labels=5, k=2,
                         label_names=names)
    assert e.labels == (2, 3)
    assert e.mr == (2, 3)


def test_parse_canonicalizes_to_minimum_repeat():
    # (a b a b)+ and (a b)+ denote the same query (Lemma 1)
    e = parse_expression("(0 1 0 1)+", num_labels=2, k=2)
    assert e.labels == (0, 1, 0, 1)
    assert e.mr == (0, 1)
    # and a long power of a short MR is accepted even when |labels| > k
    e = parse_expression("(1 1 1 1 1)+", num_labels=2, k=2)
    assert e.mr == (1,)


@pytest.mark.parametrize("bad", [
    "",                 # empty
    "   ",              # blank
    "(0 1)",            # missing +
    "0 1",              # missing +
    "()+",              # empty group
    "(0 1+",            # unbalanced parens
    '("0 1)+',          # unbalanced quote
    "((0 1))+",         # nested group
    "(0+ 1)+",          # stray +
    "(7)+",             # label id out of alphabet (num_labels=3)
    "(-1)+",            # negative id never parses as a label token
    "(frob)+",          # unknown name
    "(0 1 2)+",         # |MR| = 3 > k = 2
])
def test_parse_rejects_malformed(bad):
    with pytest.raises(ExpressionError):
        parse_expression(bad, num_labels=3, k=2)


def test_parse_error_messages_are_actionable():
    with pytest.raises(ExpressionError, match="unknown label 'frob'"):
        parse_expression("(frob)+", num_labels=3, k=2,
                         label_names={"knows": 0})
    with pytest.raises(ExpressionError, match="> k=2"):
        parse_expression("(0 1 2)+", num_labels=3, k=2)
    with pytest.raises(ExpressionError, match="out of range"):
        parse_expression("(5)+", num_labels=3, k=2)


# ------------------------------------------------------------------ #
# LRU result cache
# ------------------------------------------------------------------ #
def test_cache_hit_returns_identical_answer():
    c = ResultCache(capacity=8)
    c.put((1, 2, 0), True)
    c.put((3, 4, 1), False)
    assert c.get((1, 2, 0)) is True
    assert c.get((3, 4, 1)) is False    # negative answers are cached too
    assert c.stats.hits == 2 and c.stats.misses == 0


def test_cache_miss_and_eviction_at_capacity():
    c = ResultCache(capacity=2)
    c.put((0, 0, 0), True)
    c.put((1, 1, 1), True)
    assert c.get((9, 9, 9)) is None
    c.put((2, 2, 2), True)              # evicts LRU (0,0,0)
    assert len(c) == 2
    assert c.stats.evictions == 1
    assert c.get((0, 0, 0)) is None
    assert c.get((2, 2, 2)) is True


def test_cache_lru_recency_order():
    c = ResultCache(capacity=2)
    c.put((0, 0, 0), True)
    c.put((1, 1, 1), False)
    assert c.get((0, 0, 0)) is True     # refresh (0,0,0)
    c.put((2, 2, 2), True)              # now (1,1,1) is LRU -> evicted
    assert c.get((1, 1, 1)) is None
    assert c.get((0, 0, 0)) is True


def test_cache_zero_capacity_disables():
    c = ResultCache(capacity=0)
    c.put((0, 0, 0), True)
    assert c.get((0, 0, 0)) is None
    assert len(c) == 0


# ------------------------------------------------------------------ #
# Micro-batching scheduler
# ------------------------------------------------------------------ #
def test_scheduler_flushes_on_batch_full():
    clock = [0.0]
    b = MicroBatcher(batch_size=4, max_wait_s=100.0, clock=lambda: clock[0])
    for i in range(3):
        _, ready = b.submit(i, i, 0, 1)
        assert ready == []
    _, ready = b.submit(3, 3, 0, 1)
    assert len(ready) == 1
    batch = ready[0]
    assert batch.reason == "full"
    assert batch.n_real == 4 and batch.n_padding == 0
    assert [r.s for r in batch.requests] == [0, 1, 2, 3]
    assert b.pending() == 0


def test_scheduler_flushes_on_deadline():
    clock = [0.0]
    b = MicroBatcher(batch_size=8, max_wait_s=0.5, clock=lambda: clock[0])
    b.submit(0, 1, 0, 1)
    assert b.poll() == []               # deadline not reached
    clock[0] = 0.6
    ready = b.poll()
    assert len(ready) == 1
    assert ready[0].reason == "deadline"
    assert ready[0].n_real == 1
    # underfull flushes carry real slots only — no repeated-request
    # padding (the executor pads jit backends internally)
    assert len(ready[0].s) == 1 and ready[0].n_padding == 0
    assert list(ready[0].s) == [0] and list(ready[0].t) == [1]


def test_scheduler_deadline_checked_on_submit():
    clock = [0.0]
    b = MicroBatcher(batch_size=8, max_wait_s=0.5, clock=lambda: clock[0])
    b.submit(0, 1, 0, 1)                # bucket |MR|=1
    clock[0] = 1.0
    _, ready = b.submit(2, 3, 4, 2)     # bucket |MR|=2; poll fires bucket 1
    assert len(ready) == 1
    assert ready[0].mr_len == 1 and ready[0].reason == "deadline"
    assert b.pending() == 1             # the |MR|=2 request still queued


def test_scheduler_coalesces_duplicate_inflight_keys():
    clock = [0.0]
    b = MicroBatcher(batch_size=4, max_wait_s=100.0, clock=lambda: clock[0])
    r1, _ = b.submit(7, 9, 2, 1)
    r2, _ = b.submit(7, 9, 2, 1)        # duplicate while in flight
    assert r2.req_id == r1.req_id       # same request, no second slot
    assert b.pending() == 1 and b.coalesced == 1
    # a different key still takes its own slot
    r3, _ = b.submit(7, 9, 3, 1)
    assert r3.req_id != r1.req_id and b.pending() == 2
    # after the flush the key is no longer in flight -> fresh request
    batches = b.drain()
    assert len(batches) == 1 and batches[0].n_real == 2
    r4, _ = b.submit(7, 9, 2, 1)
    assert r4.req_id != r1.req_id
    assert b.coalesced == 1


def test_scheduler_coalesced_batch_never_double_books():
    b = MicroBatcher(batch_size=2, max_wait_s=100.0, clock=lambda: 0.0)
    b.submit(0, 1, 0, 1)
    _, ready = b.submit(0, 1, 0, 1)     # coalesced: bucket must NOT fill
    assert ready == []
    _, ready = b.submit(2, 3, 0, 1)     # second distinct request fills it
    assert len(ready) == 1
    assert [r.s for r in ready[0].requests] == [0, 2]


def test_service_fans_coalesced_answers_out():
    g = erdos_renyi(40, 3.0, 3, seed=17)
    svc = RLCService.build(g, ServiceConfig(k=2, batch_size=32,
                                            cache_capacity=0))
    # duplicates within one query_batch; cache off, so only coalescing
    # can collapse them
    qs = [(1, 2, "(0 1)+"), (3, 4, "(0)+"), (1, 2, "(0 1)+"),
          (1, 2, "(0 1)+"), (3, 4, "(0)+")]
    got = svc.query_batch(qs)
    assert got[0] == got[2] == got[3]
    assert got[1] == got[4]
    assert got == [bibfs_rlc(g, s, t,
                             parse_expression(c, num_labels=3, k=2).mr)
                   for s, t, c in qs]
    st = svc.stats()["scheduler"]
    assert st["coalesced"] == 3


def test_scheduler_background_ticker_fires_deadline_flush():
    import threading
    b = MicroBatcher(batch_size=8, max_wait_s=0.02)
    flushed = []
    done = threading.Event()

    def on_batch(batch):
        flushed.append(batch)
        done.set()

    assert not b.ticker_running
    b.start_ticker(on_batch)
    try:
        b.submit(0, 1, 0, 1)
        # no further admissions: only the ticker can flush this bucket
        assert done.wait(timeout=5.0), "ticker never flushed"
    finally:
        b.stop_ticker()
    assert not b.ticker_running
    assert len(flushed) == 1
    assert flushed[0].reason == "deadline" and flushed[0].n_real == 1
    assert b.pending() == 0
    with pytest.raises(RuntimeError):   # double start is a bug
        b.start_ticker(on_batch)
        b.start_ticker(on_batch)
    b.stop_ticker()


def test_scheduler_ticker_survives_callback_errors():
    import threading
    b = MicroBatcher(batch_size=8, max_wait_s=0.01)
    seen = []
    ok = threading.Event()

    def flaky(batch):
        if not seen:
            seen.append("boom")
            raise RuntimeError("executor died")
        ok.set()

    b.start_ticker(flaky)
    try:
        b.submit(0, 1, 0, 1)            # first flush: callback raises
        deadline = 5.0
        import time as _t
        t0 = _t.monotonic()
        while not seen and _t.monotonic() - t0 < deadline:
            _t.sleep(0.005)
        b.submit(2, 3, 0, 1)            # second flush must still fire
        assert ok.wait(timeout=5.0), "ticker died after callback error"
    finally:
        b.stop_ticker()
    assert b.ticker_errors == 1


def test_scheduler_buckets_by_mr_length():
    b = MicroBatcher(batch_size=2, max_wait_s=100.0, clock=lambda: 0.0)
    _, r1 = b.submit(0, 0, 0, 1)
    _, r2 = b.submit(1, 1, 5, 2)        # different bucket: no flush yet
    assert r1 == [] and r2 == []
    _, r3 = b.submit(2, 2, 6, 2)        # fills the |MR|=2 bucket
    assert len(r3) == 1 and r3[0].mr_len == 2
    assert all(req.mr_len == 2 for req in r3[0].requests)
    drained = b.drain()
    assert len(drained) == 1 and drained[0].mr_len == 1


# ------------------------------------------------------------------ #
# Multi-backend executor
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def small_setup():
    g = erdos_renyi(40, 3.0, 3, seed=2)
    svc = RLCService.build(g, ServiceConfig(k=2, batch_size=8,
                                            cache_capacity=0))
    rng = np.random.default_rng(1)
    mrs = enumerate_mrs(3, 2)
    queries = [(int(rng.integers(40)), int(rng.integers(40)),
                mrs[int(rng.integers(len(mrs)))]) for _ in range(48)]
    return g, svc, queries


def test_executor_backends_agree(small_setup):
    g, svc, queries = small_setup
    ex = svc.executor
    s = np.array([q[0] for q in queries], np.int32)
    t = np.array([q[1] for q in queries], np.int32)
    mr = np.array([svc.mr_ids[q[2]] for q in queries], np.int32)
    ref, b0 = ex.execute(s, t, mr, backend="python")
    assert b0 == "python"
    for backend in ("numpy", "sorted", "pallas"):
        got, b = ex.execute(s, t, mr, backend=backend)
        assert b == backend
        np.testing.assert_array_equal(got, ref, err_msg=backend)


def test_executor_fallback_when_device_missing(small_setup):
    g, svc, queries = small_setup
    ex = BatchExecutor(svc.index, svc.frozen, device_index=None,
                       id_to_mr=svc._id_to_mr, backend="auto")
    assert not ex.available("pallas") and not ex.available("sorted")
    s = np.array([q[0] for q in queries[:8]], np.int32)
    t = np.array([q[1] for q in queries[:8]], np.int32)
    mr = np.array([svc.mr_ids[q[2]] for q in queries[:8]], np.int32)
    got, backend = ex.execute(s, t, mr)
    assert backend in ("numpy", "python")
    ref, _ = ex.execute(s, t, mr, backend="python")
    np.testing.assert_array_equal(got, ref)


def test_executor_fallback_on_backend_failure(small_setup):
    g, svc, queries = small_setup

    class Boom:
        row_len = 8

        def query_batch(self, *a, **kw):
            raise RuntimeError("device lost")

    ex = BatchExecutor(svc.index, svc.frozen, device_index=Boom(),
                       id_to_mr=svc._id_to_mr, backend="sorted")
    s = np.array([q[0] for q in queries[:4]], np.int32)
    t = np.array([q[1] for q in queries[:4]], np.int32)
    mr = np.array([svc.mr_ids[q[2]] for q in queries[:4]], np.int32)
    got, backend = ex.execute(s, t, mr)
    assert backend in ("numpy", "python")   # fell through the chain
    assert ex.fallbacks == 1
    ref, _ = ex.execute(s, t, mr, backend="python")
    np.testing.assert_array_equal(got, ref)


def test_executor_records_per_backend_metrics(small_setup):
    g, svc, queries = small_setup
    stats = svc.executor.stats()
    # the fixture ran batches through every backend above
    assert any(k in stats for k in ("python", "numpy", "sorted", "pallas"))
    for v in stats.values():
        assert v["batches"] >= 1
        assert v["p99_ms"] >= v["p50_ms"] >= 0.0


# ------------------------------------------------------------------ #
# RLCService facade
# ------------------------------------------------------------------ #
def test_service_end_to_end_matches_oracle():
    g = erdos_renyi(50, 3.0, 3, seed=5)
    svc = RLCService.build(g, ServiceConfig(k=2, batch_size=8,
                                            cache_capacity=256))
    rng = np.random.default_rng(7)
    mrs = enumerate_mrs(3, 2)
    queries, want = [], []
    for _ in range(64):
        s, t = int(rng.integers(50)), int(rng.integers(50))
        L = mrs[int(rng.integers(len(mrs)))]
        queries.append((s, t, L))
        want.append(bibfs_rlc(g, s, t, L))
    got = svc.query_batch(queries)
    assert got == want
    # replay: everything should now come from the cache, same answers
    before = svc.cache.stats.hits
    assert svc.query_batch(queries) == want
    assert svc.cache.stats.hits >= before + len(set(queries))


def test_service_accepts_string_and_named_constraints():
    g, names, labels = fig1_graph()
    svc = RLCService.build(
        g, ServiceConfig(k=3, batch_size=4, label_names=labels))
    assert svc.query(names["A14"], names["A19"], "(debits credits)+") == True  # noqa: E712 — Answer equality
    assert svc.query(names["P10"], names["P13"],
                     "(knows knows worksFor)+") == False  # noqa: E712
    assert svc.query(names["A14"], names["A19"], (2, 3)) == True  # noqa: E712


def test_service_rejects_bad_input():
    g = erdos_renyi(20, 2.0, 2, seed=0)
    svc = RLCService.build(g, ServiceConfig(k=2))
    with pytest.raises(ExpressionError):
        svc.query(0, 1, "(0 1 0)+")      # |MR|=3 > k
    with pytest.raises(ValueError):
        svc.query(0, 99, "(0)+")         # vertex out of range
    with pytest.raises(ValueError):
        RLCService.build(g, ServiceConfig(k=3),
                         index=build_rlc_index(g, 2))  # k mismatch


def test_service_stats_shape():
    g = erdos_renyi(30, 2.0, 2, seed=3)
    svc = RLCService.build(g, ServiceConfig(k=2, batch_size=4))
    svc.query_batch([(0, 1, "(0)+"), (1, 2, "(1)+"), (0, 1, "(0)+")])
    st = svc.stats()
    assert st["queries_served"] == 3
    assert st["cache"]["hits"] + st["cache"]["misses"] == 3
    assert 0.0 <= st["cache"]["hit_rate"] <= 1.0    # ratio, not percent
    assert st["index"]["num_mrs"] == len(svc.mr_ids)
    assert st["scheduler"]["pending"] == 0
    # executor observability is one nested dict: per-backend latencies AND
    # the fallback count together (no more flat `fallbacks` sibling)
    assert "fallbacks" not in st
    assert set(st["executor"]) == {"backends", "fallbacks"}
    assert st["executor"]["fallbacks"] >= 0
    for b in st["executor"]["backends"].values():
        assert b["p99_ms"] >= b["p50_ms"] >= 0.0


# ------------------------------------------------------------------ #
# biased_true_queries fix
# ------------------------------------------------------------------ #
def test_biased_true_queries_multi_label_and_false_side():
    g = erdos_renyi(60, 4.0, 3, seed=9)
    k = 3
    qs = biased_true_queries(g, k, n=80, seed=4)
    assert len(qs.true_queries) == 80
    assert len(qs.false_queries) > 0
    # the old bug: only ever single-label constraints
    lens = {len(L) for _, _, L in qs.true_queries}
    assert lens - {1}, f"expected multi-label MRs, got lengths {lens}"
    assert all(1 <= len(L) <= k for _, _, L in qs.true_queries)
    # verify both sides against the oracle
    for s, t, L in qs.true_queries[:40]:
        assert bibfs_rlc(g, s, t, L), (s, t, L)
    for s, t, L in qs.false_queries[:40]:
        assert not bibfs_rlc(g, s, t, L), (s, t, L)
