"""Async admission (`submit()` futures), the typed Answer result, the
unified service lifecycle, and the ``repro.service.stats/1`` schema
(ISSUE-10 satellites 1-4)."""
import threading
import warnings

import numpy as np
import pytest

from repro.graphgen import erdos_renyi
from repro.service import (SHED, Answer, RLCService, ServiceConfig,
                           ShardedRLCService, ShardedServiceConfig,
                           validate_stats)

K = 2


def _svc(**kw):
    g = erdos_renyi(80, 3.0, 3, seed=11)
    cfg = dict(k=K, batch_size=8, backend="numpy", use_device=False)
    cfg.update(kw)
    return g, RLCService.build(g, ServiceConfig(**cfg))


def _queries(g, n=40, seed=0):
    rng = np.random.default_rng(seed)
    st = rng.integers(0, g.num_vertices, size=(n, 2))
    return [(int(s), int(t), (0,)) for s, t in st]


# ------------------------------------------------------------------ #
# The typed Answer result
# ------------------------------------------------------------------ #
def test_answer_wraps_bool_transparently():
    a = Answer(True, "computed", "numpy")
    assert bool(a) is True and a == True and a != False  # noqa: E712
    assert a == Answer(True, "cache_hit")  # equality is value-only
    assert hash(a) == hash(Answer(True, "computed", "sorted"))
    assert a.disposition == "computed" and a.backend == "numpy"
    assert "True" in repr(a) and "computed" in repr(a)
    d = a.as_dict()
    assert d == dict(value=True, disposition="computed", backend="numpy")


def test_answer_is_immutable_and_validated():
    a = Answer(False, "computed")
    with pytest.raises(AttributeError):
        a.value = True
    with pytest.raises(ValueError):
        Answer(None, "computed")        # only shed carries no value
    with pytest.raises(ValueError):
        Answer(True, "shed")            # shed carries no value
    with pytest.raises(ValueError):
        Answer(True, "nonsense")


def test_shed_is_an_answer_and_still_refuses_bool():
    assert isinstance(SHED, Answer) and SHED.shed
    assert repr(SHED) == "SHED"
    with pytest.raises(TypeError):
        bool(SHED)
    assert SHED == SHED and SHED != Answer(True, "computed")
    assert SHED != True and SHED != False  # noqa: E712


def test_query_returns_answers_with_dispositions():
    g, svc = _svc()
    qs = _queries(g, 12)
    first = svc.query_batch(qs)
    assert all(isinstance(a, Answer) for a in first)
    assert {a.disposition for a in first} == {"computed"}
    assert {a.backend for a in first} == {"numpy"}
    again = svc.query_batch(qs)
    assert {a.disposition for a in again} == {"cache_hit"}
    assert first == again               # equality is value-only
    assert [bool(a) for a in first] == [bool(a) for a in again]


# ------------------------------------------------------------------ #
# submit(): futures, ordering, coalescing, exceptions
# ------------------------------------------------------------------ #
def test_submit_matches_sync_answers():
    g, svc = _svc()
    qs = _queries(g, 40)
    sync = [bool(a) for a in svc.query_batch(qs)]
    svc.cache.clear()
    with svc.start():
        futs = [svc.submit(s, t, c) for s, t, c in qs]
        svc._engine.flush()
        vals = [f.result(timeout=30) for f in futs]
    assert [bool(v) for v in vals] == sync
    assert {v.disposition for v in vals} <= {"computed", "cache_hit"}


def test_submit_resolution_order_follows_admission_order():
    g, svc = _svc(batch_size=4)
    qs = _queries(g, 16, seed=3)
    order = []
    lock = threading.Lock()
    with svc.start():
        futs = []
        for i, (s, t, c) in enumerate(qs):
            f = svc.submit(s, t, c)
            f.add_done_callback(
                lambda _f, i=i: (lock.acquire(), order.append(i),
                                 lock.release()))
            futs.append(f)
        svc._engine.flush()
        for f in futs:
            f.result(timeout=30)
    # same-bucket batches flush in admission order, so the completion
    # order never inverts *within* the stream of non-cache-hit keys
    assert sorted(order) == list(range(16))
    non_hits = [i for i in order]
    assert non_hits == sorted(non_hits) or len(set(order)) == 16


def test_submit_coalesces_duplicate_inflight_keys():
    g, svc = _svc(batch_size=64, max_wait_ms=1e4)  # nothing auto-flushes
    s, t, c = _queries(g, 1, seed=5)[0]
    with svc.start(tick_interval_s=10.0):   # ticker effectively off
        f1 = svc.submit(s, t, c)
        f2 = svc.submit(s, t, c)
        f3 = svc.submit(s, t, c)
        assert svc.batcher.coalesced >= 2
        svc._engine.flush()
        r1, r2, r3 = (f.result(timeout=30) for f in (f1, f2, f3))
    assert bool(r1) == bool(r2) == bool(r3)
    assert svc._engine.exec_batches == 1    # one execution served all


def test_submit_cache_hit_resolves_immediately():
    g, svc = _svc()
    s, t, c = _queries(g, 1)[0]
    expected = bool(svc.query(s, t, c))
    with svc.start(tick_interval_s=10.0):
        f = svc.submit(s, t, c)
        assert f.done()                     # no execution round-trip
        assert f.result().disposition == "cache_hit"
        assert bool(f.result()) == expected


def test_submit_propagates_execution_exceptions():
    g, svc = _svc(batch_size=4)
    qs = _queries(g, 4, seed=7)
    boom = RuntimeError("executor exploded")

    orig = svc._run_batch

    def bad_run_batch(batch, tr=None):
        raise boom

    with svc.start(tick_interval_s=10.0):
        svc._run_batch = bad_run_batch
        futs = [svc.submit(s, t, c) for s, t, c in qs]
        svc._engine.flush()
        for f in futs:
            with pytest.raises(RuntimeError, match="executor exploded"):
                f.result(timeout=30)
        assert svc._engine.failed_batches >= 1
        # the engine survives: later submits still resolve
        svc._run_batch = orig
        svc.cache.clear()
        f = svc.submit(*qs[0])
        svc._engine.flush()
        assert isinstance(f.result(timeout=30), Answer)


def test_submit_sheds_via_admission_control():
    g, svc = _svc(batch_size=64, max_wait_ms=1e4, admission_max_pending=2)
    qs = _queries(g, 12, seed=9)
    with svc.start(tick_interval_s=10.0):
        futs = [svc.submit(s, t, c) for s, t, c in qs]
        shed = [f for f in futs if f.done() and f.result() is SHED]
        assert shed, "pending depth 2 must shed some of 12 submits"
        svc._engine.flush()
        vals = [f.result(timeout=30) for f in futs]
    assert all(isinstance(v, Answer) for v in vals)
    assert svc.queries_shed == len([v for v in vals if v.shed])
    assert svc.stats()["async"]["shed"] == svc.queries_shed


def test_malformed_submit_raises_synchronously():
    g, svc = _svc()
    with svc.start():
        with pytest.raises(ValueError):
            svc.submit(-5, 10 ** 9, (0,))


# ------------------------------------------------------------------ #
# Unified lifecycle
# ------------------------------------------------------------------ #
def test_lifecycle_is_idempotent_and_context_managed():
    g, svc = _svc()
    assert svc.start() is svc
    svc.start()                          # second start is a no-op
    assert svc._engine.active
    svc.close()
    svc.close()                          # double close is fine
    assert not svc._engine.active
    with pytest.raises(RuntimeError):
        svc.start()                      # closed services stay closed
    g2, svc2 = _svc()
    with svc2.start() as inside:
        assert inside is svc2
    assert svc2._closed


def test_sharded_shares_the_same_lifecycle():
    g = erdos_renyi(80, 3.0, 3, seed=11)
    svc = ShardedRLCService.build(g, ShardedServiceConfig(
        k=K, num_shards=2, batch_size=8, backend="numpy",
        use_device=False))
    qs = _queries(g, 20)
    sync = [bool(a) for a in svc.query_batch(qs)]
    svc.cache.clear()
    with svc.start():
        futs = [svc.submit(s, t, c) for s, t, c in qs]
        svc._engine.flush()
        assert [bool(f.result(timeout=30)) for f in futs] == sync
    assert svc._closed


def test_deprecated_ticker_shims_warn_and_delegate():
    g, svc = _svc()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        svc.start_ticker()
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert svc._engine is not None and svc._engine.active
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        svc.stop_ticker()
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert svc._closed


def test_query_batch_bridges_through_active_engine():
    g, svc = _svc()
    qs = _queries(g, 20)
    expected = [bool(a) for a in svc.query_batch(qs)]
    svc.cache.clear()
    with svc.start():
        got = svc.query_batch(qs)        # engine active: bridged path
        assert [bool(a) for a in got] == expected
        assert svc._engine.submitted >= 20


def test_scheduler_ticker_on_error_hook():
    from repro.service.scheduler import MicroBatcher
    mb = MicroBatcher(2, 1e-4)
    seen = []
    mb.start_ticker(lambda b: (_ for _ in ()).throw(RuntimeError("x")),
                    interval_s=1e-3, on_error=seen.append)
    try:
        mb.submit(1, 2, 0, 1)
        deadline = __import__("time").monotonic() + 5.0
        while not seen and __import__("time").monotonic() < deadline:
            __import__("time").sleep(0.01)
    finally:
        mb.stop_ticker()
    assert seen and isinstance(seen[0], RuntimeError)
    assert mb.ticker_errors >= 1


# ------------------------------------------------------------------ #
# The versioned stats schema
# ------------------------------------------------------------------ #
def test_stats_schema_single_and_sharded():
    g, svc = _svc()
    svc.query_batch(_queries(g, 8))
    doc = validate_stats(svc.stats())
    assert doc["schema"] == "repro.service.stats/1"
    assert doc["facade"] == "single" and doc["transport"] == "local"
    assert doc["async"] is None          # engine never started
    svc.start()
    assert validate_stats(svc.stats())["async"]["active"]
    svc.close()

    sh = ShardedRLCService.build(g, ShardedServiceConfig(
        k=K, num_shards=2, batch_size=8, backend="numpy",
        use_device=False))
    sh.query_batch(_queries(g, 8))
    doc = validate_stats(sh.stats())
    assert doc["facade"] == "sharded" and doc["transport"] == "inproc"
    assert {"local", "remote", "sub_batches",
            "digest_bytes"} <= set(doc["executor"])
    sh.close()


def test_validate_stats_rejects_drift():
    g, svc = _svc()
    doc = svc.stats()
    bad = dict(doc); bad["schema"] = "repro.service.stats/0"
    with pytest.raises(ValueError, match=r"\$\.schema"):
        validate_stats(bad)
    bad = dict(doc); bad.pop("scheduler")
    with pytest.raises(ValueError, match=r"\$\.scheduler"):
        validate_stats(bad)
    bad = dict(doc); bad["facade"] = "tripled"
    with pytest.raises(ValueError, match=r"\$\.facade"):
        validate_stats(bad)
    bad = dict(doc)
    bad["scheduler"] = dict(doc["scheduler"], coalesced=-1)
    with pytest.raises(ValueError, match=r"\$\.scheduler\.coalesced"):
        validate_stats(bad)
